"""Property + unit tests: JAX directory vs the pure-Python executable spec.

The refimpl is the oracle; the array directory must agree on every observable
(status codes, owner, pfn, derived per-node states) after arbitrary event
sequences, and both must uphold the paper's invariants (single-copy, no
sharers in E, deterministic teardown).
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core import refimpl as R
from repro.core.protocol import DPCProtocol, ProtocolConfig
from repro.core.coherence import CoherenceManager

CAP = 64
NODES = 8
CFG = dirx.DirectoryConfig(capacity=CAP, num_nodes=NODES, max_probe=CAP)


def fresh():
    return dirx.init_directory(CFG), R.RefDirectory(CAP, NODES)


def batch(stream, page, node, aux=0):
    return D.make_batch([stream], [page], [node], [aux])


def li(d, s, p, n=0, *, node=None):
    n = node if node is not None else n
    d, res = dirx.lookup_and_install(d, batch(s, p, n), max_probe=CFG.max_probe)
    return d, np.asarray(res)[0]


# ---------------------------------------------------------------------------
# unit tests: each Fig. 2 transition
# ---------------------------------------------------------------------------


class TestStateMachine:
    def test_acc_miss_alloc_grants_e(self):
        d, ref = fresh()
        d, res = li(d, 7, 3, node=2)
        want = ref.lookup_and_install(7, 3, 2)
        assert res[0] == D.ST_GRANT_E == want[0]
        assert ref.node_state((7, 3), 2) == "E"

    def test_second_requester_blocked_while_e(self):
        d, ref = fresh()
        d, _ = li(d, 7, 3, 2)
        ref.lookup_and_install(7, 3, 2)
        d, res = li(d, 7, 3, 5)
        want = ref.lookup_and_install(7, 3, 5)
        assert res[0] == D.ST_BLOCKED == want[0]

    def test_commit_publishes_owner(self):
        d, ref = fresh()
        d, _ = li(d, 7, 3, 2)
        ref.lookup_and_install(7, 3, 2)
        d, res = dirx.commit(d, batch(7, 3, 2, aux=42))
        assert np.asarray(res)[0, 0] == D.ST_OK
        assert ref.commit(7, 3, 2, 42) == D.ST_OK
        d, res = li(d, 7, 3, 5)
        want = ref.lookup_and_install(7, 3, 5)
        assert res[0] == D.ST_MAP_S == want[0]
        assert res[1] == 2 == want[1]      # owner
        assert res[2] == 42 == want[2]     # pfn
        assert ref.node_state((7, 3), 5) == "S"

    def test_commit_without_e_is_bad(self):
        d, ref = fresh()
        d, res = dirx.commit(d, batch(9, 9, 1, aux=5))
        assert np.asarray(res)[0, 0] == D.ST_BAD
        assert ref.commit(9, 9, 1, 5) == D.ST_BAD

    def test_owner_rehit(self):
        d, ref = fresh()
        d, _ = li(d, 1, 1, 0)
        ref.lookup_and_install(1, 1, 0)
        d, _ = dirx.commit(d, batch(1, 1, 0, aux=7))
        ref.commit(1, 1, 0, 7)
        d, res = li(d, 1, 1, 0)
        want = ref.lookup_and_install(1, 1, 0)
        assert res[0] == D.ST_HIT_OWNER == want[0]

    def test_full_invalidation_round(self):
        d, ref = fresh()
        # install by node 0, map on nodes 1, 2
        d, _ = li(d, 5, 0, 0)
        ref.lookup_and_install(5, 0, 0)
        d, _ = dirx.commit(d, batch(5, 0, 0, aux=11))
        ref.commit(5, 0, 0, 11)
        for n in (1, 2):
            d, _ = li(d, 5, 0, n)
            ref.lookup_and_install(5, 0, n)

        # owner evicts: O -> TBI, sharers notified
        d, res, masks = dirx.begin_invalidate(d, batch(5, 0, 0))
        st, sharers = ref.begin_invalidate(5, 0, 0)
        assert np.asarray(res)[0, 0] == D.ST_OK == st
        got = int(np.asarray(masks)[0, 0])
        assert got == (1 << 1) | (1 << 2)
        assert sharers == {1, 2}
        assert ref.node_state((5, 0), 0) == "TBI"

        # new access while TBI is blocked (both impls)
        d, res = li(d, 5, 0, 3)
        assert res[0] == D.ST_BLOCKED == ref.lookup_and_install(5, 0, 3)[0]

        # complete before ACKs -> BLOCKED
        d, res = dirx.complete_invalidate(d, batch(5, 0, 0))
        assert np.asarray(res)[0, 0] == D.ST_BLOCKED
        assert ref.complete_invalidate(5, 0, 0)[0] == D.ST_BLOCKED

        # sharer ACKs (node 2 observed it dirty)
        d, _ = dirx.ack_invalidate(d, batch(5, 0, 1, aux=0))
        ref.ack_invalidate(5, 0, 1, False)
        d, _ = dirx.ack_invalidate(d, batch(5, 0, 2, aux=1))
        ref.ack_invalidate(5, 0, 2, True)

        # INVALIDATION_ACK: entry removed, writeback required
        d, res = dirx.complete_invalidate(d, batch(5, 0, 0))
        st, dirty = ref.complete_invalidate(5, 0, 0)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK == st
        assert res[0, 2] == 1 and dirty
        assert ref.node_state((5, 0), 0) == "I"

        # page is installable again (all-I)
        d, res = li(d, 5, 0, 3)
        assert res[0] == D.ST_GRANT_E == ref.lookup_and_install(5, 0, 3)[0]

    def test_sharer_drop(self):
        d, ref = fresh()
        d, _ = li(d, 2, 2, 0)
        ref.lookup_and_install(2, 2, 0)
        d, _ = dirx.commit(d, batch(2, 2, 0, aux=1))
        ref.commit(2, 2, 0, 1)
        d, _ = li(d, 2, 2, 4)
        ref.lookup_and_install(2, 2, 4)
        d, res = dirx.sharer_drop(d, batch(2, 2, 4))
        assert np.asarray(res)[0, 0] == D.ST_OK == ref.sharer_drop(2, 2, 4)
        # eviction now needs no DIR_INV
        d, res, masks = dirx.begin_invalidate(d, batch(2, 2, 0))
        _, sharers = ref.begin_invalidate(2, 2, 0)
        assert int(np.asarray(masks)[0].sum()) == 0 and not sharers

    def test_abort_install(self):
        d, ref = fresh()
        d, _ = li(d, 3, 3, 1)
        ref.lookup_and_install(3, 3, 1)
        d, res = dirx.abort_install(d, batch(3, 3, 1))
        assert np.asarray(res)[0, 0] == D.ST_OK == ref.abort_install(3, 3, 1)
        d, res = li(d, 3, 3, 2)
        assert res[0] == D.ST_GRANT_E == ref.lookup_and_install(3, 3, 2)[0]

    def test_same_batch_serialization(self):
        """Two requests for the same absent page in ONE batch: first E,
        second BLOCKED — descriptor order is transaction order."""
        d, _ = fresh()
        descs = D.make_batch([9, 9], [4, 4], [0, 1])
        d, res = dirx.lookup_and_install(d, descs, max_probe=CFG.max_probe)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_GRANT_E
        assert res[1, 0] == D.ST_BLOCKED

    def test_padded_rows_skipped(self):
        d, _ = fresh()
        descs = D.pad_batch(D.make_batch([1], [1], [0]), 8)
        d, res = dirx.lookup_and_install(d, descs, max_probe=CFG.max_probe)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_GRANT_E
        assert (res[1:, 0] == dirx.STAT_SKIP).all()
        assert int(dirx.occupancy(d)) == 1

    def test_capacity_full(self):
        small = dirx.DirectoryConfig(capacity=4, num_nodes=4, max_probe=4)
        d = dirx.init_directory(small)
        ref = R.RefDirectory(4, 4)
        for i in range(4):
            d, res = dirx.lookup_and_install(d, batch(1, i, 0),
                                             max_probe=small.max_probe)
            assert np.asarray(res)[0, 0] == D.ST_GRANT_E
            assert ref.lookup_and_install(1, i, 0)[0] == D.ST_GRANT_E
        d, res = dirx.lookup_and_install(d, batch(1, 99, 0),
                                         max_probe=small.max_probe)
        assert np.asarray(res)[0, 0] == D.ST_FULL
        assert ref.lookup_and_install(1, 99, 0)[0] == D.ST_FULL

    def test_fail_node_drops_ownership_and_shares(self):
        d, ref = fresh()
        # node 1 owns (1,0); node 2 shares it; node 2 owns (1,1)
        for s, p, owner in [(1, 0, 1), (1, 1, 2)]:
            d, _ = li(d, s, p, owner)
            ref.lookup_and_install(s, p, owner)
            d, _ = dirx.commit(d, batch(s, p, owner, aux=p))
            ref.commit(s, p, owner, p)
        d, _ = li(d, 1, 0, 2)
        ref.lookup_and_install(1, 0, 2)

        d, n_owned = dirx.fail_node(d, jnp.int32(2))
        owned, shared = ref.fail_node(2)
        assert int(n_owned) == 1 == len(owned)
        assert shared == [(1, 0)]
        # (1,1) is gone: reinstallable; (1,0) has no sharers left
        d, res = li(d, 1, 1, 0)
        assert res[0] == D.ST_GRANT_E == ref.lookup_and_install(1, 1, 0)[0]
        host = dirx.to_host_dict(d, CFG)
        assert host[(1, 0)][2] == set()


# ---------------------------------------------------------------------------
# property test: random event sequences, array impl ≡ refimpl
# ---------------------------------------------------------------------------


def _apply_event(d, ref, event, failed):
    """One random event against both implementations; asserts agreement."""
    op, s, p, n, dirty = event
    if op == "lookup":
        d, res = li(d, s, p, n)
        want = ref.lookup_and_install(s, p, n)
        assert tuple(res) == want, (op, s, p, n)
    elif op == "commit":
        d, res = dirx.commit(d, batch(s, p, n, aux=17))
        assert np.asarray(res)[0, 0] == ref.commit(s, p, n, 17)
    elif op == "begin_inv":
        d, res, masks = dirx.begin_invalidate(d, batch(s, p, n))
        st_ref, sharers = ref.begin_invalidate(s, p, n)
        assert np.asarray(res)[0, 0] == st_ref
        if st_ref == D.ST_OK:
            got = set()
            for w, bits in enumerate(np.asarray(masks)[0].tolist()):
                for b in range(32):
                    if int(bits) & (1 << b):
                        got.add(w * 32 + b)
            assert got == sharers
    elif op == "begin_mig":
        d, res, masks = dirx.begin_migrate(d, batch(s, p, n))
        st_ref, old_owner, old_pfn, sharers = ref.begin_migrate(s, p, n)
        res = np.asarray(res)
        assert res[0, 0] == st_ref
        if st_ref == D.ST_OK:
            assert res[0, 1] == old_owner and res[0, 2] == old_pfn
            got = set()
            for w, bits in enumerate(np.asarray(masks)[0].tolist()):
                for b in range(32):
                    if int(bits) & (1 << b):
                        got.add(w * 32 + b)
            assert got == sharers
    elif op == "complete_mig":
        # aux = current owner: completions only land on our own TBM entries
        old = ref.entries.get((s, p)).owner if (s, p) in ref.entries else -1
        d, res = dirx.complete_migrate(d, batch(s, p, n, aux=old))
        st_ref, dirty_ref = ref.complete_migrate(s, p, n, old)
        res = np.asarray(res)
        assert res[0, 0] == st_ref
        if st_ref == D.ST_OK:
            assert bool(res[0, 2]) == dirty_ref
    elif op == "ack_inv":
        d, res = dirx.ack_invalidate(d, batch(s, p, n, aux=int(dirty)))
        assert np.asarray(res)[0, 0] == ref.ack_invalidate(s, p, n, dirty)
    elif op == "complete_inv":
        d, res = dirx.complete_invalidate(d, batch(s, p, n))
        st_ref, dirty_ref = ref.complete_invalidate(s, p, n)
        res = np.asarray(res)
        assert res[0, 0] == st_ref
        if st_ref == D.ST_OK:
            assert bool(res[0, 2]) == dirty_ref
    elif op == "drop":
        d, res = dirx.sharer_drop(d, batch(s, p, n, aux=int(dirty)))
        assert np.asarray(res)[0, 0] == ref.sharer_drop(s, p, n, dirty)
    elif op == "fail":
        if n not in failed:
            failed.add(n)
            d, _ = dirx.fail_node(d, jnp.int32(n))
            ref.fail_node(n)
    ref.check_invariants()
    return d


EVENT_OPS = ["lookup", "commit", "begin_inv", "ack_inv", "complete_inv",
             "begin_mig", "complete_mig", "drop", "fail"]


def _check_final_equivalence(d, ref):
    host = dirx.to_host_dict(d, CFG)
    want = {k: (e.state, e.owner, set(e.sharers), e.pfn)
            for k, e in ref.entries.items()}
    got = {k: (v[0], v[1], v[2], v[3]) for k, v in host.items()}
    assert got == want


def _run_events(events):
    d = dirx.init_directory(CFG)
    ref = R.RefDirectory(CAP, NODES)
    failed = set()
    for event in events:
        d = _apply_event(d, ref, event, failed)
    _check_final_equivalence(d, ref)


@pytest.mark.parametrize("seed", range(4))
def test_directory_matches_refimpl_seeded(seed):
    """Tier-1 fixed-seed variant (runs even without hypothesis)."""
    rng = np.random.default_rng(seed)
    events = [(EVENT_OPS[rng.integers(len(EVENT_OPS))],
               int(rng.integers(4)), int(rng.integers(6)),
               int(rng.integers(NODES)), bool(rng.integers(2)))
              for _ in range(80)]
    _run_events(events)


if HAVE_HYPOTHESIS:
    EVENTS = st.lists(
        st.tuples(
            st.sampled_from(EVENT_OPS),
            st.integers(0, 3),    # stream
            st.integers(0, 5),    # page
            st.integers(0, NODES - 1),
            st.booleans(),        # dirty
        ),
        min_size=1, max_size=60,
    )

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(EVENTS)
    def test_directory_matches_refimpl(events):
        _run_events(events)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_directory_matches_refimpl():
        pass


# ---------------------------------------------------------------------------
# protocol-level: full read/commit/reclaim flows with pools
# ---------------------------------------------------------------------------


class TestProtocolFlows:
    def make(self, placement="sharded", pool_pages=8):
        # shadow_oracle: every flow in this class also runs against the
        # refimpl in lockstep — dirty-bit divergence fails loudly
        cfg = ProtocolConfig(num_nodes=4, pool_pages=pool_pages,
                             directory_capacity=256, placement=placement,
                             shadow_oracle=True)
        return DPCProtocol(cfg)

    @pytest.mark.parametrize("placement", ["sharded", "central"])
    def test_read_grant_commit_then_remote_hit(self, placement):
        proto = self.make(placement)
        res = proto.read_pages([1, 1, 1], [0, 1, 2], node=0)
        assert (res.status == D.ST_GRANT_E).all()
        assert (res.slot >= 0).all()
        proto.commit_pages([1, 1, 1], [0, 1, 2], 0, res.slot)

        res2 = proto.read_pages([1, 1, 1], [0, 1, 2], node=1)
        assert (res2.status == D.ST_MAP_S).all()
        assert (res2.owner == 0).all()
        # pfn encodes (owner node, slot)
        assert (res2.pfn // proto.cfg.pool_pages == 0).all()
        assert proto.hit_rate() == 0.5

    def test_single_copy_invariant_cluster_wide(self):
        proto = self.make()
        # all four nodes read the same 3 pages; exactly one owner each
        for node in range(4):
            res = proto.read_pages([9] * 3, [0, 1, 2], node)
            g = res.granted()
            if len(g):
                proto.commit_pages(np.asarray([9] * 3)[g],
                                   np.asarray([0, 1, 2])[g], node, res.slot[g])
        view = proto.directory_view()
        assert len(view) == 3
        owners = [v[1] for v in view.values()]
        assert all(o == 0 for o in owners)  # first reader installed them
        # later readers are sharers, no second copy anywhere
        total_installed = sum(
            int(np.asarray(p.slot_state == 2).sum()) for p in proto.state.pools)
        assert total_installed == 3

    def test_reclaim_full_round(self):
        proto = self.make(pool_pages=4)
        streams, pages = [3] * 4, list(range(4))
        res = proto.read_pages(streams, pages, 0)
        proto.commit_pages(streams, pages, 0, res.slot)
        proto.read_pages(streams, pages, 1)  # node 1 maps all 4 remotely

        # pool full: next grant fails until reclaim
        r2 = proto.read_pages([4], [0], 0)
        assert r2.status[0] == D.ST_FULL

        freed, wb = proto.reclaim_sync(0, want=2)
        assert freed == 2 and wb == 0
        assert int(proto.state.pools[0].free_top) == 2

        # sharer node 1 no longer maps the torn-down pages
        view = proto.directory_view()
        assert len(view) == 2
        for v in view.values():
            assert v[2] == {1}

        # and the freed frames are reusable
        r3 = proto.read_pages([4, 4], [0, 1], 0)
        assert (r3.status == D.ST_GRANT_E).all()

    def test_deterministic_reclaim_blocks_until_acks(self):
        proto = self.make(pool_pages=4)
        res = proto.read_pages([5], [0], 0)
        proto.commit_pages([5], [0], 0, res.slot)
        proto.read_pages([5], [0], 2)

        victims, notify = proto.reclaim_begin(0, want=1)
        assert len(victims) == 1 and notify == {(5, 0): [2]}
        # not freed yet — deterministic sequence requires the ACK
        freed, _ = proto.reclaim_finish(0)
        assert freed == 0
        proto.reclaim_ack(5, 0, 2)
        freed, _ = proto.reclaim_finish(0)
        assert freed == 1

    def test_failed_node_unblocks_eviction(self):
        """Paper §5 liveness: a dead sharer must not pin the owner's memory."""
        proto = self.make(pool_pages=4)
        res = proto.read_pages([6], [0], 0)
        proto.commit_pages([6], [0], 0, res.slot)
        proto.read_pages([6], [0], 3)

        _, notify = proto.reclaim_begin(0, want=1)
        assert notify == {(6, 0): [3]}
        proto.fail_node(3)  # node 3 never ACKs
        freed, _ = proto.reclaim_finish(0)
        assert freed == 1

    def test_strong_write_two_step(self):
        proto = self.make()
        coh = CoherenceManager(proto, "dpc_sc")
        t = coh.prepare([7, 7], [0, 1], node=1)
        assert len(t.locked_rows) == 2
        assert coh.commit(t) == 2
        # a second writer on another node maps the pages (write-through)
        t2 = coh.prepare([7, 7], [0, 1], node=2)
        assert len(t2.remote_rows) == 2
        coh.commit(t2)
        view = proto.directory_view()
        assert all(v[4] for v in view.values())  # dirty

    def test_relaxed_write_no_roundtrip(self):
        proto = self.make()
        coh = CoherenceManager(proto, "dpc")
        t = coh.prepare([7], [0], node=1)
        assert len(t.locked_rows) == 0 and len(t.remote_rows) == 0
        assert proto.counters["reads"] == 0
