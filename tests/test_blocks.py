"""Block-level consistency: chunked/parallel forms vs token-by-token oracles,
MoE capacity dispatch vs dense oracle, MLA prefill vs absorbed decode."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_arch
from repro.models import layers, mla, moe, rwkv6, ssm_mamba2
from repro.models.spec import init_params


def params_for(specs, seed=0):
    return init_params(specs, jax.random.PRNGKey(seed))


class TestMamba2:
    def test_chunked_matches_recurrent(self):
        cfg = get_smoke_arch("zamba2-1.2b")
        specs = ssm_mamba2.mamba2_specs(cfg)
        params = params_for(specs)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, cfg.d_model),
                              jnp.float32)
        y_chunk, (conv_c, st_c) = ssm_mamba2.mamba2_forward(
            params, cfg, x, return_state=True)
        y_rec, (conv_r, st_r) = ssm_mamba2.mamba2_recurrent_oracle(
            params, cfg, x)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(conv_c, np.float32),
                                   np.asarray(conv_r, np.float32), atol=1e-6)

    def test_prefill_then_decode_continues(self):
        """Handoff: chunked prefill state feeds the recurrent decode."""
        cfg = get_smoke_arch("zamba2-1.2b")
        params = params_for(ssm_mamba2.mamba2_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 21, cfg.d_model),
                              jnp.float32)
        y_full = ssm_mamba2.mamba2_forward(params, cfg, x)
        y_pre, (conv, st) = ssm_mamba2.mamba2_forward(
            params, cfg, x[:, :16], return_state=True)
        ys = [y_pre]
        for i in range(16, 21):
            y1, conv, st = ssm_mamba2.mamba2_decode(params, cfg, x[:, i],
                                                    conv, st)
            ys.append(y1[:, None])
        y_cat = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                                   atol=2e-4, rtol=2e-4)


class TestRWKV6:
    def test_chunked_matches_recurrent(self):
        cfg = get_smoke_arch("rwkv6-3b")
        params = params_for(rwkv6.rwkv6_timemix_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 45, cfg.d_model),
                              jnp.float32)
        y_chunk, (sh_c, wkv_c) = rwkv6.rwkv6_timemix(params, cfg, x,
                                                     return_state=True)
        y_rec, (sh_r, wkv_r) = rwkv6.rwkv6_recurrent_oracle(params, cfg, x)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(wkv_c), np.asarray(wkv_r),
                                   atol=2e-4, rtol=2e-4)

    def test_channelmix_decode_matches(self):
        cfg = get_smoke_arch("rwkv6-3b")
        params = params_for(rwkv6.rwkv6_channelmix_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 9, cfg.d_model),
                              jnp.float32)
        y, last = rwkv6.rwkv6_channelmix(params, x, return_state=True)
        # replay final token through decode with the prior shift state
        y1, _ = rwkv6.rwkv6_channelmix_decode(params, x[:, -1], x[:, -2])
        np.testing.assert_allclose(np.asarray(y[:, -1]), np.asarray(y1),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(last), np.asarray(x[:, -1]))


class TestMoE:
    def test_capacity_dispatch_matches_dense_oracle(self):
        cfg = get_smoke_arch("qwen3-moe-235b-a22b")
        # huge capacity factor -> no drops -> must equal the dense oracle
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
        params = params_for(moe.moe_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(5), (3, 16, cfg.d_model),
                              jnp.float32)
        y, aux = moe.moe_apply(params, cfg, x)
        y_ref = moe.moe_apply_dense_oracle(params, cfg, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        assert float(aux) > 0

    def test_capacity_drops_bounded(self):
        cfg = get_smoke_arch("deepseek-v2-lite-16b")
        params = params_for(moe.moe_specs(cfg))
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, cfg.d_model),
                              jnp.float32)
        y, aux = moe.moe_apply(params, cfg, x)
        assert y.shape == x.shape
        assert np.isfinite(np.asarray(y)).all()

    def test_shared_experts_contribute(self):
        cfg = get_smoke_arch("deepseek-v2-lite-16b")
        params = params_for(moe.moe_specs(cfg))
        assert "ws_gate" in params  # deepseek has shared experts
        x = jnp.ones((1, 4, cfg.d_model), jnp.float32)
        y, _ = moe.moe_apply(params, cfg, x)
        # zeroing shared experts must change the output
        params2 = dict(params, ws_down=jnp.zeros_like(params["ws_down"]))
        y2, _ = moe.moe_apply(params2, cfg, x)
        assert not np.allclose(np.asarray(y), np.asarray(y2))


class TestMLA:
    def test_prefill_matches_absorbed_decode(self):
        """The absorbed decode on cached latents must reproduce the last-token
        output of the full prefill attention (the correctness of absorption
        AND of the paged latent cache layout)."""
        from repro.kernels import dispatch as kd
        cfg = get_smoke_arch("deepseek-v2-lite-16b")
        params = params_for(mla.mla_specs(cfg))
        b, s = 2, 12
        x = jax.random.normal(jax.random.PRNGKey(7), (b, s, cfg.d_model),
                              jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        out_full, latent = mla.mla_prefill_attention(params, cfg, x, positions)

        # build a latent pool: page size 4, s=12 -> 3 pages per request
        page = 4
        n_pages = s // page
        rd = latent.shape[-1]
        pool = latent.reshape(b * n_pages, page, rd)
        pt = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
        sl = jnp.full((b,), s, jnp.int32)

        ql, qr = mla.mla_decode_q(params, cfg, x[:, -1],
                                  positions[:, -1])
        o_lat = kd.mla_paged_attention(ql, qr, pool, pt, sl,
                                       sm_scale=mla.mla_sm_scale(cfg),
                                       impl="ref")
        out_dec = mla.mla_decode_out(params, o_lat)
        np.testing.assert_allclose(np.asarray(out_dec),
                                   np.asarray(out_full[:, -1]),
                                   atol=2e-4, rtol=2e-4)
