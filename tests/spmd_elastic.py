"""Elastic re-mesh drill under 8 virtual devices (subprocess; see
tests/test_distributed.py).

Simulates the full large-scale failure path:
  1. train on a (4, 2) mesh with production shardings,
  2. checkpoint,
  3. "lose" a data row -> membership epoch bump -> elastic_mesh_shape picks
     (2, 2) (the surviving shape at the same TP width),
  4. re-lower the SAME step function on the smaller mesh, restore the
     checkpoint into the NEW shardings, continue training.

Asserts the restored loss continues from (not restarts) the pre-failure
trajectory.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_arch
from repro.configs.base import (MeshConfig, RunConfig, ShapeConfig,
                                ShardingConfig)
from repro.models import registry
from repro.runtime.liveness import Membership, elastic_mesh_shape
from repro.training import train_step as tst


def jit_on_mesh(run, api, mesh, ocfg):
    from repro import sharding as shardlib
    step = tst.make_train_step(run, api, n_micro=1, ocfg=ocfg)
    state_abs = tst.abstract_train_state(run, api, ocfg=ocfg)
    st_sh = tst.state_shardings(run, api, mesh, state_abs)
    batch_spec = registry.train_batch_spec(run.arch, run.shape.global_batch,
                                           run.shape.seq_len)
    b_sh = tst.batch_shardings(run, mesh, batch_spec)
    with shardlib.activation_sharding(mesh, run.sharding):
        return jax.jit(step, in_shardings=(st_sh, b_sh)), st_sh


def main():
    arch = get_smoke_arch("qwen3-1.7b")
    api = registry.get_model(arch)
    run = RunConfig(arch=arch, shape=ShapeConfig("t", 32, 8, "train"),
                    mesh=MeshConfig((4, 2), ("data", "model")),
                    sharding=ShardingConfig(remat="none"), warmup_steps=1)
    ocfg = tst.adamw_config(run, total_steps=20)
    batch = registry.make_train_batch(arch, 8, 32, jax.random.PRNGKey(1))

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    step_a, sh_a = jit_on_mesh(run, api, mesh_a, ocfg)
    state = tst.init_train_state(run, api, jax.random.PRNGKey(0), ocfg=ocfg)
    state = jax.device_put(state, sh_a)

    ckpt = CheckpointManager("/tmp/repro_elastic_ckpt")
    losses = []
    for i in range(4):
        state, m = step_a(state, batch)
        losses.append(float(m["loss"]))
    ckpt.save(4, state, blocking=True)
    for i in range(2):   # steps that will be LOST by the failure
        state, m = step_a(state, batch)

    # --- failure: one 2-chip node group dies -> 6 chips survive
    membership = Membership(num_nodes=4)
    membership.evict(3, "fail")
    new_shape = elastic_mesh_shape(len(membership.alive) * 2,
                                   model_parallel=2)
    assert new_shape == (3, 2), new_shape
    # global batch 8 needs data | 8: shrink further to the largest divisor
    data = max(d for d in range(1, new_shape[0] + 1) if 8 % d == 0)
    mesh_b = jax.make_mesh((data, 2), ("data", "model"))
    print(f"epoch={membership.epoch} remesh {run.mesh.shape} -> ({data}, 2)")

    run_b = run.replace(mesh=MeshConfig((data, 2), ("data", "model")))
    step_b, sh_b = jit_on_mesh(run_b, api, mesh_b, ocfg)
    state_abs = tst.abstract_train_state(run_b, api, ocfg=ocfg)
    state_like = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        state_abs, sh_b)
    restored, _, at_step = ckpt.restore_latest(state_like)
    assert at_step == 4

    resumed = []
    for i in range(2):
        restored, m = step_b(restored, batch)
        resumed.append(float(m["loss"]))
    print("pre-failure losses:", [f"{x:.4f}" for x in losses])
    print("resumed losses:", [f"{x:.4f}" for x in resumed])
    # resumed trajectory continues below the last checkpointed loss
    assert resumed[0] < losses[0], "must continue, not restart"
    assert all(np.isfinite(resumed))
    print("ELASTIC_OK")


if __name__ == "__main__":
    main()
