"""SPMD correctness checks — run with 8 virtual CPU devices.

Invoked by tests/test_distributed.py via subprocess (the device-count flag
must be set before jax initializes).  Each check compares a distributed
datapath against the single-device LocalBackend oracle over identical global
state and prints OK lines that the test asserts on.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core.remote_read import make_shipdata_attend
from repro.core.ship_compute import make_dpc_attend, make_dpc_attend_mla
from repro.models.cache import LocalBackend


def make_case(seed=0, b=8, hq=4, hkv=2, d=16, pool_pages_total=32, page=4,
              n_pages=3):
    rng = np.random.RandomState(seed)
    q = rng.randn(b, hq, d).astype(np.float32)
    k_new = rng.randn(b, hkv, d).astype(np.float32)
    v_new = rng.randn(b, hkv, d).astype(np.float32)
    k_pool = rng.randn(pool_pages_total, page, hkv, d).astype(np.float32)
    v_pool = rng.randn(pool_pages_total, page, hkv, d).astype(np.float32)

    # unique global page ids per request; last valid page is the append page
    pt = np.full((b, n_pages), -1, np.int32)
    sl = np.zeros((b,), np.int32)
    ap = np.zeros((b,), np.int32)
    perm = rng.permutation(pool_pages_total)
    ptr = 0
    for i in range(b):
        nv = 1 + (i % n_pages)
        pt[i, :nv] = perm[ptr:ptr + nv]
        ptr += nv
        # seq fills all but the last page fully, last page partially
        sl[i] = (nv - 1) * page + (i % page)
        ap[i] = pt[i, nv - 1]
    return (jnp.asarray(q), jnp.asarray(k_new), jnp.asarray(v_new),
            jnp.asarray(k_pool), jnp.asarray(v_pool), jnp.asarray(pt),
            jnp.asarray(sl), jnp.asarray(ap))


def oracle(q, k_new, v_new, k_pool, v_pool, pt, sl, ap):
    be = LocalBackend(pt, sl, ap % k_pool.shape[0], impl="ref")
    # LocalBackend appends at (append_slot, sl % page) then attends; the
    # global-id table indexes the full pool directly on one device.
    return be.attend(q, k_new, v_new, k_pool, v_pool)


def check(name, got, want, atol=1e-4):
    ok = np.allclose(np.asarray(got, np.float32),
                     np.asarray(want, np.float32), atol=atol, rtol=1e-4)
    print(f"{'OK' if ok else 'FAIL'} {name} "
          f"max_err={np.abs(np.asarray(got, np.float32) - np.asarray(want, np.float32)).max():.2e}")
    return ok


def main():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    case = make_case()
    q, k_new, v_new, k_pool, v_pool, pt, sl, ap = case
    pool_pages_local = k_pool.shape[0] // 8  # 8 nodes = 4*2

    want_out, want_k, want_v = oracle(*case)

    all_ok = True

    attend = make_dpc_attend(mesh, batch_axes=("data",), head_axis="model",
                             pool_pages=pool_pages_local, impl="ref")
    got_out, got_k, got_v = attend(q, k_new, v_new, k_pool, v_pool,
                                   pt, sl, ap)
    all_ok &= check("ship_compute.out", got_out, want_out)
    all_ok &= check("ship_compute.k_pool", got_k, want_k)
    all_ok &= check("ship_compute.v_pool", got_v, want_v)

    attend_sd = make_shipdata_attend(mesh, batch_axes=("data",),
                                     head_axis="model",
                                     pool_pages=pool_pages_local, impl="ref")
    got_out, got_k, got_v, ovf = attend_sd(q, k_new, v_new, k_pool,
                                           v_pool, pt, sl, ap)
    all_ok &= check("ship_data.out", got_out, want_out)
    all_ok &= check("ship_data.k_pool", got_k, want_k)
    all_ok &= check("ship_data.v_pool", got_v, want_v)
    if int(ovf) != 0:
        print(f"FAIL ship_data.overflow={int(ovf)}")
        all_ok = False
    else:
        print("OK ship_data.overflow=0")

    # --- MLA variant
    rng = np.random.RandomState(1)
    b, h, r, dr, page = 8, 4, 16, 8, 4
    ql = jnp.asarray(rng.randn(b, h, r), jnp.float32)
    qr = jnp.asarray(rng.randn(b, h, dr), jnp.float32)
    lat_new = jnp.asarray(rng.randn(b, r + dr), jnp.float32)
    pool = jnp.asarray(rng.randn(32, page, r + dr), jnp.float32)
    be = LocalBackend(pt, sl, ap, impl="ref")
    want_mla, want_pool = be.attend_mla(ql, qr, lat_new, pool, sm_scale=0.17)

    attend_mla = make_dpc_attend_mla(
        mesh, batch_axes=("data",), head_axis="model",
        pool_pages=pool_pages_local, impl="ref", sm_scale=0.17)
    got_mla, got_pool = attend_mla(ql, qr, lat_new, pool, pt, sl, ap)
    all_ok &= check("ship_compute_mla.out", got_mla, want_mla)
    all_ok &= check("ship_compute_mla.pool", got_pool, want_pool)

    # --- 3-axis mesh (pod)
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    attend3 = make_dpc_attend(mesh3, batch_axes=("pod", "data"),
                              head_axis="model",
                              pool_pages=pool_pages_local, impl="ref")
    got_out, got_k, got_v = attend3(q, k_new, v_new, k_pool, v_pool,
                                    pt, sl, ap)
    all_ok &= check("ship_compute_pod.out", got_out, want_out)

    attend3_sd = make_shipdata_attend(mesh3, batch_axes=("pod", "data"),
                                      head_axis="model",
                                      pool_pages=pool_pages_local, impl="ref")
    got_out, _, _, ovf = attend3_sd(q, k_new, v_new, k_pool, v_pool,
                                    pt, sl, ap)
    all_ok &= check("ship_data_pod.out", got_out, want_out)

    all_ok &= check_lane_transport(mesh)

    print("ALL_OK" if all_ok else "SOME_FAILED")
    sys.exit(0 if all_ok else 1)


def check_lane_transport(mesh):
    """Data-plane lanes under SPMD: a routed opcode batch carrying
    SHOOTDOWN/COPY/FLUSH rows, sharded across the mesh's data axis, must
    (a) leave the directory op's results and end state identical to the
    unsharded run, (b) leave lane rows directory-inert (STAT_SKIP), and
    (c) survive the device round trip bit-exactly so the receiving node
    decodes the same obligations that were posted."""
    ok = True
    dcfg = dirx.DirectoryConfig(capacity=64, num_nodes=8)

    shoot = [(1, 5, 0), (6, 11, 0)]
    copies = [(3, 7, 9), (3, 8, 10), (5, 2, 4)]
    flushes = [(4, 6, 0), (4, 9, 1), (2, 1, 0)]
    lanes = np.concatenate([D.encode_shootdowns(shoot),
                            D.encode_copies(copies),
                            D.encode_flushes(flushes)])
    lookups = np.asarray(D.make_batch(list(range(1, 9)), [0] * 8, [2] * 8))
    # interleave: every lookup row is followed by a lane row, so inertness
    # is tested in the adversarial (mixed) layout the transport produces
    batch = np.empty((16, 4), np.int32)
    batch[0::2], batch[1::2] = lookups, lanes

    _, want = dirx.lookup_and_install(dirx.init_directory(dcfg),
                                      jnp.asarray(batch))
    d_want, _ = dirx.lookup_and_install(dirx.init_directory(dcfg),
                                        jnp.asarray(batch))

    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))
    sharded = jax.device_put(jnp.asarray(batch), sharding)
    d_got, got = dirx.lookup_and_install(
        jax.device_put(dirx.init_directory(dcfg),
                       jax.sharding.NamedSharding(
                           mesh, jax.sharding.PartitionSpec())),
        sharded)
    ok &= check("lane_transport.results", got, want, atol=0)
    for field in ("keys", "state", "owner", "pfn"):
        ok &= check(f"lane_transport.dir.{field}",
                    getattr(d_got, field), getattr(d_want, field), atol=0)

    skips = np.asarray(got)[1::2, 0]
    if np.all(skips == dirx.STAT_SKIP):
        print("OK lane_transport.inert")
    else:
        print(f"FAIL lane_transport.inert statuses={skips.tolist()}")
        ok = False

    # round trip: the sharded device batch decodes to the posted obligations
    back = np.asarray(sharded)[1::2]
    rt = (D.decode_shootdowns(back) == shoot
          and D.decode_copies(back) == copies
          and D.decode_flushes(back) == flushes)
    print("OK lane_transport.roundtrip" if rt
          else "FAIL lane_transport.roundtrip")
    return ok & rt


if __name__ == "__main__":
    main()
