"""Unit tests for the CI perf-regression gate (benchmarks/compare_baseline).

The acceptance check for ISSUE 5: a synthetic >4x regression must FAIL the
build (exit 1), a 2-4x one must only warn, and the ALLOWLIST must exempt
intentionally-moved rows from the blocking tier.  Pure host-side JSON work —
no jax, tier 1.
"""

import json
import subprocess
import sys

from benchmarks.compare_baseline import (check_allowlist, compare,
                                         load_allowlist)


def _write_bench(dirpath, suite, rows):
    dirpath.mkdir(parents=True, exist_ok=True)
    path = dirpath / f"BENCH_{suite}.json"
    path.write_text(json.dumps({
        "suite": suite, "unix_time": 0.0,
        "rows": [{"name": n, "us_per_call": us, "derived": ""}
                 for n, us in rows.items()]}))
    return path


def make_pair(tmp_path, base_rows, fresh_rows, suite="x"):
    _write_bench(tmp_path / "baselines", suite, base_rows)
    _write_bench(tmp_path / "fresh", suite, fresh_rows)
    return str(tmp_path / "fresh"), str(tmp_path / "baselines")


class TestBlockingGate:
    def test_over_4x_regression_fails_the_build(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 450.0})
        code, warns, fails = compare(fresh, base)
        assert code == 1
        assert fails == [("x.a", 4.5)]

    def test_2x_to_4x_only_warns(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 250.0})
        code, warns, fails = compare(fresh, base)
        assert code == 0 and not fails
        assert warns == [("x.a", 2.5)]

    def test_within_threshold_is_clean(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 150.0})
        assert compare(fresh, base) == (0, [], [])

    def test_strict_escalates_warnings(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 250.0})
        code, _, _ = compare(fresh, base, strict=True)
        assert code == 1

    def test_improvements_and_missing_rows_never_fail(self, tmp_path):
        fresh, base = make_pair(tmp_path,
                                {"x.a": 100.0, "x.gone": 10.0},
                                {"x.a": 20.0, "x.new": 1.0})
        assert compare(fresh, base) == (0, [], [])


class TestAllowlist:
    def test_allowlisted_row_does_not_block(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 900.0})
        code, warns, fails = compare(fresh, base, allowlist=["x.a"])
        assert code == 0 and not fails
        assert warns == [("x.a", 9.0)]     # still surfaced, just not red

    def test_fnmatch_pattern_matches_family(self, tmp_path):
        fresh, base = make_pair(
            tmp_path, {"x.a.b1": 100.0, "y.c": 100.0},
            {"x.a.b1": 900.0, "y.c": 900.0})
        code, _, fails = compare(fresh, base, allowlist=["x.a.*"])
        assert code == 1                   # y.c still blocks
        assert fails == [("y.c", 9.0)]

    def test_allowlist_file_parsing(self, tmp_path):
        p = tmp_path / "ALLOWLIST"
        p.write_text("# comment\n\nx.a   # trailing comment\nread.*\n")
        assert load_allowlist(str(p)) == ["x.a", "read.*"]
        assert load_allowlist(str(tmp_path / "missing")) == []


class TestMedianOfThree:
    """A >4x shot triggers up to two reruns; the median of the three
    ratios decides the blocking verdict (scheduler noise must not block)."""

    def test_noise_spike_downgrades_to_warning(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 450.0})
        shots = iter([{"x.a": 110.0}, {"x.a": 120.0}])
        calls = []

        def rerun(suite):
            calls.append(suite)
            return next(shots)

        code, warns, fails = compare(fresh, base, rerun=rerun)
        assert code == 0 and not fails
        # median of [4.5, 1.1, 1.2] = 1.2 — surfaced, not blocking
        assert warns == [("x.a", 1.2)]
        assert calls == ["x", "x"]

    def test_real_regression_reproduces_and_blocks(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 450.0})
        shots = iter([{"x.a": 460.0}, {"x.a": 440.0}])
        code, warns, fails = compare(fresh, base,
                                     rerun=lambda s: next(shots))
        assert code == 1
        assert fails == [("x.a", 4.5)]   # median of [4.5, 4.6, 4.4]

    def test_warn_band_stays_single_shot(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 250.0})

        def rerun(suite):
            raise AssertionError("2-4x rows must not trigger reruns")

        code, warns, fails = compare(fresh, base, rerun=rerun)
        assert code == 0 and warns == [("x.a", 2.5)]

    def test_allowlisted_row_never_reruns(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 900.0})

        def rerun(suite):
            raise AssertionError("allowlisted rows must not trigger reruns")

        code, _, fails = compare(fresh, base, allowlist=["x.a"],
                                 rerun=rerun)
        assert code == 0 and not fails

    def test_unrunnable_suite_keeps_single_shot_verdict(self, tmp_path):
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 450.0})
        code, _, fails = compare(fresh, base, rerun=lambda s: None)
        assert code == 1
        assert fails == [("x.a", 4.5)]

    def test_reruns_fetched_once_per_suite(self, tmp_path):
        fresh, base = make_pair(tmp_path,
                                {"x.a": 100.0, "x.b": 100.0},
                                {"x.a": 900.0, "x.b": 900.0})
        calls = []

        def rerun(suite):
            calls.append(suite)
            return {"x.a": 880.0, "x.b": 920.0}

        code, _, fails = compare(fresh, base, rerun=rerun)
        assert code == 1 and len(fails) == 2
        assert calls == ["x", "x"]       # two suspect rows, one cached fetch


class TestCheckAllowlist:
    """refresh-baselines gate: stale fnmatch patterns must error."""

    def test_stale_pattern_errors(self, tmp_path, capsys):
        _write_bench(tmp_path / "b", "x", {"x.a": 1.0})
        (tmp_path / "b" / "ALLOWLIST").write_text("x.*\ndead.b1.*\n")
        assert check_allowlist(str(tmp_path / "b")) == 1
        out = capsys.readouterr().out
        assert "::error" in out and "dead.b1.*" in out

    def test_live_patterns_pass(self, tmp_path):
        _write_bench(tmp_path / "b", "x", {"x.a": 1.0, "read.p99": 2.0})
        (tmp_path / "b" / "ALLOWLIST").write_text("x.a\nread.*\n")
        assert check_allowlist(str(tmp_path / "b")) == 0

    def test_empty_allowlist_passes(self, tmp_path):
        _write_bench(tmp_path / "b", "x", {"x.a": 1.0})
        assert check_allowlist(str(tmp_path / "b")) == 0

    def test_cli_mode(self, tmp_path):
        _write_bench(tmp_path / "b", "x", {"x.a": 1.0})
        (tmp_path / "b" / "ALLOWLIST").write_text("gone.*\n")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.compare_baseline",
             "--check-allowlist", "--baselines", str(tmp_path / "b")],
            capture_output=True, text=True)
        assert proc.returncode == 1
        assert "::error" in proc.stdout


class TestCLI:
    def test_module_exit_code_matches(self, tmp_path):
        """The exact invocation CI uses must propagate the failure."""
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 450.0})
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.compare_baseline", fresh,
             "--baselines", base],
            capture_output=True, text=True)
        assert proc.returncode == 1
        assert "::error" in proc.stdout
        # allowlist flips it green
        allow = tmp_path / "ALLOW"
        allow.write_text("x.*\n")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.compare_baseline", fresh,
             "--baselines", base, "--allowlist", str(allow)],
            capture_output=True, text=True)
        assert proc.returncode == 0


class TestStepSummary:
    def test_summary_out_collects_per_suite_stats(self, tmp_path):
        from benchmarks.compare_baseline import render_markdown_summary
        _write_bench(tmp_path / "baselines", "a",
                     {"a.fast": 100.0, "a.slow": 100.0})
        _write_bench(tmp_path / "fresh", "a",
                     {"a.fast": 90.0, "a.slow": 450.0, "a.new": 5.0})
        _write_bench(tmp_path / "baselines", "b", {"b.x": 10.0})
        _write_bench(tmp_path / "fresh", "b", {"b.x": 25.0})
        summary = []
        code, _, _ = compare(str(tmp_path / "fresh"),
                             str(tmp_path / "baselines"),
                             summary_out=summary)
        assert code == 1
        by_suite = {s["suite"]: s for s in summary}
        assert by_suite["a"]["fails"] == 1 and by_suite["a"]["rows"] == 2
        assert by_suite["a"]["worst_row"] == "a.slow"
        assert by_suite["a"]["new_rows"] == 1
        assert by_suite["b"]["warns"] == 1 and by_suite["b"]["fails"] == 0
        md = render_markdown_summary(summary)
        assert "| 🔴 a |" in md and "| 🟡 b |" in md
        assert "`a.slow`" in md and "4.50x" in md

    def test_cli_writes_github_step_summary(self, tmp_path):
        """The exact CI invocation appends the markdown table to the file
        named by $GITHUB_STEP_SUMMARY."""
        import os
        fresh, base = make_pair(tmp_path, {"x.a": 100.0}, {"x.a": 110.0})
        dest = tmp_path / "summary.md"
        env = dict(os.environ, GITHUB_STEP_SUMMARY=str(dest))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.compare_baseline", fresh,
             "--baselines", base, "--no-rerun"],
            capture_output=True, text=True, env=env)
        assert proc.returncode == 0
        text = dest.read_text()
        assert "## Perf smoke vs committed baseline" in text
        assert "| 🟢 x |" in text

    def test_no_env_is_a_noop(self, tmp_path, monkeypatch):
        from benchmarks.compare_baseline import write_step_summary
        monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
        assert write_step_summary([], 2.0, 4.0) is False
