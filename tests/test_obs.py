"""Observability tier-1 tests: registry, histograms, tracer, audit.

Covers the ISSUE 8 surface end to end:
  * MetricsRegistry / MetricsView — dict compatibility (the migration
    contract for every ad-hoc stats dict), incarnation-fold reset
    semantics, gauge providers, snapshot shape;
  * Histogram — log2 bucketing, vectorized observe_array == scalar loop;
  * Obs levels — off is plain dicts, counters has no tracer, full-tier
    histograms (probe depth) stay None below full;
  * EventTracer — ring wrap, export roundtrip, balanced Chrome spans;
  * audit — clean traces pass, each corrupted trace trips exactly its
    invariant, membership edges scope the cleanup;
  * integration — a seeded async-data-plane interleaving (the
    test_async_data_plane schedule) traced at obs_level="full" replays
    through the checker with zero violations, and membership
    drain/rejoin folds counters monotonically.
"""

import json

import numpy as np
import pytest

from repro.obs import (CLUSTER, LEVEL_FULL, EventTracer, Histogram,
                       MetricsRegistry, Obs, StatsDict)
from repro.obs import audit
from repro.obs import trace as T


# ---------------------------------------------------------------------------
# registry + views
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_view_is_dict_compatible(self):
        reg = MetricsRegistry()
        v = reg.view(0, "tlb", ("hits", "misses"))
        v["hits"] += 3
        v["hits"] += 1
        assert v["hits"] == 4 and v["misses"] == 0
        assert v.get("hits") == 4 and v.get("absent", 7) == 7
        assert "hits" in v and "absent" not in v
        assert sorted(v.keys()) == ["hits", "misses"]
        assert dict(v.items()) == {"hits": 4, "misses": 0}
        assert v == {"hits": 4, "misses": 0}
        v.update({"misses": 9}, hits=5)
        assert v.copy() == {"hits": 5, "misses": 9}

    def test_unknown_name_allocates_on_first_touch(self):
        reg = MetricsRegistry()
        v = reg.view(1, "proto")
        v["ad_hoc"] += 2
        assert v["ad_hoc"] == 2
        assert reg.value(1, "proto", "ad_hoc") == 2

    def test_views_share_rows_across_instances(self):
        """Two views over the same (node, subsystem) hit the same storage —
        the wipe-and-replace TLB path depends on this."""
        reg = MetricsRegistry()
        a = reg.view(2, "tlb", ("hits",))
        b = reg.view(2, "tlb", ("hits",))
        a["hits"] += 5
        assert b["hits"] == 5

    def test_reset_node_folds_and_stays_monotonic(self):
        reg = MetricsRegistry()
        v = reg.view(1, "engine", ("steps",))
        other = reg.view(2, "engine", ("steps",))
        v["steps"] += 10
        other["steps"] += 3
        reg.reset_node(1)
        assert v["steps"] == 0                # live restarts per incarnation
        assert v.total("steps") == 10         # cluster total is monotonic
        assert other["steps"] == 3            # other nodes untouched
        assert reg.incarnations == {1: 1}
        v["steps"] += 4
        reg.reset_node(1)
        assert v.total("steps") == 14
        assert reg.total("engine", "steps") == 17
        assert reg.incarnations == {1: 2}

    def test_reset_node_clears_hists_and_gauges(self):
        reg = MetricsRegistry()
        h = reg.histogram(1, "tlb", "probe_depth")
        h.observe(3)
        reg.set_gauge(1, "pool", "free", 5)
        reg.set_gauge(2, "pool", "free", 7)
        reg.reset_node(1)
        assert h.count == 0
        snap = reg.snapshot()
        assert snap["gauges"] == {"pool": {"free.n2": 7.0}}

    def test_gauge_providers_run_lazily_at_snapshot(self):
        reg = MetricsRegistry()
        calls = []
        reg.add_gauge_provider(
            lambda: (calls.append(1),
                     reg.set_gauge(CLUSTER, "pool", "free", len(calls))))
        assert calls == []                    # data path never pays
        snap = reg.snapshot()
        assert calls == [1]
        assert snap["gauges"]["pool"]["free"] == 1.0

    def test_snapshot_shape(self):
        reg = MetricsRegistry()
        reg.view(0, "tlb", ("hits",))["hits"] += 2
        reg.view(CLUSTER, "protocol", ("reads",))["reads"] += 1
        reg.histogram(CLUSTER, "protocol", "batch").observe(4)
        snap = reg.snapshot()
        assert snap["counters"]["tlb"]["hits"] == 2
        assert snap["counters"]["protocol"]["reads"] == 1
        assert snap["nodes"][0]["tlb"]["hits"] == 2
        assert "protocol" not in snap["nodes"].get(0, {})  # cluster row
        assert snap["histograms"]["protocol"]["batch"]["count"] == 1


class TestHistogram:
    def test_log2_buckets_and_percentiles(self):
        h = Histogram()
        for v in (0, 1, 2, 3, 4, 1000):
            h.observe(v)
        s = h.snapshot()
        assert s["count"] == 6 and s["sum"] == 1010
        # bit_length buckets: 0->0, 1->1, {2,3}->2, 4->3, 1000->10
        assert s["buckets"] == {0: 1, 1: 1, 2: 2, 3: 1, 10: 1}
        assert s["p50"] == 3                  # upper bound of bucket 2
        assert h.percentile(1.0) == (1 << 10) - 1
        assert Histogram().percentile(0.5) == 0

    def test_observe_array_matches_scalar_loop(self):
        vals = np.array([0, 1, 2, 3, 7, 8, 255, 256, 10_000, 0, 1])
        ha, hb = Histogram(), Histogram()
        ha.observe_array(vals)
        for v in vals:
            hb.observe(v)
        assert ha.snapshot() == hb.snapshot()
        ha.observe_array(np.array([], np.int64))   # empty batch is a no-op
        assert ha.count == len(vals)

    def test_negative_values_clamp_to_zero(self):
        ha, hb = Histogram(), Histogram()
        ha.observe(-5)
        hb.observe_array(np.array([-5]))
        assert ha.snapshot() == hb.snapshot()
        assert ha.buckets[0] == 1

    def test_reset(self):
        h = Histogram()
        h.observe(9)
        h.reset()
        assert h.count == 0 and h.total == 0 and sum(h.buckets) == 0


class TestObsLevels:
    def test_off_is_plain_dicts(self):
        obs = Obs("off")
        assert obs.registry is None and obs.tracer is None
        v = obs.view(0, "tlb", ("hits",))
        assert isinstance(v, StatsDict) and isinstance(v, dict)
        v["hits"] += 1
        assert v() == {"level": "off"}
        assert obs.histogram(0, "tlb", "probe_depth") is None
        assert obs.snapshot() == {"level": "off"}

    def test_counters_has_registry_but_no_tracer(self):
        obs = Obs("counters")
        assert obs.registry is not None and obs.tracer is None
        assert obs.snapshot()["level"] == "counters"

    def test_full_tier_histograms_gate_below_full(self):
        """Hot-path distributions (TLB probe depth) ride the full tier —
        at counters they must come back None so the <1.1x overhead gate
        holds."""
        at_counters = Obs("counters")
        assert at_counters.histogram(0, "tlb", "probe_depth",
                                     min_level=LEVEL_FULL) is None
        at_full = Obs("full")
        assert at_full.histogram(0, "tlb", "probe_depth",
                                 min_level=LEVEL_FULL) is not None
        assert at_full.tracer is not None

    def test_callable_view_returns_hub_snapshot(self):
        obs = Obs("full", num_nodes=2)
        v = obs.view(0, "cache", ("lookups",))
        v["lookups"] += 1
        snap = v()
        assert snap["level"] == "full"
        assert snap["trace"]["capacity"] == obs.tracer.capacity

    def test_bad_level_rejected(self):
        with pytest.raises(ValueError):
            Obs("verbose")


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestEventTracer:
    def test_emit_and_events_roundtrip(self):
        tr = EventTracer(64)
        tr.emit(T.EV_BIND, 0, 11, 3, 42)
        tr.emit(T.EV_UNBIND, 0, 11, 3, 42)
        evs = tr.events()
        # first 7 fields are the stable layout; the trailing wall-clock
        # microsecond stamp is monotone non-decreasing, not reproducible
        assert [e[:7] for e in evs] == [(0, T.EV_BIND, 0, 11, 3, 42, 0),
                                        (1, T.EV_UNBIND, 0, 11, 3, 42, 0)]
        assert all(len(e) == 8 for e in evs)
        assert 0 <= evs[0][7] <= evs[1][7]
        assert tr.emitted == 2 and tr.dropped == 0

    def test_ring_wrap_keeps_newest_oldest_first(self):
        tr = EventTracer(8)       # pow2 already
        for i in range(20):
            tr.emit(T.EV_BATCH, 0, i)
        assert tr.capacity == 8
        assert tr.dropped == 12
        evs = tr.events()
        assert [e[0] for e in evs] == list(range(12, 20))  # seqs, oldest 1st
        assert [e[3] for e in evs] == list(range(12, 20))

    def test_capacity_rounds_up_to_pow2(self):
        assert EventTracer(100).capacity == 128
        assert EventTracer(1).capacity == 8   # floor

    def test_export_chrome_roundtrip(self, tmp_path):
        tr = EventTracer(64, meta={"num_nodes": 2, "pool_pages": 4})
        tr.emit(T.EV_TBI_BEGIN, 1, 11, 3, 0, 1)
        tr.emit(T.EV_TBI_ACK, 1, 11, 3, 1, 0)
        tr.emit(T.EV_TBI_END, 1, 11, 3, 0)
        tr.emit(T.EV_BIND, 0, 11, 3, 5)
        path = tmp_path / "trace.json"
        doc = tr.export_chrome(str(path))
        on_disk = json.loads(path.read_text())
        assert on_disk["dpcEvents"] == [list(e) for e in tr.events()]
        assert on_disk["dpcMeta"]["pool_pages"] == 4
        # async spans balance: every "b" has its "e" with the same id
        begins = [e for e in doc["traceEvents"] if e["ph"] == "b"]
        ends = [e for e in doc["traceEvents"] if e["ph"] == "e"]
        assert {e["id"] for e in begins} == {e["id"] for e in ends}
        assert begins and begins[0]["name"] == "TBI"
        # instants carry their args; metadata names every pid
        pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] == "M"
                and e["name"] == "process_name"}
        assert pids == {0, 1}


# ---------------------------------------------------------------------------
# audit: each invariant trips on exactly its corruption
# ---------------------------------------------------------------------------


def _ev(seq, kind, node=0, a=0, b=0, c=0, d=0):
    return (seq, kind, node, a, b, c, d)


class TestAudit:
    def test_clean_lifecycle_passes(self):
        events = [
            _ev(0, T.EV_BIND, 0, 11, 0, 5),
            _ev(1, T.EV_WB_REG, 0, 5, 11, 0),
            _ev(2, T.EV_WB_COMMIT, 0, 5),
            _ev(3, T.EV_UNBIND, 0, 11, 0, 5),
            _ev(4, T.EV_FRAME_FREE, 0, 5, 0, 5),
            _ev(5, T.EV_BIND, 1, 11, 0, 9),     # legal re-home
        ]
        assert audit.audit_events(events) == []

    def test_double_bind_is_single_copy_violation(self):
        events = [_ev(0, T.EV_BIND, 0, 11, 0, 5),
                  _ev(1, T.EV_BIND, 1, 11, 0, 9)]   # no unbind between
        (v,) = audit.audit_events(events)
        assert v.rule == "single-copy" and "double-resident" in v.detail
        assert v.seq == 1

    def test_frame_aliasing_is_single_copy_violation(self):
        events = [_ev(0, T.EV_BIND, 0, 11, 0, 5),
                  _ev(1, T.EV_BIND, 0, 11, 1, 5)]   # same pfn, other page
        (v,) = audit.audit_events(events)
        assert v.rule == "single-copy" and "aliased" in v.detail

    def test_free_with_pending_writeback_violates(self):
        events = [_ev(0, T.EV_WB_REG, 2, 7, 11, 0),
                  _ev(1, T.EV_FRAME_FREE, 2, 7, 0, 23)]
        (v,) = audit.audit_events(events)
        assert v.rule == "flush-before-free" and "seq=0" in v.detail

    def test_rebind_with_undelivered_shootdown_violates(self):
        events = [_ev(0, T.EV_SD_POST, 3, 11, 0),
                  _ev(1, T.EV_BIND, 0, 11, 0, 5)]
        (v,) = audit.audit_events(events)
        assert v.rule == "shootdown-before-remap"
        # delivering first makes the same rebind legal...
        ok = [_ev(0, T.EV_SD_POST, 3, 11, 0),
              _ev(1, T.EV_SD_DELIVER, 3, 11, 0),
              _ev(2, T.EV_BIND, 0, 11, 0, 5)]
        assert audit.audit_events(ok) == []
        # ...as do a node wipe and a global flash
        for clear in (_ev(1, T.EV_SD_WIPE, 3), _ev(1, T.EV_SD_FLASH, -1)):
            evs = [_ev(0, T.EV_SD_POST, 3, 11, 0), clear,
                   _ev(2, T.EV_BIND, 0, 11, 0, 5)]
            assert audit.audit_events(evs) == []

    def test_fail_retires_node_frames_and_obligations(self):
        """EV_FAIL drops the dead node's frame range (pool_pages-scoped)
        and its writeback obligations — the frames are gone, not freed,
        so neither re-binding the page elsewhere nor the lost obligation
        is a violation."""
        events = [
            _ev(0, T.EV_BIND, 1, 11, 0, 4 + 1),  # node 1 frame range [4,8)
            _ev(1, T.EV_WB_REG, 1, 1, 11, 0),
            _ev(2, T.EV_FAIL, 1, 0),
            _ev(3, T.EV_BIND, 0, 11, 0, 2),      # re-home, no unbind seen
        ]
        assert audit.audit_events(events, pool_pages=4) == []
        # without the fail edge the same stream is a double-bind
        bad = [events[0], events[3]]
        assert len(audit.audit_events(bad, pool_pages=4)) == 1

    def test_audit_trace_requires_dpc_events(self):
        with pytest.raises(ValueError):
            audit.audit_trace({"traceEvents": []})

    def test_cli_exit_codes(self, tmp_path, capsys):
        tr = EventTracer(64, meta={"pool_pages": 4})
        tr.emit(T.EV_BIND, 0, 11, 0, 5)
        clean = tmp_path / "clean.json"
        tr.export_chrome(str(clean))
        assert audit.main([str(clean)]) == 0
        tr.emit(T.EV_BIND, 1, 11, 0, 9)          # corrupt: double-bind
        bad = tmp_path / "bad.json"
        tr.export_chrome(str(bad))
        assert audit.main([str(bad)]) == 1
        assert audit.main([str(tmp_path / "missing.json")]) == 2
        out = capsys.readouterr().out
        assert "violation" in out


# ---------------------------------------------------------------------------
# integration: live cluster traces replay cleanly; membership folds
# ---------------------------------------------------------------------------


class TestClusterIntegration:
    def test_seeded_interleaving_trace_audits_clean(self, monkeypatch):
        """Trace one of the async-data-plane seeded interleavings (reads,
        writes, reclaim TBI, migrate TBM, pump, failover) at
        obs_level="full" and replay it through the checker."""
        import test_async_data_plane as adp
        captured = []
        orig = adp.make_kv

        def traced_make_kv(*a, **kw):
            kw.setdefault("obs_level", "full")
            kv = orig(*a, **kw)
            captured.append(kv)
            return kv

        monkeypatch.setattr(adp, "make_kv", traced_make_kv)
        adp._run_interleaving(adp._seeded_events(seed=0), async_dp=True)
        (kv,) = captured
        events = kv.obs.tracer.events()
        assert kv.obs.tracer.dropped == 0
        kinds = {e[1] for e in events}
        assert {T.EV_BATCH, T.EV_BIND, T.EV_TBI_BEGIN} <= kinds
        violations = audit.audit_events(
            events, pool_pages=kv.dpc.pool_pages_per_shard)
        assert violations == []

    def test_membership_events_fold_counters_on_rejoin(self):
        """Counter-reset semantics on membership events: per-node live
        counters restart on rejoin (incarnation fold) while cluster
        totals stay monotonic, and membership transitions themselves are
        counted."""
        from repro.runtime.liveness import Membership
        from test_async_data_plane import make_kv

        kv = make_kv(pool_pages=8, storage_backend="memory",
                     writeback_async=False)
        membership = Membership(num_nodes=4)
        membership.attach_obs(kv.obs)
        kv.lookup([7], [0], 2)                # node 2 does some work
        tlb2 = kv.obs.view(2, "tlb", ("misses",))
        before = tlb2["misses"]
        assert before > 0

        membership.drain(2)
        kv.drain_node(2)
        membership.join(2)
        kv.rejoin_node(2)                     # incarnation fold happens here
        assert tlb2["misses"] == 0            # live restarted
        assert tlb2.total("misses") == before  # total monotonic
        snap = kv.stats()
        assert snap["incarnations"] == {2: 1}
        mem = snap["counters"]["membership"]
        assert mem["drains"] == 1 and mem["joins"] == 1
        assert mem["epoch"] == membership.epoch
        kv.close()
