"""Substrate tests: optimizer, compression, checkpoint/restore, data
pipeline + host DPC cache, liveness/elasticity/stragglers, coherence modes,
serving engine integration."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_arch
from repro.configs.base import (DPCConfig, MeshConfig, RunConfig,
                                ShapeConfig, ShardingConfig)
from repro.core.dpc_cache import DistributedKVCache
from repro.data import pipeline as dpipe
from repro.models import registry
from repro.models.spec import init_params
from repro.optim import adamw, compression
from repro.runtime import liveness
from repro.training import train_step as tst


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


class TestAdamW:
    def test_bias_correction_first_step(self):
        cfg = adamw.AdamWConfig(learning_rate=1e-2, warmup_steps=0,
                                weight_decay=0.0, grad_clip=1e9,
                                schedule="constant")
        params = {"w": jnp.ones((4, 4))}
        state = adamw.init(params, cfg)
        grads = {"w": jnp.full((4, 4), 0.5)}
        new_p, state, m = adamw.update(grads, state, params, cfg)
        # first Adam step moves by ~lr regardless of grad scale
        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   1.0 - 1e-2, rtol=1e-4)

    def test_moment_dtype_bf16(self):
        cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.ones((8,))}
        state = adamw.init(params, cfg)
        assert state.mu["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        g = {"w": jnp.full((10,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        np.testing.assert_allclose(
            float(adamw.global_norm(clipped)), 1.0, rtol=1e-5)


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        """With EF, the accumulated compression error stays bounded and the
        mean reconstructed gradient converges to the true mean."""
        rng = np.random.RandomState(0)
        g_true = jnp.asarray(rng.randn(256) * 0.1, jnp.float32)
        ef = jnp.zeros((256,), jnp.float32)
        acc = jnp.zeros((256,), jnp.float32)
        for _ in range(50):
            q, s, ef = compression.ef_compress(g_true, ef)
            acc = acc + compression.dequantize_int8(q, s)
        np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                                   atol=2e-3)

    def test_quantize_roundtrip_bound(self):
        x = jnp.linspace(-3, 3, 1000)
        q, s = compression.quantize_int8(x)
        err = np.abs(np.asarray(compression.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        state = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3))}}
        cm.save(100, state, extra={"data": {"cursor": 7}}, blocking=True)
        got, extra = cm.restore(100, state)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(state["a"]))
        assert extra["data"]["cursor"] == 7

    def test_gc_keeps_latest(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        state = {"a": jnp.zeros(4)}
        for step in (1, 2, 3, 4):
            cm.save(step, state, blocking=True)
        assert cm.latest_step() == 4
        assert sorted(cm._complete_steps()) == [3, 4]

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        state = {"a": jnp.zeros(4)}
        cm.save(5, state, blocking=True)
        # fake a crashed write
        os.makedirs(tmp_path / "step_00000009", exist_ok=True)
        assert cm.latest_step() == 5

    def test_train_restart_resumes_identically(self, tmp_path):
        """Train 6 steps straight vs 3 + checkpoint + restore + 3: same loss."""
        cfg = get_smoke_arch("qwen3-1.7b")
        api = registry.get_model(cfg)
        run = RunConfig(arch=cfg, shape=ShapeConfig("t", 16, 4, "train"),
                        mesh=MeshConfig((1,), ("data",)),
                        sharding=ShardingConfig(remat="none"),
                        warmup_steps=1)
        ocfg = tst.adamw_config(run, total_steps=10)
        step = jax.jit(tst.make_train_step(run, api, n_micro=1, ocfg=ocfg))
        batch = registry.make_train_batch(cfg, 4, 16, jax.random.PRNGKey(1))

        s1 = tst.init_train_state(run, api, jax.random.PRNGKey(0), ocfg=ocfg)
        for _ in range(6):
            s1, m1 = step(s1, batch)

        s2 = tst.init_train_state(run, api, jax.random.PRNGKey(0), ocfg=ocfg)
        cm = CheckpointManager(str(tmp_path))
        for _ in range(3):
            s2, _ = step(s2, batch)
        cm.save(3, s2, blocking=True)
        s2_restored, _ = cm.restore(3, s2)
        for _ in range(3):
            s2_restored, m2 = step(s2_restored, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline + host-tier DPC
# ---------------------------------------------------------------------------


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = dpipe.DataConfig(vocab_size=100, seq_len=8, global_batch=4,
                               num_shards=4, shard_tokens=1024)
        p1 = dpipe.TokenPipeline(cfg, 0, 1)
        b1 = [p1.next_batch() for _ in range(3)]
        state = p1.state_dict()
        b_next = p1.next_batch()

        p2 = dpipe.TokenPipeline(cfg, 0, 1)
        p2.load_state_dict(state)
        b_resumed = p2.next_batch()
        np.testing.assert_array_equal(b_next["tokens"], b_resumed["tokens"])

    def test_host_cache_single_copy_and_remote_hits(self):
        cfg = dpipe.DataConfig(vocab_size=100, seq_len=8, global_batch=4,
                               num_shards=4, shard_tokens=1024)
        cache = dpipe.HostShardCache(cfg, num_ranks=2, capacity_per_rank=4)
        p0 = dpipe.TokenPipeline(cfg, 0, 2, cache)
        p1 = dpipe.TokenPipeline(cfg, 1, 2, cache)
        for _ in range(8):
            p0.next_batch()
            p1.next_batch()
        # shards fetched from storage at most once each (single copy);
        # the other rank's accesses become remote hits
        assert cache.store.fetches <= cfg.num_shards
        assert cache.hits_remote > 0
        cache.dir.check_invariants()

    def test_ranks_see_disjoint_streams(self):
        cfg = dpipe.DataConfig(vocab_size=100, seq_len=8, global_batch=4,
                               num_shards=2, shard_tokens=4096)
        cache = dpipe.HostShardCache(cfg, num_ranks=2)
        p0 = dpipe.TokenPipeline(cfg, 0, 2, cache)
        p1 = dpipe.TokenPipeline(cfg, 1, 2, cache)
        b0, b1 = p0.next_batch(), p1.next_batch()
        assert not np.array_equal(b0["tokens"], b1["tokens"])


# ---------------------------------------------------------------------------
# liveness / elasticity / stragglers
# ---------------------------------------------------------------------------


class TestRuntime:
    def test_membership_failure_detection(self):
        t = [0.0]
        mem = liveness.Membership(4, timeout_s=10, clock=lambda: t[0])
        t[0] = 5.0
        mem.heartbeat(0), mem.heartbeat(1), mem.heartbeat(2)
        t[0] = 12.0
        failed = mem.check()
        assert failed == [3]
        assert mem.epoch == 1 and 3 not in mem.alive

    def test_elastic_mesh_shrinks_data_axis(self):
        assert liveness.elastic_mesh_shape(256, 16) == (16, 16)
        assert liveness.elastic_mesh_shape(240, 16) == (15, 16)
        assert liveness.elastic_mesh_shape(512, 16, pods=2) == (2, 16, 16)
        assert liveness.elastic_mesh_shape(8, 16) is None

    def test_straggler_watchdog_flags_repeat_offender(self):
        wd = liveness.StragglerWatchdog(factor=2.0, strikes=2)
        wd.observe(1.0)
        assert wd.observe(1.1, slowest_node=5) is None
        assert wd.observe(5.0, slowest_node=7) is None   # strike 1
        assert wd.observe(5.0, slowest_node=7) == 7      # strike 2 -> flag

    def test_directory_guard_falls_back_local(self):
        t = [0.0]
        g = liveness.DirectoryClientGuard(timeout_s=5, clock=lambda: t[0])
        assert g.check() == "dpc"
        t[0] = 6.0
        assert g.check() == "local_only"

    def test_failed_node_pages_lost_then_refilled(self):
        """Paper §5: losing a node only shrinks the cache; pages refill."""
        dpc = DPCConfig(page_size=8, pool_pages_per_shard=32)
        kv = DistributedKVCache(dpc, 4)
        lks = kv.lookup([1, 1], [0, 1], node=3)
        kv.commit([1, 1], [0, 1], 3, lks)
        assert kv.directory_occupancy() == 2
        lost = kv.fail_node(3)
        assert lost == 2 and kv.directory_occupancy() == 0
        lks = kv.lookup([1, 1], [0, 1], node=0)   # refill on another node
        assert all(lk.needs_fill for lk in lks)


# ---------------------------------------------------------------------------
# serving engine end-to-end (prefix reuse across engines via shared cache)
# ---------------------------------------------------------------------------


class TestServingIntegration:
    def test_cross_replica_prefix_reuse(self):
        from repro.serving.engine import ServingEngine
        cfg = get_smoke_arch("granite-3-2b")
        api = registry.get_model(cfg)
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
        run = RunConfig(arch=cfg, shape=ShapeConfig("s", 64, 4, "decode"),
                        mesh=MeshConfig((1,), ("data",)),
                        dpc=DPCConfig(page_size=8, pool_pages_per_shard=128))
        kv = DistributedKVCache(run.dpc, 2)
        e0 = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                           node=0, num_nodes=2, kv_cache=kv)
        e1 = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                           node=1, num_nodes=2, kv_cache=kv)
        prompt = list(range(7, 31))  # 3 full pages
        e0.submit(prompt, max_new_tokens=2)
        for _ in range(20):
            if e0.step() == 0:
                break
        # replica 1 reads the same prompt: its pages hit REMOTELY via DPC
        e1.submit(prompt, max_new_tokens=2)
        for _ in range(20):
            if e1.step() == 0:
                break
        assert e1.prefix_stats.pages_remote >= 3
        assert e1.prefix_stats.prefill_tokens_saved >= 24

    def test_cached_prefix_generations_identical(self):
        """Cold prefill vs cached-prefix tail-decode admission must produce
        byte-identical greedy generations (and actually skip prefill)."""
        from repro.serving.engine import ServingEngine
        cfg = get_smoke_arch("granite-3-2b")
        api = registry.get_model(cfg)
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
        run = RunConfig(arch=cfg, shape=ShapeConfig("s", 64, 4, "decode"),
                        mesh=MeshConfig((1,), ("data",)),
                        dpc=DPCConfig(page_size=8, pool_pages_per_shard=128))
        eng = ServingEngine(run, params, max_batch=4, max_pages_per_seq=10)
        prompt = list(range(40, 64))
        outs = []
        for _ in range(2):
            rid = eng.submit(prompt, max_new_tokens=5)
            req = None
            while True:
                for r in eng.active:
                    if r is not None and r.rid == rid:
                        req = r
                if eng.step() == 0:
                    break
            outs.append(tuple(req.generated))
        assert outs[0] == outs[1]
        assert eng.prefix_stats.prefill_tokens_saved >= 24

    def test_local_only_mode_never_shares(self):
        from repro.serving.engine import ServingEngine
        cfg = get_smoke_arch("granite-3-2b")
        api = registry.get_model(cfg)
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
        run = RunConfig(arch=cfg, shape=ShapeConfig("s", 64, 4, "decode"),
                        mesh=MeshConfig((1,), ("data",)),
                        dpc=DPCConfig(mode="local_only", page_size=8,
                                      pool_pages_per_shard=128))
        eng = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8)
        prompt = list(range(7, 31))
        for _ in range(2):
            eng.submit(prompt, max_new_tokens=2)
            for _ in range(20):
                if eng.step() == 0:
                    break
        assert eng.prefix_stats.pages_local == 0 and eng.prefix_stats.pages_remote == 0
