"""Durable backing-store + async writeback subsystem (repro/storage).

Covers the storage tier bottom-up: BackingStore implementations (staged vs
durable, crash simulation, extent-file persistence), the WritebackQueue
(FIFO batching, coalescing, epoch barriers, per-stream fsync, read-your-
writes peeks), the protocol integration (flush-before-free, dirty-bit
oracle agreement, migration writeback), the cache-level evict -> refault
loop (the acceptance test: a dirty page evicted under memory pressure and
re-read returns its last-written bytes), and the serving engine end-to-end
(evicted KV pages refill from storage with identical generations).
"""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_arch
from repro.configs.base import DPCConfig, MeshConfig, RunConfig, ShapeConfig
from repro.core import descriptors as D
from repro.core import pagepool as pp
from repro.core.dpc_cache import DistributedKVCache
from repro.models import registry
from repro.models.spec import init_params
from repro.storage import (FileBackingStore, MemoryBackingStore,
                           WritebackConfig, WritebackQueue)


def page(v, n=8):
    return np.full((n,), v, np.float32)


# ---------------------------------------------------------------------------
# BackingStore implementations
# ---------------------------------------------------------------------------


class TestMemoryStore:
    def test_roundtrip_and_staging(self):
        st = MemoryBackingStore()
        assert st.read(1, 0) is None
        st.write(1, 0, page(7))
        np.testing.assert_array_equal(st.read(1, 0), page(7))  # staged read
        assert st.stats["bytes_written"] == 0   # not durable yet
        st.sync()
        assert st.stats["bytes_written"] == page(7).nbytes

    def test_crash_drops_unsynced_writes_only(self):
        st = MemoryBackingStore()
        st.write(1, 0, page(1))
        st.sync()
        st.write(1, 0, page(2))   # staged overwrite
        st.write(1, 1, page(3))
        st.crash()
        np.testing.assert_array_equal(st.read(1, 0), page(1))
        assert st.read(1, 1) is None

    def test_copies_are_isolated(self):
        st = MemoryBackingStore()
        src = page(5)
        st.write(1, 0, src)
        src[:] = 99
        got = st.read(1, 0)
        np.testing.assert_array_equal(got, page(5))
        got[:] = 42
        np.testing.assert_array_equal(st.read(1, 0), page(5))


class TestFileStore:
    def test_roundtrip_and_persistence(self, tmp_path):
        st = FileBackingStore(str(tmp_path), extent_pages=4)
        st.write(3, 0, page(1))
        st.write(3, 5, page(2))   # second extent
        st.sync()
        assert st.extent_files() == 2
        # a fresh instance sees only what was synced
        st2 = FileBackingStore(str(tmp_path), extent_pages=4)
        np.testing.assert_array_equal(st2.read(3, 0), page(1))
        np.testing.assert_array_equal(st2.read(3, 5), page(2))
        assert st2.read(3, 1) is None   # present extent, absent page

    def test_extent_write_amplification_is_visible(self, tmp_path):
        st = FileBackingStore(str(tmp_path), extent_pages=8)
        st.write(1, 0, page(1))
        st.sync()
        # one dirty page cost a whole extent rewrite
        assert st.stats["bytes_written"] >= 8 * page(1).nbytes

    def test_crash_reverts_to_last_sync(self, tmp_path):
        st = FileBackingStore(str(tmp_path), extent_pages=4)
        st.write(1, 0, page(1))
        st.sync()
        st.write(1, 0, page(2))
        st.crash()
        np.testing.assert_array_equal(st.read(1, 0), page(1))

    def test_extent_shape_is_enforced(self, tmp_path):
        st = FileBackingStore(str(tmp_path), extent_pages=4)
        st.write(1, 0, page(1, n=8))
        with pytest.raises(ValueError):
            st.write(1, 1, page(1, n=16))


# ---------------------------------------------------------------------------
# WritebackQueue
# ---------------------------------------------------------------------------


def sync_queue(store=None, **kw):
    kw.setdefault("async_mode", False)
    if store is None:   # NB: `store or ...` would misfire — empty stores
        store = MemoryBackingStore()   # have len() == 0 and are falsy
    return WritebackQueue(store, WritebackConfig(**kw))


class TestWritebackQueue:
    def test_batched_flush_and_counts(self):
        q = sync_queue(batch_size=4)
        for i in range(10):
            q.enqueue((1, i), page(i))
        assert q.pending_count() == 10
        q.pump(max_batches=1)
        assert q.pending_count() == 6 and q.stats["batches"] == 1
        q.flush_barrier()
        assert q.pending_count() == 0
        assert q.stats["flushed_pages"] == 10
        assert q.store.stats["syncs"] == q.stats["batches"]

    def test_fifo_prefix_ordering_under_crash(self):
        """The durable image is always a prefix of the enqueue order: a
        crash can never surface obligation N+1 without obligation N."""
        store = MemoryBackingStore()
        q = sync_queue(store, batch_size=3)
        for i in range(7):
            q.enqueue((1, i), page(i))
        q.pump(max_batches=2)     # 6 durable, 1 staged-never-written
        store.crash()
        seen = [i for i in range(7) if store.read(1, i) is not None]
        assert seen == list(range(6))

    def test_coalescing_rewrites_same_key(self):
        q = sync_queue(batch_size=64)
        q.enqueue((1, 0), page(1))
        q.enqueue((1, 0), page(2))
        assert q.pending_count() == 1 and q.stats["coalesced"] == 1
        q.flush_barrier()
        np.testing.assert_array_equal(q.store.read(1, 0), page(2))

    def test_tokened_obligations_never_coalesce(self):
        q = sync_queue(batch_size=64)
        q.enqueue((1, 0), page(1), token=(0, 3))
        q.enqueue((1, 0), page(2), token=(0, 9))
        assert q.pending_count() == 2
        q.flush_barrier()
        assert sorted(t for t, _ in q.drain_completions()) == [(0, 3), (0, 9)]

    def test_peek_serves_read_your_writes(self):
        q = sync_queue(batch_size=64)
        assert q.peek((1, 0)) is None
        q.enqueue((1, 0), page(5))
        np.testing.assert_array_equal(q.peek((1, 0)), page(5))
        q.flush_barrier()
        assert q.peek((1, 0)) is None            # durable now: read the store
        np.testing.assert_array_equal(q.store.read(1, 0), page(5))

    def test_epoch_barrier_orders_prefix_only(self):
        q = sync_queue(batch_size=1)
        q.enqueue((1, 0), page(1))
        e = q.advance_epoch()
        q.enqueue((1, 1), page(2))
        q.flush_barrier(upto_epoch=e - 1)
        # the barrier only owes epochs <= e-1; later epochs may still pend
        assert q.store.read(1, 0) is not None

    def test_fsync_stream_is_per_stream(self):
        q = sync_queue(batch_size=1)
        q.enqueue((7, 0), page(1))
        q.enqueue((8, 0), page(2))
        q.fsync_stream(7)
        assert not q.has_pending_stream(7)
        np.testing.assert_array_equal(q.store.read(7, 0), page(1))

    def test_async_flusher_drains_in_background(self):
        q = WritebackQueue(MemoryBackingStore(), WritebackConfig(
            batch_size=4, flush_interval_s=0.001, async_mode=True))
        try:
            for i in range(16):
                q.enqueue((1, i), page(i))
            q.flush_barrier(timeout=10.0)
            assert q.pending_count() == 0
            assert q.stats["batches"] >= 1
        finally:
            q.close()

    def test_flush_failure_redrives_the_batch(self):
        """A store.sync failure must not wedge the pipeline: the batch is
        un-marked and the next flush re-drives it."""
        store = MemoryBackingStore()
        fail = {"on": True}
        real_sync = store.sync

        def flaky_sync():
            if fail["on"]:
                raise OSError("disk full")
            real_sync()

        store.sync = flaky_sync
        q = sync_queue(store, batch_size=4)
        q.enqueue((1, 0), page(1), token=(0, 0))
        with pytest.raises(OSError):
            q.pump()
        assert q.pending_count() == 1 and q.stats["flush_errors"] == 1
        fail["on"] = False
        q.flush_barrier()
        assert q.pending_count() == 0
        assert [t for t, _ in q.drain_completions()] == [(0, 0)]

    def test_write_amplification_metric(self):
        store = FileBackingStore(extent_pages=8)
        try:
            q = sync_queue(store, batch_size=64)
            for i in range(2):             # 2 dirty pages in an 8-page extent
                q.enqueue((1, i), page(i))
            q.flush_barrier()
            assert q.write_amplification() >= 3.5   # ~8/2 x (+ mask bytes)
        finally:
            store.close()   # self-created temp root


# ---------------------------------------------------------------------------
# protocol integration: flush-before-free + oracle + migration writeback
# ---------------------------------------------------------------------------


def make_cache(pool_pages=4, nodes=2, **dpc_kw):
    dpc_kw.setdefault("storage_backend", "memory")
    dpc_kw.setdefault("writeback_async", False)
    dpc_kw.setdefault("shadow_oracle", True)
    dpc_kw.setdefault("migrate_threshold", 0)   # manual migration only
    dpc = DPCConfig(page_size=4, pool_pages_per_shard=pool_pages, **dpc_kw)
    kv = DistributedKVCache(dpc, nodes)
    frames = {}
    kv.set_page_bytes_fn(lambda key, pfn: frames.get(pfn))
    return kv, frames


def fill(kv, frames, streams, node=0, value_of=lambda s: s):
    lks = kv.lookup(streams, [0] * len(streams), node)
    for s, lk in zip(streams, lks):
        assert lk.status == D.ST_GRANT_E
        frames[lk.page_id] = page(value_of(s))
    kv.commit(streams, [0] * len(streams), node, lks)
    return lks


class TestProtocolWriteback:
    def test_dirty_eviction_pins_frame_until_flush(self):
        kv, frames = make_cache()
        fill(kv, frames, [1, 2, 3, 4])
        proto = kv.proto
        freed, wb = proto.reclaim_sync(0, want=2)
        assert freed == 2 and wb == 2
        # frames are NOT reusable yet: pinned in S_WRITEBACK
        pool = proto.state.pools[0]
        assert int(pp.num_writeback(pool)) == 2
        assert int(pool.free_top) == 0
        assert proto.counters["writebacks_committed"] == 0
        # the flush barrier commits the batch and releases the frames
        released = proto.flush()
        assert released == 2
        pool = proto.state.pools[0]
        assert int(pp.num_writeback(pool)) == 0 and int(pool.free_top) == 2
        assert proto.counters["flush_before_free_violations"] == 0
        assert proto.counters["oracle_mismatches"] == 0

    def test_clean_pages_keep_the_fast_path(self):
        kv, frames = make_cache(storage_backend="memory")
        # commit clean (override): eviction must free immediately, no queue
        lks = kv.lookup([1, 2], [0, 0], 0)
        for lk in lks:
            frames[lk.page_id] = page(0)
        kv.commit([1, 2], [0, 0], 0, lks, dirty=False)
        freed, wb = kv.proto.reclaim_sync(0, want=2)
        assert freed == 2 and wb == 0
        assert kv.writeback.stats["enqueued"] == 0
        assert int(kv.proto.state.pools[0].free_top) == 4

    def test_reclaim_under_pressure_pumps_without_barrier(self):
        kv, frames = make_cache()
        fill(kv, frames, [1, 2, 3, 4])
        # sync-mode pump satisfies the pressure inline: frames come back
        # free with no blocking full-queue barrier
        freed = kv.reclaim(0, 2)
        assert freed == 2
        assert int(kv.proto.state.pools[0].free_top) == 2
        assert kv.stats["sync_flushes"] == 0

    def test_reclaim_under_pressure_falls_back_to_barrier(self):
        # async queue whose flusher sleeps a long interval: pump harvests
        # nothing, so reclaim must run the barrier (which expedites the
        # flusher) before the retry can succeed
        kv, frames = make_cache(writeback_async=True,
                                writeback_interval_s=5.0)
        try:
            fill(kv, frames, [1, 2, 3, 4])
            freed = kv.reclaim(0, 2)
            assert freed == 2
            assert int(kv.proto.state.pools[0].free_top) == 2
            assert kv.stats["sync_flushes"] == 1
        finally:
            kv.close()

    def test_migration_of_dirty_page_writes_back(self):
        kv, frames = make_cache(pool_pages=4)
        fill(kv, frames, [5])
        proto = kv.proto

        def copy(key, src_pfn, dst_pfn):
            frames[dst_pfn] = frames[src_pfn]

        moved = proto.migrate_sync([((5, 0), 1)], copy_fn=copy)
        assert len(moved) == 1
        proto.fence_data_lanes()   # checkpoint rides a COPY lane
        assert proto.counters["migration_writebacks"] == 1
        # source frame pinned until the flush commits
        assert int(pp.num_writeback(proto.state.pools[0])) == 1
        proto.flush()
        assert int(pp.num_writeback(proto.state.pools[0])) == 0
        assert int(proto.state.pools[0].free_top) == 4
        # the moved page is durable: bytes survive in the store
        np.testing.assert_array_equal(kv.store.read(5, 0), page(5))
        assert proto.counters["oracle_mismatches"] == 0

    def test_oracle_divergence_fails_loudly(self):
        """Corrupt the oracle's dirty bookkeeping: the next completed
        invalidation must raise, not silently disagree."""
        kv, frames = make_cache()
        fill(kv, frames, [1])
        # register the buffered write-grant dirty bit first, or the flush at
        # reclaim_begin would re-dirty the oracle and undo the sabotage
        kv.proto.flush_dirty_marks()
        kv.proto.oracle.entries[(1, 0)].dirty = False    # sabotage
        kv.proto.oracle.entries[(1, 0)].inv_dirty = False
        with pytest.raises(AssertionError, match="divergence"):
            kv.proto.reclaim_sync(0, want=1)


# ---------------------------------------------------------------------------
# acceptance: evict -> refault returns last-written bytes
# ---------------------------------------------------------------------------


class TestRefaultLoop:
    @pytest.mark.parametrize("backend", ["memory", "file"])
    def test_dirty_evicted_page_refills_with_last_written_bytes(
            self, backend, tmp_path):
        kv, frames = make_cache(storage_backend=backend,
                                storage_dir=str(tmp_path))
        streams = [1, 2, 3, 4]
        fill(kv, frames, streams, value_of=lambda s: 10 * s)
        # memory pressure: evict everything (all dirty -> all written back)
        kv.reclaim(0, want=4)
        assert kv.directory_occupancy() == 0
        # refault on the OTHER node: every page must come back as a grant
        # carrying its last-written bytes from the backing store
        lks = kv.lookup(streams, [0] * 4, 1)
        for s, lk in zip(streams, lks):
            assert lk.status == D.ST_GRANT_E and lk.needs_fill
            assert lk.refill is not None, f"page ({s},0) lost its bytes"
            np.testing.assert_array_equal(lk.refill, page(10 * s))
        assert kv.stats["refills"] == 4
        # refilled pages commit clean: re-evicting them is free
        for lk in lks:
            frames[lk.page_id] = lk.refill
        kv.commit(streams, [0] * 4, 1, lks)
        _, wb = kv.proto.reclaim_sync(1, want=4)
        assert wb == 0
        assert kv.proto.counters["flush_before_free_violations"] == 0

    def test_refault_before_flush_reads_pending_copy(self):
        """Read-your-writes: a refault racing the flush must see the queued
        bytes, not the stale durable image."""
        kv, frames = make_cache()
        fill(kv, frames, [1, 2, 3, 4], value_of=lambda s: 100 + s)
        kv.proto.reclaim_sync(0, want=2)     # obligations pending, unflushed
        # the byte captures ride FLUSH lanes; settle them into the queue
        # (the flush itself is still pending — that's the race under test)
        kv.proto.fence_data_lanes()
        assert kv.writeback.pending_count() == 2
        evicted = [s for s in [1, 2, 3, 4]
                   if (s, 0) not in kv.proto.directory_view()]
        lk = kv.lookup([evicted[0]], [0], 1)[0]
        np.testing.assert_array_equal(lk.refill, page(100 + evicted[0]))


# ---------------------------------------------------------------------------
# serving engine end-to-end: evicted KV pages refill from storage
# ---------------------------------------------------------------------------


class TestEngineStorage:
    def test_evicted_kv_pages_refill_and_generations_match(self):
        from repro.serving.engine import ServingEngine
        cfg = get_smoke_arch("granite-3-2b")
        api = registry.get_model(cfg)
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
        run = RunConfig(arch=cfg, shape=ShapeConfig("s", 64, 4, "decode"),
                        mesh=MeshConfig((1,), ("data",)),
                        dpc=DPCConfig(page_size=8, pool_pages_per_shard=64,
                                      storage_backend="memory",
                                      writeback_async=False,
                                      shadow_oracle=True))
        eng = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8)
        prompt = list(range(11, 35))   # 3 full pages

        def run_one():
            """Drive one request to completion; return its generation."""
            eng.submit(prompt, max_new_tokens=4)
            req = None
            for _ in range(30):
                for r in eng.active:
                    if r is not None:
                        req = r
                if eng.step() == 0:
                    break
            return list(req.generated)

        gen_cold = run_one()

        # force-evict every page (memory pressure), flush to storage
        kv = eng.kv
        kv.reclaim(0, want=64)
        assert kv.proto.counters["writebacks"] >= 3
        assert kv.writeback.pending_count() == 0   # sync-flush fallback ran

        # resubmit the same prompt: its pages refault from the store
        gen_refilled = run_one()
        assert eng.prefix_stats.pages_refilled >= 3
        assert gen_cold == gen_refilled, \
            "refilled KV must reproduce generations"
        assert kv.proto.counters["flush_before_free_violations"] == 0
        assert kv.proto.counters["oracle_mismatches"] == 0
