"""Distributed datapath correctness: runs spmd_check.py in a subprocess with
8 virtual CPU devices (the device-count flag must precede jax init)."""

import os
import subprocess
import sys

import pytest

# whole-module tier-2: each test boots a subprocess JAX with 8 host devices
pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_script(name, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), os.path.join(ROOT, "tests"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    return proc


def test_spmd_datapaths_match_local_oracle():
    proc = run_script("spmd_check.py")
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-2000:]}"
    assert "ALL_OK" in proc.stdout
    # every individual check line must be OK
    for line in proc.stdout.splitlines():
        if line.startswith("FAIL"):
            pytest.fail(line)


def test_elastic_remesh_checkpoint_restart():
    """Node failure -> epoch bump -> smaller mesh -> restore -> continue."""
    proc = run_script("spmd_elastic.py")
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-2000:]}"
    assert "ELASTIC_OK" in proc.stdout
