"""Cluster prefix tree + predictive promotion tests.

Three layers, mirroring the feature's stack:
  * ``prefix_index.page_keys`` edge cases — partial trailing pages stay
    private, ``modality_salt`` separates identical token streams, and the
    chain hash is stable across page-size boundaries (a prefix's keys
    never depend on what comes after it).
  * ``ClusterPrefixTree`` structure — insert/match/heat, shard placement
    follows the directory's ``dir_shard_of``, capacity pruning drops the
    coldest leaves, non-root-anchored paths are refused.
  * the promotion path — the ``map_shared`` directory op (promotion never
    claims or installs), ``promote_pages`` protocol lockstep with the
    shadow oracle, and the engine-level predict-then-admit flow including
    the per-node-index ablation.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core.dpc_cache import DistributedKVCache, dir_shard_of
from repro.serving import prefix_index
from repro.serving.prefix_tree import ClusterPrefixTree

PAGE = 8


# ---------------------------------------------------------------------------
# page_keys edge cases (satellite: the stateless key layer under the tree)
# ---------------------------------------------------------------------------


class TestPageKeys:
    def test_partial_trailing_page_stays_private(self):
        """Two prompts sharing 2 full pages plus an identical *partial*
        third page share exactly 2 pages — the partial page's key exists
        (the engine needs a key to alloc under) but never counts as
        shared."""
        base = list(range(100, 100 + 2 * PAGE + 3))   # 2 full + 3 tokens
        other = list(base)
        ka = prefix_index.page_keys(base, PAGE)
        kb = prefix_index.page_keys(other, PAGE)
        assert len(ka) == 3 and ka == kb              # same keys, even partial
        assert prefix_index.shared_page_count(base, other, PAGE) == 2

    def test_partial_page_key_differs_from_full(self):
        """A partial page's hash covers fewer tokens than the full page at
        the same index, so it can never collide with the full-page key."""
        full = list(range(2 * PAGE))
        cut = full[:PAGE + 3]
        k_full = prefix_index.page_keys(full, PAGE)
        k_cut = prefix_index.page_keys(cut, PAGE)
        assert k_full[0] == k_cut[0]
        assert k_full[1] != k_cut[1]

    def test_modality_salt_separates_identical_streams(self):
        """The same token ids under different salts (text vs. audio
        codebooks, or the per-node ablation) must resolve to disjoint key
        spaces — every page key differs."""
        toks = list(range(3 * PAGE))
        a = prefix_index.page_keys(toks, PAGE, modality_salt=0)
        b = prefix_index.page_keys(toks, PAGE, modality_salt=1)
        assert all(ka[0] != kb[0] for ka, kb in zip(a, b))
        assert [k[1] for k in a] == [k[1] for k in b]  # indices unchanged

    def test_chain_hash_stable_across_page_boundaries(self):
        """Keys are prefix-closed: truncating a prompt at any full-page
        boundary yields exactly the leading keys of the longer prompt.
        This is what lets the tree match a queued prompt against paths
        other requests committed."""
        rng = np.random.RandomState(0)
        toks = rng.randint(0, 1 << 20, 5 * PAGE + 5).tolist()
        whole = prefix_index.page_keys(toks, PAGE)
        for k in range(1, 6):
            cut = prefix_index.page_keys(toks[:k * PAGE], PAGE)
            assert cut == whole[:k]

    def test_different_page_size_different_keys(self):
        """The page size participates in the chunking, so the same stream
        paged differently must not alias (page 0 of size 8 covers other
        tokens than page 0 of size 16)."""
        toks = list(range(32))
        k8 = prefix_index.page_keys(toks, 8)
        k16 = prefix_index.page_keys(toks, 16)
        assert k8[0][0] != k16[0][0]


# ---------------------------------------------------------------------------
# tree structure
# ---------------------------------------------------------------------------


def keys_for(tokens, salt=0):
    return prefix_index.page_keys(tokens, PAGE, modality_salt=salt)


class TestClusterPrefixTree:
    def test_insert_then_match_longest_path(self):
        tree = ClusterPrefixTree()
        hot = list(range(4 * PAGE))
        tree.insert(keys_for(hot), node_id=0)
        # a prompt sharing 2 pages then diverging matches exactly 2
        fork = hot[:2 * PAGE] + [999] * (2 * PAGE)
        m = tree.match(keys_for(fork), node_id=1)
        assert m == keys_for(hot)[:2]
        # the full path matches everything
        assert tree.match(keys_for(hot)) == keys_for(hot)
        assert tree.predicted_tail(keys_for(hot)) == keys_for(hot)[1:]

    def test_match_heats_edges_for_requester(self):
        tree = ClusterPrefixTree()
        hot = list(range(2 * PAGE))
        tree.insert(keys_for(hot), node_id=0)
        tree.match(keys_for(hot), node_id=3, weight=2)
        root = tree.roots[keys_for(hot)[0][0]]
        assert root.hot[3] == 2 and root.hot[0] == 1
        assert root.hottest() == (3, 2)
        tree.decay()
        assert root.hot == {3: 1}      # 0's count halved to zero

    def test_non_root_anchored_path_refused(self):
        """Keys must start at page 0 and be contiguous — a mid-prompt
        fragment would let a partial page masquerade as shareable."""
        tree = ClusterPrefixTree()
        ks = keys_for(list(range(3 * PAGE)))
        assert tree.insert(ks[1:], node_id=0) == 0     # starts at page 1
        assert tree.size == 0
        assert tree.insert([ks[0], ks[2]], node_id=0) == 1  # gap: stops at 0
        assert tree.size == 1

    def test_shard_placement_matches_directory(self):
        """Tree nodes are bucketed by the directory's shard placement, so
        the prediction metadata for a page lives with its directory
        entry."""
        dpc = DPCConfig(page_size=PAGE, directory_capacity=256,
                        directory_placement="sharded")
        cfg_kv = DistributedKVCache(dpc, 4)
        try:
            cfg = cfg_kv.proto.cfg
            tree = ClusterPrefixTree(
                shard_of=lambda s, p: dir_shard_of(cfg, s, p))
            ks = keys_for(list(range(6 * PAGE)))
            tree.insert(ks, node_id=0)
            for key in ks:
                shard = dir_shard_of(cfg, key[0], key[1])
                assert key in tree.shards[shard]
        finally:
            cfg_kv.close()

    def test_capacity_prunes_coldest_leaves(self):
        tree = ClusterPrefixTree(capacity=6)
        hot = list(range(4 * PAGE))
        tree.insert(keys_for(hot), node_id=0)          # 4 nodes
        for _ in range(5):                             # heat the hot path
            tree.match(keys_for(hot), node_id=1)
        cold = [7] * (4 * PAGE)
        tree.insert(keys_for(cold), node_id=0)         # 8 nodes -> prune
        assert tree.size <= 6
        assert tree.evicted >= 2
        # the hot path survives intact; the cold one lost its tail
        assert len(tree.match(keys_for(hot))) == 4
        assert len(tree.match(keys_for(cold))) < 4


# ---------------------------------------------------------------------------
# map_shared: the promotion directory op never claims or installs
# ---------------------------------------------------------------------------


def _dir(capacity=64):
    cfg = dirx.DirectoryConfig(capacity=capacity, num_nodes=4, max_probe=64)
    return dirx.init_directory(cfg), cfg


class TestMapSharedOp:
    def test_absent_key_is_bad_and_not_installed(self):
        d, cfg = _dir()
        descs = jnp.asarray(D.make_batch([5], [0], [1]))
        d2, res = dirx.map_shared(d, descs, max_probe=64)
        assert int(np.asarray(res)[0, 0]) == D.ST_BAD
        assert dirx.to_host_dict(d2, cfg) == {}        # nothing claimed

    def test_promote_sets_sharer_then_hits(self):
        d, cfg = _dir()
        # node 2 owns (5, 0)
        d, _ = dirx.lookup_and_install(
            d, jnp.asarray(D.make_batch([5], [0], [2])), max_probe=64)
        d, _ = dirx.commit(d, jnp.asarray(D.make_batch([5], [0], [2])),
                           max_probe=64)
        descs = jnp.asarray(D.make_batch([5], [0], [1]))
        d, res = dirx.map_shared(d, descs, max_probe=64)
        st, owner, _ = np.asarray(res)[0]
        assert st == D.ST_MAP_S and owner == 2
        assert 1 in dirx.to_host_dict(d, cfg)[(5, 0)][2]   # sharer bit set
        d, res = dirx.map_shared(d, descs, max_probe=64)   # idempotent
        assert int(np.asarray(res)[0, 0]) == D.ST_HIT_SHARER
        # the owner promoting its own page is a plain owner hit
        d, res = dirx.map_shared(
            d, jnp.asarray(D.make_batch([5], [0], [2])), max_probe=64)
        assert int(np.asarray(res)[0, 0]) == D.ST_HIT_OWNER

    def test_in_flight_entry_blocks(self):
        d, cfg = _dir()
        d, _ = dirx.lookup_and_install(
            d, jnp.asarray(D.make_batch([5], [0], [2])), max_probe=64)
        # still E (uncommitted): promotion must not observe the fill
        d, res = dirx.map_shared(
            d, jnp.asarray(D.make_batch([5], [0], [1])), max_probe=64)
        assert int(np.asarray(res)[0, 0]) == D.ST_BLOCKED
        assert dirx.to_host_dict(d, cfg)[(5, 0)][0] == dirx.E  # untouched


# ---------------------------------------------------------------------------
# kv-level promotion: TLB skip, oracle lockstep, ledger credit
# ---------------------------------------------------------------------------


def make_kv(**kw):
    dpc = DPCConfig(page_size=PAGE, pool_pages_per_shard=64,
                    shadow_oracle=True, directory_capacity=512, **kw)
    return DistributedKVCache(dpc, 2)


class TestPromotePredicted:
    def test_promote_installs_tlb_and_credits_ledger(self):
        kv = make_kv()
        try:
            ks = keys_for(list(range(3 * PAGE)))
            lks = kv.lookup([k[0] for k in ks], [k[1] for k in ks], 0)
            kv.commit([k[0] for k in ks], [k[1] for k in ks], 0, lks)
            kv.prefix_insert(ks, 0)
            matched = kv.prefix_match(ks, 1)
            assert matched == ks
            promoted, hits = kv.promote_predicted(matched, 1)
            assert promoted == ks and hits == len(ks)
            assert kv.proto.counters["promote_hits"] == len(ks)
            # prediction-sourced ledger credit, weighted
            w = kv.dpc.prefix_predict_weight
            for k in ks:
                assert kv.migrator.ledger.counts[k][1] == w
            assert kv.migrator.stats["predicted_notes"] == len(ks)
            # the promoted pages are now TLB hits: zero directory reads
            before = kv.proto.counters["reads"]
            lks = kv.lookup([k[0] for k in ks], [k[1] for k in ks], 1)
            assert all(lk.page_id >= 0 and not lk.needs_fill for lk in lks)
            assert kv.proto.counters["reads"] == before
            # re-promoting is a no-op (all TLB-cached)
            assert kv.promote_predicted(ks, 1) == ([], 0)
            assert kv.proto.counters["oracle_mismatches"] == 0
        finally:
            kv.close()

    def test_promote_miss_allocates_nothing(self):
        kv = make_kv()
        try:
            ghost = [(12345, 0), (54321, 1)]
            promoted, hits = kv.promote_predicted(ghost, 1)
            assert hits == 0
            assert kv.proto.counters["promote_misses"] == 2
            # a later real lookup still gets a fresh exclusive grant
            lk = kv.lookup([12345], [0], 0)[0]
            assert lk.status == D.ST_GRANT_E
            assert kv.proto.counters["oracle_mismatches"] == 0
        finally:
            kv.close()

    def test_fenced_node_cannot_predict_or_advertise(self):
        kv = make_kv()
        try:
            ks = keys_for(list(range(2 * PAGE)))
            lks = kv.lookup([k[0] for k in ks], [k[1] for k in ks], 0)
            kv.commit([k[0] for k in ks], [k[1] for k in ks], 0, lks)
            kv.prefix_insert(ks, 0)
            kv.proto.fence_nodes([1])
            assert kv.prefix_match(ks, 1) == []
            assert kv.promote_predicted(ks, 1) == ([], 0)
            assert kv.prefix_insert(ks, 1) == 0
        finally:
            kv.close()


# ---------------------------------------------------------------------------
# engine level: predict while queued, reconcile at admit, ablation
# ---------------------------------------------------------------------------


def _make_cluster(num_nodes=2, *, max_batch=2, prompt=32, **dpc_kw):
    import jax
    from repro.configs import get_smoke_arch
    from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
    from repro.models import registry
    from repro.models.spec import init_params
    from repro.serving.engine import ServingEngine

    arch = get_smoke_arch("granite-3-2b")
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(0))
    run = RunConfig(arch=arch, shape=ShapeConfig("s", prompt * 2, 4,
                                                 "decode"),
                    mesh=MeshConfig((1,), ("data",)),
                    dpc=DPCConfig(mode="dpc", page_size=PAGE,
                                  pool_pages_per_shard=512,
                                  shadow_oracle=True, **dpc_kw))
    kv = DistributedKVCache(run.dpc, num_nodes)
    engines = [ServingEngine(run, params, max_batch=max_batch,
                             max_pages_per_seq=prompt * 2 // PAGE + 2,
                             node=i, num_nodes=num_nodes, kv_cache=kv)
               for i in range(num_nodes)]
    return engines, kv, arch


def _drive(engines, limit=500):
    for _ in range(limit):
        if sum(e.step() for e in engines) == 0:
            return
    raise AssertionError("engines did not drain")


def _submit_mixed(engines, arch, prompt=32, n_prefixes=3, per_node=6,
                  seed=7):
    """Node 0 cycles through the prefixes (prefilling each early); node 1+
    see each prefix twice in a row, so their queued requests reference
    paths another node committed — the prediction-window case."""
    rng = np.random.RandomState(seed)
    hots = [rng.randint(0, arch.vocab_size, prompt).tolist()
            for _ in range(n_prefixes)]
    for i in range(per_node):
        engines[0].submit(
            hots[i % n_prefixes] + rng.randint(0, arch.vocab_size,
                                               5).tolist(),
            max_new_tokens=2)
    for e in engines[1:]:
        for i in range(per_node):
            e.submit(hots[(i // 2) % n_prefixes]
                     + rng.randint(0, arch.vocab_size, 5).tolist(),
                     max_new_tokens=2)


@pytest.mark.slow
class TestEnginePrediction:
    def test_queued_requests_predicted_then_hit(self):
        """A queued request whose prompt matches another node's committed
        path gets its tail promoted during the overlap window, and the
        promoted pages are still resident at admit (predict hits)."""
        engines, kv, arch = _make_cluster(async_data_plane=True)
        _submit_mixed(engines, arch)
        _drive(engines)
        pred = sum(e.prefix_stats.pages_predicted for e in engines)
        hits = sum(e.prefix_stats.predict_hits for e in engines)
        assert pred > 0
        assert hits / pred > 0.5
        assert kv.proto.counters["promotes"] > 0
        assert kv.proto.counters["oracle_mismatches"] == 0
        assert kv.migrator.stats["predicted_notes"] > 0

    def test_per_node_ablation_never_shares(self):
        """``prefix_cluster=False`` salts every key with the node id: no
        cross-node prefix reuse, no predictions — the ablation baseline
        the benchmark compares against."""
        engines, kv, arch = _make_cluster(async_data_plane=True,
                                          prefix_cluster=False)
        _submit_mixed(engines, arch)
        _drive(engines)
        for e in engines[1:]:
            assert e.prefix_stats.pages_remote == 0
        assert sum(e.prefix_stats.pages_predicted for e in engines) == 0
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_cluster_saves_more_prefill_than_ablation(self):
        """The headline claim: the cluster tree saves strictly more
        prefill tokens than per-node indexing on a shared-prefix mix."""
        saved = {}
        for cluster in (True, False):
            engines, kv, arch = _make_cluster(async_data_plane=True,
                                              prefix_cluster=cluster)
            _submit_mixed(engines, arch)
            _drive(engines)
            saved[cluster] = sum(e.prefix_stats.prefill_tokens_saved
                                 for e in engines)
            assert kv.proto.counters["oracle_mismatches"] == 0
        assert saved[True] > saved[False]
