"""Property tests: random write / reclaim / migrate / flush interleavings.

Drives the full storage-integrated protocol (DistributedKVCache with a
memory BackingStore, sync-mode WritebackQueue, and the refimpl shadow
oracle) through random op sequences and asserts, after every op:

  flush-before-free   no frame with an uncommitted flush obligation is ever
                      reusable (protocol violation counter stays 0, pool
                      state partition holds)
  single-copy         the shadow oracle's invariants (exactly one owner,
                      no sharers in E) hold — divergence from the array
                      directory raises inside the protocol itself
  read-your-writes    a refaulted page's refill bytes equal the last bytes
                      written to it, whether they come from the pending
                      queue or the durable store

Tier-1 runs the fixed-seed variant; hypothesis (when present) searches the
same space under ``-m property``.
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import pagepool as pp
from repro.core.dpc_cache import DistributedKVCache

NODES = 2
POOL = 3
STREAMS = [1, 2, 3, 4]
PAGES = [0, 1]
OP_NAMES = ["fill", "write", "reclaim", "reclaim_begin", "reclaim_finish",
            "migrate", "pump", "barrier", "epoch"]


class Harness:
    """The model: ``expected`` holds each key's last-written bytes;
    ``frames`` simulates the data plane (pfn -> bytes)."""

    def __init__(self):
        dpc = DPCConfig(page_size=4, pool_pages_per_shard=POOL,
                        storage_backend="memory", writeback_async=False,
                        writeback_batch=2, shadow_oracle=True,
                        migrate_threshold=0)
        self.kv = DistributedKVCache(dpc, NODES)
        self.frames = {}
        self.kv.set_page_bytes_fn(lambda key, pfn: self.frames.get(pfn))
        self.expected = {}
        self.version = 0

    def _fresh_bytes(self):
        self.version += 1
        return np.full((6,), self.version, np.int32)

    # -- ops ---------------------------------------------------------------

    def fill(self, key, node):
        lk = self.kv.lookup([key[0]], [key[1]], node)[0]
        if lk.status == D.ST_GRANT_E:
            if lk.refill is not None:
                # read-your-writes after refault: the recovered bytes must
                # be the last ones written, from queue or store alike
                assert key in self.expected, f"{key}: refill of never-written"
                np.testing.assert_array_equal(lk.refill, self.expected[key])
                self.frames[lk.page_id] = lk.refill
            else:
                assert key not in self.expected, \
                    f"{key}: written bytes lost (no refill offered)"
                data = self._fresh_bytes()
                self.frames[lk.page_id] = data
                self.expected[key] = data
            self.kv.commit([key[0]], [key[1]], node, [lk])
        elif lk.status in (D.ST_MAP_S, D.ST_HIT_SHARER, D.ST_HIT_OWNER):
            np.testing.assert_array_equal(self.frames[lk.page_id],
                                          self.expected[key])
        # BLOCKED (teardown in flight) / FULL (pool exhausted): skip

    def write(self, key, _node):
        view = self.kv.proto.directory_view()
        ent = view.get(key)
        if ent is None or ent[0] != 2:   # state O required
            return
        owner, pfn = ent[1], ent[3]
        st = self.kv.proto.mark_dirty([key[0]], [key[1]], owner)[0]
        if st == D.ST_OK:
            data = self._fresh_bytes()
            self.frames[pfn] = data
            self.expected[key] = data

    def reclaim(self, _key, node, want):
        self.kv.proto.reclaim_sync(node, want)

    def reclaim_begin(self, _key, node):
        _, notify = self.kv.proto.reclaim_begin(node, want=1)
        for key, sharers in notify.items():
            for s in sharers:   # deliver ACKs but do NOT finish yet
                self.kv.proto.reclaim_ack(key[0], key[1], s)

    def reclaim_finish(self, _key, node):
        self.kv.proto.reclaim_finish(node)

    def migrate(self, key, dst):
        view = self.kv.proto.directory_view()
        ent = view.get(key)
        if ent is None or ent[0] != 2 or ent[1] == dst:
            return

        def copy(_key, src_pfn, dst_pfn):
            self.frames[dst_pfn] = self.frames[src_pfn]

        self.kv.proto.migrate_sync([(key, dst)], copy_fn=copy)
        # the hand-off's KV copy rides a COPY lane under the async data
        # plane; the model observes bytes directly, so settle first (the
        # engine's analog is settle_data_plane at the step boundary)
        self.kv.proto.fence_data_lanes()

    def pump(self):
        self.kv.pump_storage(1)

    def barrier(self):
        self.kv.flush()

    def epoch(self):
        self.kv.advance_epoch()

    # -- invariants --------------------------------------------------------

    def check(self):
        proto = self.kv.proto
        assert proto.counters["flush_before_free_violations"] == 0
        assert proto.counters["oracle_mismatches"] == 0
        proto.oracle.check_invariants()   # single-copy et al.
        for node in range(NODES):
            pool = proto.state.pools[node]
            states = np.asarray(pool.slot_state)
            # slot states partition the pool; the free stack matches S_FREE
            assert (states == pp.S_FREE).sum() == int(pool.free_top)
            # every pinned frame has exactly one outstanding obligation
            wb_slots = {s for (n, s) in proto._wb_outstanding if n == node}
            assert wb_slots == set(np.nonzero(states == pp.S_WRITEBACK)[0]
                                   .tolist())

    def finale(self):
        """Drain everything, then refault every key ever written."""
        # complete any dangling invalidation rounds before the final audit
        for node in range(NODES):
            self.kv.proto.reclaim_finish(node)
        self.kv.flush()
        self.check()
        assert self.kv.writeback.pending_count() == 0
        for key in list(self.expected):
            for node in range(NODES):
                self.fill(key, node)   # hit, refill, or FULL — all asserted
            self.kv.proto.reclaim_sync(0, want=1)   # keep pools breathing
            self.kv.flush()


def _run_ops(ops):
    h = Harness()
    for op, s, p, node, want in ops:
        key = (STREAMS[s % len(STREAMS)], PAGES[p % len(PAGES)])
        node = node % NODES
        if op == "fill":
            h.fill(key, node)
        elif op == "write":
            h.write(key, node)
        elif op == "reclaim":
            h.reclaim(key, node, 1 + want % 3)
        elif op == "reclaim_begin":
            h.reclaim_begin(key, node)
        elif op == "reclaim_finish":
            h.reclaim_finish(key, node)
        elif op == "migrate":
            h.migrate(key, node)
        elif op == "pump":
            h.pump()
        elif op == "barrier":
            h.barrier()
        elif op == "epoch":
            h.epoch()
        h.check()
    h.finale()


@pytest.mark.parametrize("seed", range(3))
def test_writeback_matches_model_seeded(seed):
    """Tier-1 fixed-seed variant (runs even without hypothesis)."""
    rng = np.random.default_rng(seed)
    ops = [(OP_NAMES[rng.integers(len(OP_NAMES))],
            int(rng.integers(8)), int(rng.integers(8)),
            int(rng.integers(NODES)), int(rng.integers(4)))
           for _ in range(80)]
    _run_ops(ops)


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(
            st.sampled_from(OP_NAMES),
            st.integers(0, 7),            # stream pick
            st.integers(0, 7),            # page pick
            st.integers(0, NODES - 1),    # node / migration dst
            st.integers(0, 3),            # want
        ),
        min_size=1, max_size=60)

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(OPS)
    def test_writeback_matches_model(ops):
        _run_ops(ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_writeback_matches_model():
        pass
