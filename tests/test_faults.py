"""Quorum membership, partition fencing, and deterministic fault injection.

ISSUE 9 coverage: membership transitions commit through the quorum-backed
epoch log (minority proposals raise, never split-brain), fenced nodes'
routed batches are rejected by fencing-token compare while they degrade to
local-only and rejoin through the client guard's re-probe hysteresis, and
the seeded :class:`FaultPlan` (drop / delay / duplicate / crash / skew /
sync-fail) runs the existing invariants under adversity: duplicate lane
delivery is idempotent, crashes at every named crash point recover through
the ordinary failover path with zero lost committed dirty bytes, and —
tier-2 property — any crash-free fault schedule settles observably
equivalent to the clean execution.
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core.dpc_cache import DistributedKVCache
from repro.core.protocol import DPCProtocol, ProtocolConfig, StaleEpochError
from repro.obs.audit import audit_trace
from repro.runtime.epoch_log import EpochLog, QuorumLostError
from repro.runtime.faults import (CRASH_POINTS, FaultConfig, FaultPlan,
                                  InjectedSyncError, NodeCrash, random_plan)
from repro.runtime.liveness import (DirectoryClientGuard, Membership,
                                    StragglerWatchdog)

PAGE = 8


def make_proto(nodes=4, pool=16, cap=256, **kw):
    return DPCProtocol(ProtocolConfig(
        num_nodes=nodes, pool_pages=pool, directory_capacity=cap,
        shadow_oracle=True, **kw))


def put(proto, s, p, node, dirty=False):
    rr = proto.read_pages([s], [p], node)
    assert int(rr.status[0]) == D.ST_GRANT_E, int(rr.status[0])
    slot = int(rr.slot[0])
    proto.commit_pages([s], [p], node, [slot],
                       dirty=[dirty] if dirty else None)
    return slot


def make_kv(nodes=5, pool=32, obs_level="counters"):
    dpc = DPCConfig(page_size=PAGE, pool_pages_per_shard=pool,
                    directory_capacity=1 << 9, shadow_oracle=True,
                    storage_backend="memory", writeback_async=False,
                    obs_level=obs_level,
                    migrate_threshold=3, migrate_batch=64)
    return DistributedKVCache(dpc, nodes)


def seed_kv(kv, frames, node, streams):
    lks = kv.lookup(streams, [0] * len(streams), node)
    for s in streams:
        frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
    kv.commit(streams, [0] * len(streams), node, lks)


def wire(kv, frames, membership):
    """Standard harness wiring: byte capture + re-home install + faults."""
    kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
    kv.attach_membership(
        membership,
        install_fn=lambda key, pfn, data: frames.__setitem__(key, data))


# ---------------------------------------------------------------------------
# epoch log: quorum math
# ---------------------------------------------------------------------------


class TestEpochLog:
    def test_commit_requires_majority(self):
        log = EpochLog(5)
        e = log.propose("join", 4)
        assert e.index == 1 and log.epoch == 1 == log.fence_token
        log.partition([3, 4])
        # majority side (3 of 5) still commits; epoch strictly increases
        e2 = log.propose("fence", 3)
        assert e2.index == 2
        # minority side (2 of 5) cannot
        with pytest.raises(QuorumLostError) as ei:
            log.propose("noop", 4, proposer=4)
        assert ei.value.acks == 2 and ei.value.quorum == 3
        assert log.epoch == 2   # the failed proposal committed nothing

    def test_even_split_blocks_both_sides_without_witness(self):
        log = EpochLog(4)
        log.partition([2, 3])
        assert not log.has_quorum(0) and not log.has_quorum(2)

    def test_witness_breaks_even_split(self):
        log = EpochLog(4, witnesses=1)      # 5 participants, quorum 3
        log.partition([2, 3])
        # witnesses model CXL lease words on the surviving fabric: the
        # side that can attest them wins the tie
        assert log.has_quorum(0) and not log.has_quorum(2)

    def test_denominator_fixed_across_death_grows_on_join(self):
        log = EpochLog(4)
        assert log.quorum == 3
        log.propose("fail", 3)              # death never shrinks quorum
        assert log.quorum == 3
        log.add_voter(4)
        assert log.quorum == 3 and len(log.voters) == 5
        log.add_voter(4)                    # idempotent rejoin
        assert len(log.voters) == 5

    def test_heal_restores_quorum(self):
        log = EpochLog(5)
        log.partition([0, 1])
        assert not log.has_quorum(0)
        assert log.heal() == {0, 1}
        assert log.has_quorum(0) and log.minority == set()


# ---------------------------------------------------------------------------
# partition fencing end-to-end
# ---------------------------------------------------------------------------


class TestPartitionFencing:
    def test_fenced_node_batches_rejected_then_unfenced(self):
        proto = make_proto(nodes=4)
        put(proto, 1, 0, 0)
        token = proto.fence_nodes([2])
        assert proto.is_fenced(2) and token == 1
        with pytest.raises(StaleEpochError) as ei:
            proto.read_pages([1], [0], 2)
        assert ei.value.node == 2 and ei.value.token == token
        assert proto.counters["fenced_rejects"] == 1
        # other nodes are untouched
        rr = proto.read_pages([1], [0], 3)
        assert int(rr.status[0]) == D.ST_MAP_S
        proto.unfence_nodes([2])
        rr = proto.read_pages([1], [0], 2)
        assert int(rr.status[0]) == D.ST_MAP_S
        assert proto.counters["unfenced_nodes"] == 1

    def test_partition_fences_minority_and_heals_via_reprobe(self):
        kv = make_kv(nodes=5)
        frames = {}
        m = Membership(num_nodes=5)
        wire(kv, frames, m)
        for n in range(5):
            seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(4)])
        kv.checkpoint_dirty()
        before = len(kv.proto.directory_view())

        cut = m.partition([4])
        assert cut == [4] and m.fenced == {4}
        assert kv.proto.is_fenced(4)
        assert kv.guards[4].mode == "local_only"
        # the minority side observes quorum loss, not a commit
        m.assert_no_quorum(4)
        # its pages were re-homed onto survivors: nothing lost, nobody
        # double-owns (shadow oracle checks every op)
        assert kv.proto.counters["lost_dirty_pages"] == 0
        view = kv.proto.directory_view()
        assert not any(v[1] == 4 for v in view.values())
        assert len(view) == before
        # fenced node still *serves* — locally, no ownership transitions
        transitions = kv.proto.counters["commits"]
        lks = kv.lookup([91, 92], [0, 0], 4)
        assert all(lk.status == D.ST_GRANT_E for lk in lks)
        kv.commit([91, 92], [0, 0], 4, lks)
        assert kv.proto.counters["commits"] == transitions
        assert (91, 0) not in kv.proto.directory_view()

        # heal: the guard's hysteresis drives the rejoin, not the heal
        assert m.heal() == [4]
        assert m.fenced == {4} and kv.proto.is_fenced(4)
        rejoined = []
        for _ in range(kv.guards[4].reprobe_successes):
            rejoined += kv.probe_fenced(m)
        assert rejoined == [4]
        assert not kv.proto.is_fenced(4) and 4 in m.alive
        rr = kv.lookup([1], [0], 4)     # back through the directory
        assert rr[0].status in (D.ST_MAP_S, D.ST_HIT_SHARER)

    def test_reprobe_streak_resets_while_still_partitioned(self):
        kv = make_kv(nodes=5)
        frames = {}
        m = Membership(num_nodes=5)
        wire(kv, frames, m)
        m.partition([4])
        # probing against a still-open partition never accumulates
        for _ in range(10):
            assert kv.probe_fenced(m) == []
        assert kv.proto.is_fenced(4) and kv.guards[4].mode == "local_only"

    def test_epoch_and_fence_token_monotone_across_churn(self):
        kv = make_kv(nodes=5, obs_level="full")
        frames = {}
        m = Membership(num_nodes=5)
        wire(kv, frames, m)
        for n in range(4):
            seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(3)])
        kv.checkpoint_dirty()
        m.drain(3)
        m.partition([2])
        m.heal()
        for _ in range(3):
            kv.probe_fenced(m)
        m.evict(1, kind="fail")
        assert m.epoch == len(m.log.entries) == kv.proto.fence_token
        doc = {"dpcEvents": [list(e) for e in kv.obs.tracer.events()],
               "dpcMeta": {"pool_pages": kv.dpc.pool_pages_per_shard,
                           "dropped": kv.obs.tracer.dropped}}
        assert audit_trace(doc) == []


# ---------------------------------------------------------------------------
# fault plan: message-layer faults
# ---------------------------------------------------------------------------


class TestMessageFaults:
    def _workload(self, proto):
        for s in range(1, 9):
            put(proto, s, 0, s % proto.cfg.num_nodes, dirty=False)
        for s in range(1, 9):
            proto.read_pages([s], [0], (s + 1) % proto.cfg.num_nodes)
        proto.reclaim_sync(0, 2)
        proto.fence_data_lanes()
        if proto.tlbs is not None:
            for nd in range(proto.cfg.num_nodes):
                proto.tlbs.drain_for([nd])

    def test_duplicate_delivery_is_idempotent(self):
        clean = make_proto(nodes=4, async_data_plane=True)
        faulty = make_proto(nodes=4, async_data_plane=True)
        plan = FaultPlan(FaultConfig(seed=7, dup_p=1.0))
        faulty.attach_faults(plan)
        self._workload(clean)
        self._workload(faulty)     # shadow oracle checks every op
        assert plan.counters(0)["lanes_duplicated"] > 0
        assert clean.directory_view() == faulty.directory_view()

    def test_drop_retries_are_bounded_and_accounted(self):
        clean = make_proto(nodes=4)
        faulty = make_proto(nodes=4)
        plan = FaultPlan(FaultConfig(seed=3, drop_p=0.9, max_retries=2,
                                     backoff_base_us=10))
        faulty.attach_faults(plan)
        self._workload(clean)
        self._workload(faulty)
        tot = {k: sum(plan.counters(n)[k] for n in range(4))
               for k in ("drops_injected", "retries", "backoff_us",
                         "send_timeouts")}
        assert tot["drops_injected"] > 0
        assert tot["retries"] == tot["drops_injected"]   # every drop redrives
        assert tot["backoff_us"] > 0 and tot["send_timeouts"] > 0
        assert clean.directory_view() == faulty.directory_view()

    def test_delayed_lanes_settle_at_fences(self):
        clean = make_proto(nodes=4, async_data_plane=True)
        faulty = make_proto(nodes=4, async_data_plane=True)
        plan = FaultPlan(FaultConfig(seed=11, delay_p=0.8, delay_batches=3))
        faulty.attach_faults(plan)
        self._workload(clean)
        self._workload(faulty)
        assert sum(plan.counters(n)["lanes_delayed"] for n in range(4)) > 0
        assert clean.directory_view() == faulty.directory_view()

    def test_clock_skew_drives_false_suspicion(self):
        plan = FaultPlan(FaultConfig(clock_skew_s={0: 60.0}))
        t = [0.0]
        m = Membership(3, timeout_s=5.0, clock=lambda: t[0])
        # node 0's liveness clock runs 60s ahead: every peer's heartbeat
        # looks expired from its view — false suspicion under test control
        m.clock = plan.skewed_clock(0, lambda: t[0])
        assert set(m.check()) == {0, 1, 2}
        assert plan.counters(0)["skew_applied"] == 1

    def test_deterministic_given_seed(self):
        views = []
        for _ in range(2):
            proto = make_proto(nodes=4, async_data_plane=True)
            proto.attach_faults(FaultPlan(FaultConfig(
                seed=42, drop_p=0.3, delay_p=0.3, dup_p=0.3)))
            self._workload(proto)
            views.append(proto.directory_view())
        assert views[0] == views[1]


# ---------------------------------------------------------------------------
# crash points: recovery through the ordinary failover path
# ---------------------------------------------------------------------------


def _recover(kv, frames, m, crashed):
    """The harness reaction to a NodeCrash: ordinary failover."""
    m.evict(crashed, kind="fail")
    assert kv.proto.counters["lost_dirty_pages"] == 0
    view = kv.proto.directory_view()
    assert not any(v[1] == crashed for v in view.values())


class TestCrashPoints:
    def _cluster(self, point, node, pool=32, hits=1):
        kv = make_kv(nodes=5, pool=pool)
        frames = {}
        m = Membership(num_nodes=5)
        wire(kv, frames, m)
        for n in range(5):
            seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(6)])
        kv.checkpoint_dirty()
        # arm after the steady-state setup so the crash hits the op under
        # test, not the seeding
        plan = FaultPlan(FaultConfig(seed=1, crashes={(point, node): hits}),
                         obs=kv.obs)
        kv.attach_faults(plan)
        return kv, frames, m, plan

    def test_crash_post_commit(self):
        kv, frames, m, plan = self._cluster("post_commit", 1)
        with pytest.raises(NodeCrash) as ei:
            lks = kv.lookup([99], [0], 1)
            frames[(99, 0)] = np.zeros(PAGE, np.float32)
            # committed clean (a durable copy exists): the crash right
            # after the commit must not lose anything
            kv.commit([99], [0], 1, lks, dirty=[False])
        assert (ei.value.node, ei.value.point) == (1, "post_commit")
        _recover(kv, frames, m, 1)
        assert plan.counters(1)["crashes_fired"] == 1
        # the commit itself completed before the crash: survivors refault
        # the page cleanly
        assert kv.lookup([99], [0], 2)[0].status in (D.ST_GRANT_E,
                                                     D.ST_MAP_S)

    def test_crash_pre_reclaim_finish(self):
        kv, frames, m, plan = self._cluster("pre_reclaim_finish", 0, pool=8)
        with pytest.raises(NodeCrash):
            # pool 0 is full (6 seeds + reserve) — reclaim crashes at the
            # finish boundary, invalidations already delivered
            kv.reclaim(0, 4)
        _recover(kv, frames, m, 0)
        assert plan.counters(0)["crashes_fired"] == 1

    def test_crash_pre_migrate_finish(self):
        kv, frames, m, plan = self._cluster("pre_migrate_finish", 0)
        for _ in range(4):       # push (1,0) over the promotion threshold
            kv.lookup([1], [0], 2)
        with pytest.raises(NodeCrash):
            kv.run_migrations()
        _recover(kv, frames, m, 0)
        assert plan.counters(0)["crashes_fired"] == 1

    def test_crash_mid_drain_chunk(self):
        kv, frames, m, plan = self._cluster("mid_drain_chunk", 3)
        with pytest.raises(NodeCrash):
            m.drain(3)
        # the drain died mid-evacuation: the crash becomes a failover
        _recover(kv, frames, m, 3)
        assert plan.counters(3)["crashes_fired"] == 1

    def test_crash_post_flush_register(self):
        kv, frames, m, plan = self._cluster("post_flush_register", 0, pool=8)
        # fresh dirty pages (the checkpoint cleaned the seeds): reclaiming
        # the whole pool forces dirty evictions through the FLUSH lane
        seed_kv(kv, frames, 0, [101, 102])
        with pytest.raises(NodeCrash):
            # the dirty eviction defers its byte capture onto a FLUSH lane
            # and crashes right after the obligation token registers — the
            # failover's lane fence must still land the bytes
            kv.reclaim(0, 8)
        # surviving registered dirty pages persist from the pooled memory
        # (CXL frames outlive the node) before the failover wipes it
        kv.checkpoint_dirty()
        _recover(kv, frames, m, 0)
        assert plan.counters(0)["crashes_fired"] == 1

    def test_all_named_points_are_reachable(self):
        assert set(CRASH_POINTS) == {
            "pre_migrate_finish", "post_flush_register", "mid_drain_chunk",
            "pre_reclaim_finish", "post_commit"}

    def test_crash_fires_once_and_disarms_during_recovery(self):
        kv, frames, m, plan = self._cluster("post_commit", 1)
        with pytest.raises(NodeCrash):
            lks = kv.lookup([99], [0], 1)
            kv.commit([99], [0], 1, lks, dirty=[False])
        _recover(kv, frames, m, 1)   # fail_node disarms; nothing re-fires
        # armed crashes fire at most once: the same op on another node
        lks = kv.lookup([98], [0], 2)
        kv.commit([98], [0], 2, lks)
        assert plan.counters(1)["crashes_fired"] == 1


# ---------------------------------------------------------------------------
# storage sync faults
# ---------------------------------------------------------------------------


class TestSyncFaults:
    def test_injected_sync_failures_redrive_in_order(self):
        kv = make_kv(nodes=3, pool=8)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        plan = FaultPlan(FaultConfig(seed=5, sync_fail_p=1.0, max_retries=2))
        kv.attach_faults(plan)
        seed_kv(kv, frames, 0, list(range(1, 8)))    # fills commit dirty
        kv.reclaim(0, 4)        # dirty evictions enqueue flush obligations
        kv.flush()
        # every obligation landed despite the injected failures, in order
        assert kv.writeback.pending_count() == 0
        wb = kv.obs.view(-1, "writeback", ())
        assert wb["flushed_pages"] > 0
        assert wb["flush_errors"] > 0
        assert plan.counters(-1)["sync_fails_injected"] > 0
        # the durable image matches what was evicted: every flushed key
        # reads back its enqueue-time bytes
        for s in range(1, 8):
            got = kv.store.read(s, 0)
            if got is not None:
                assert float(got[0]) == float(s)

    def test_retry_budget_exhaustion_serves_clean(self):
        kv = make_kv(nodes=3, pool=4)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        kv.attach_faults(FaultPlan(FaultConfig(seed=5, sync_fail_p=1.0,
                                               max_retries=2)))
        seed_kv(kv, frames, 0, [1, 2, 3])
        kv.reclaim(0, 3)
        kv.flush()    # p=1.0: every attempt fails until the bypass kicks in
        assert kv.writeback.pending_count() == 0


# ---------------------------------------------------------------------------
# satellite regressions: guard hysteresis, watchdog warm-up
# ---------------------------------------------------------------------------


class TestGuardHysteresis:
    def test_reprobe_needs_consecutive_successes(self):
        t = [0.0]
        g = DirectoryClientGuard(timeout_s=5.0, clock=lambda: t[0],
                                 reprobe_successes=3)
        g.trip()
        assert g.mode == "local_only"
        g.response_received()
        g.response_received()
        g.probe_failed()                  # streak resets: not back yet
        g.response_received()
        g.response_received()
        assert g.mode == "local_only"
        g.response_received()             # third consecutive
        assert g.mode == "dpc"

    def test_one_lucky_packet_does_not_bounce_back(self):
        t = [0.0]
        g = DirectoryClientGuard(timeout_s=5.0, clock=lambda: t[0])
        t[0] = 10.0
        assert g.check() == "local_only"
        g.response_received()             # single response on a flapping link
        assert g.mode == "local_only"


class TestWatchdogWarmup:
    def test_slow_first_step_does_not_poison_baseline(self):
        wd = StragglerWatchdog(factor=2.0, strikes=2, warmup=3)
        # straggler on step 0: the old first-step seeding would make 5.0
        # the baseline and nothing would ever flag
        assert wd.observe(5.0, slowest_node=0) is None
        assert wd.observe(1.0, slowest_node=1) is None
        assert wd.observe(1.1, slowest_node=1) is None
        assert wd.ewma == pytest.approx(1.1)     # median, not the outlier
        assert wd.observe(5.0, slowest_node=0) is None   # strike 1
        assert wd.observe(5.0, slowest_node=0) == 0      # strike 2: flagged

    def test_fast_warmup_keeps_existing_behavior(self):
        wd = StragglerWatchdog(factor=3.0, strikes=2)     # warmup=2 default
        assert wd.observe(1.0) is None
        assert wd.observe(1.1) is None
        assert wd.ewma == pytest.approx(1.05)
        assert wd.observe(5.0, slowest_node=2) is None
        assert wd.observe(5.0, slowest_node=2) == 2


# ---------------------------------------------------------------------------
# tier-2 property: fault schedules are observably equivalent to clean runs
# ---------------------------------------------------------------------------


def _check_schedule_settles_clean(seed):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(1, 40)), int(rng.integers(4)),
            int(rng.integers(3))) for _ in range(60)]

    def run(plan):
        proto = make_proto(nodes=4, pool=16, async_data_plane=True)
        if plan is not None:
            proto.attach_faults(plan)
        for s, node, kind in ops:
            rr = proto.read_pages([s], [0], node)
            if int(rr.status[0]) == D.ST_GRANT_E:
                proto.commit_pages([s], [0], node, [int(rr.slot[0])],
                                   dirty=[kind == 2])
            if kind == 1:
                proto.reclaim_sync(node, 1)
        proto.fence_data_lanes()
        proto.flush_dirty_marks()
        return proto.directory_view()

    faulty = random_plan(seed, 4, crash_candidates=())  # crash-free
    assert run(None) == run(faulty)


class TestFaultEquivalenceProperty:
    def test_one_seed(self):
        _check_schedule_settles_clean(1234)

    if HAVE_HYPOTHESIS:
        @pytest.mark.property
        @settings(deadline=None, max_examples=20)
        @given(seed=st.integers(min_value=0, max_value=2 ** 16))
        def test_property(self, seed):
            _check_schedule_settles_clean(seed)
