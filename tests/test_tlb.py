"""Mapping-cache (software TLB) coherence tests.

The tentpole invariant: a TLB hit must never return a mapping the directory
no longer grants.  Every kv-level test here runs with the refimpl shadow
oracle on, so ``DPCProtocol.check_tlb_grant`` asserts that invariant on every
single cached hit; the interleaving tests race a cached reader against
reclamation, migration, and node failure — a lost shootdown fails loudly at
the exact faulting lookup.

Also covers the CLEAR_DIRTY satellite (array opcode ≡ refimpl; migrated
pages stop paying double writebacks).
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core import pagepool as pp
from repro.core import refimpl as R
from repro.core.coherence import CoherenceManager
from repro.core.dpc_cache import DistributedKVCache
from repro.core.tlb import MODE_M, MODE_O, MODE_S, TLBGroup

NODES = 4
CAP = 64
CFG = dirx.DirectoryConfig(capacity=CAP, num_nodes=NODES, max_probe=CAP)


def batch(stream, page, node, aux=0):
    return D.make_batch([stream], [page], [node], [aux])


def make_kv(pool_pages=8, **kw) -> DistributedKVCache:
    dpc = DPCConfig(page_size=8, pool_pages_per_shard=pool_pages,
                    shadow_oracle=True, migrate_threshold=0, tlb_slots=64,
                    **kw)
    return DistributedKVCache(dpc, NODES)


def seed_pages(kv, streams, pages, owner=0):
    lks = kv.lookup(streams, pages, owner)
    kv.commit(streams, pages, owner, lks)
    return lks


# ---------------------------------------------------------------------------
# TLB structure unit tests
# ---------------------------------------------------------------------------


class TestMappingTLBUnit:
    def test_install_lookup_drop(self):
        g = TLBGroup(2, slots=16)
        g.install(0, 5, 3, owner=1, pfn=42, mode=MODE_S)
        assert g.lookup(0, 5, 3) == (1, 42, MODE_S)
        assert g.lookup(1, 5, 3) is None        # per-node isolation
        assert g.drop(0, (5, 3))
        assert g.lookup(0, 5, 3) is None
        assert not g.drop(0, (5, 3))            # already gone

    def test_reinstall_updates_in_place(self):
        g = TLBGroup(1, slots=16)
        g.install(0, 1, 1, owner=0, pfn=7, mode=MODE_O)
        g.install(0, 1, 1, owner=2, pfn=19, mode=MODE_S)
        assert g.lookup(0, 1, 1) == (2, 19, MODE_S)
        assert g.nodes[0].stats["installs"] == 1   # second was an update

    def test_mode_upgrade_in_place(self):
        """O -> M (write grant) is an in-place update, not a new install."""
        g = TLBGroup(1, slots=16)
        g.install(0, 1, 1, owner=0, pfn=7, mode=MODE_O)
        g.install(0, 1, 1, owner=0, pfn=7, mode=MODE_M)
        assert g.lookup(0, 1, 1) == (0, 7, MODE_M)
        assert g.nodes[0].stats["installs"] == 1

    def test_capacity_replacement_never_wrong(self):
        """Overfilling a tiny TLB loses entries (it is a cache) but every
        surviving lookup answer must still be the installed mapping."""
        g = TLBGroup(1, slots=8, max_probe=2)
        truth = {}
        for i in range(32):
            key = (i, i * 3)
            g.install(0, key[0], key[1], owner=i % 4, pfn=i, mode=MODE_O)
            truth[key] = (i % 4, i, MODE_O)
        hits = 0
        for key, want in truth.items():
            got = g.lookup(0, key[0], key[1])
            if got is not None:
                assert got == want
                hits += 1
        assert 0 < hits <= 8

    def test_probe_overflow_falls_back_to_directory(self):
        """TLB sizing satellite: when the probe chain overflows a tiny,
        short-probe TLB, lookups MISS and fall back to the directory — every
        answer stays correct (oracle-checked), the miss counter shows the
        fallback actually happened, and nothing is served stale."""
        dpc = DPCConfig(page_size=8, pool_pages_per_shard=32,
                        shadow_oracle=True, migrate_threshold=0,
                        tlb_slots=8, tlb_max_probe=1)
        kv = DistributedKVCache(dpc, NODES)
        streams = list(range(40, 64))        # 24 keys >> 8 slots, probe 1
        pages = [0] * len(streams)
        seed_pages(kv, streams, pages)
        # remote readers map everything twice: the second pass can only
        # TLB-hit the few survivors; the rest re-resolve via the directory
        kv.lookup(streams, pages, 1)
        lks = kv.lookup(streams, pages, 1)
        view = kv.proto.directory_view()
        for s, lk in zip(streams, lks):
            assert lk.status in (D.ST_MAP_S, D.ST_HIT_SHARER)
            assert lk.page_id == view[(s, 0)][3]   # never a stale pfn
        stats = kv.proto.tlbs.nodes[1].stats
        assert stats["misses"] > 0           # overflow really fell back
        assert stats["replacements"] > 0     # chains overflowed in a 1-probe
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_flash_invalidates_everything(self):
        g = TLBGroup(2, slots=16)
        g.install(0, 1, 0, 0, 5, MODE_O)
        g.install(1, 1, 0, 0, 5, MODE_S)
        g.flash_all()
        assert g.lookup(0, 1, 0) is None and g.lookup(1, 1, 0) is None
        # slots are reusable after the flash
        g.install(0, 1, 0, 2, 9, MODE_S)
        assert g.lookup(0, 1, 0) == (2, 9, MODE_S)

    def test_pending_queue_services_before_hit(self):
        g = TLBGroup(1, slots=16)
        g.install(0, 7, 0, 0, 3, MODE_O)
        g.post(0, (7, 0))
        # posted but not yet serviced: the entry is still visible (the
        # pre-ACK window real hardware also has)
        assert g.lookup(0, 7, 0) is not None
        assert g.service(0) == 1
        assert g.lookup(0, 7, 0) is None

    def test_fence_forces_delivery_for_lagging_nodes(self):
        """The bounded-staleness fence: a node that saw no batch traffic
        since a post is behind its post epoch; fence() forces delivery."""
        g = TLBGroup(3, slots=16)
        g.install(1, 7, 0, 0, 3, MODE_S)
        g.install(2, 7, 0, 0, 3, MODE_S)
        g.post(1, (7, 0))
        g.post(2, (7, 0))
        # node 1 sees traffic (drain + deliver, the piggyback path)...
        assert g.deliver(g.drain_for([1])) == 1
        assert g.lookup(1, 7, 0) is None
        # ...node 2 does not: it is behind until the fence forces it
        assert g.served_epoch[2] < g.post_epoch[2]
        assert g.fence([1, 2]) == 1           # only node 2 was behind
        assert g.lookup(2, 7, 0) is None
        assert g.stats["fenced"] == 1
        assert g.fence([1, 2]) == 0           # everyone caught up


# ---------------------------------------------------------------------------
# CLEAR_DIRTY opcode: array impl ≡ refimpl
# ---------------------------------------------------------------------------


class TestClearDirty:
    def fresh(self):
        return dirx.init_directory(CFG), R.RefDirectory(CAP, NODES)

    def _install(self, d, ref, s, p, owner, pfn):
        d, _ = dirx.lookup_and_install(d, batch(s, p, owner), max_probe=CAP)
        ref.lookup_and_install(s, p, owner)
        d, _ = dirx.commit(d, batch(s, p, owner, aux=pfn))
        ref.commit(s, p, owner, pfn)
        return d

    def test_owner_clears_and_result_carries_old_bit(self):
        d, ref = self.fresh()
        d = self._install(d, ref, 1, 0, owner=2, pfn=7)
        d, _ = dirx.mark_dirty(d, batch(1, 0, 2))
        ref.mark_dirty(1, 0, 2)
        d, res = dirx.clear_dirty(d, batch(1, 0, 2))
        st_ref, was_ref = ref.clear_dirty(1, 0, 2)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK == st_ref
        assert bool(res[0, 2]) and was_ref            # old bit reported
        host = dirx.to_host_dict(d, CFG)
        assert host[(1, 0)][4] is False               # entry now clean
        # idempotent: second clear reports was_dirty=False
        d, res = dirx.clear_dirty(d, batch(1, 0, 2))
        st_ref, was_ref = ref.clear_dirty(1, 0, 2)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK == st_ref
        assert not bool(res[0, 2]) and not was_ref

    def test_non_owner_and_absent_are_bad(self):
        d, ref = self.fresh()
        d, res = dirx.clear_dirty(d, batch(9, 9, 0))
        assert np.asarray(res)[0, 0] == D.ST_BAD == ref.clear_dirty(9, 9, 0)[0]
        d = self._install(d, ref, 1, 0, owner=2, pfn=7)
        d, res = dirx.clear_dirty(d, batch(1, 0, 3))   # not the owner
        assert np.asarray(res)[0, 0] == D.ST_BAD == ref.clear_dirty(1, 0, 3)[0]

    def test_migrated_page_pays_single_writeback(self):
        """The ROADMAP follow-on closed: the hand-off checkpoints the bytes
        and CLEAR_DIRTY stops the destination paying a second writeback."""
        kv = make_kv(storage_backend="memory", writeback_async=False,
                     writeback_batch=4)
        payload = np.ones((4,), np.float32)
        kv.set_page_bytes_fn(lambda key, pfn: payload)
        lks = seed_pages(kv, [5], [0])
        assert lks[0].refill is None
        kv.lookup([5], [0], 1)
        moved = kv.proto.migrate_sync([((5, 0), 1)])
        assert len(moved) == 1
        # the checkpoint rides a COPY lane now: settle before counting
        kv.proto.fence_data_lanes()
        assert kv.proto.counters["migration_writebacks"] == 1
        assert kv.proto.counters["dirty_clears"] == 1
        kv.flush()
        wb = kv.proto.counters["writebacks"]
        freed, wrote = kv.proto.reclaim_sync(1, 1)
        assert freed == 1 and wrote == 0
        assert kv.proto.counters["writebacks"] == wb
        assert kv.proto.counters["oracle_mismatches"] == 0
        # the persisted bytes are still refillable after the clean eviction
        lk = kv.lookup([5], [0], 2)[0]
        assert lk.status == D.ST_GRANT_E and lk.refill is not None


# ---------------------------------------------------------------------------
# piggybacked shootdown lanes (descriptor encoding + delivery transport)
# ---------------------------------------------------------------------------


class TestPiggybackLanes:
    def test_lane_encoding_roundtrip(self):
        triples = [(2, 5, 0), (1, 7, 3)]
        rows = D.encode_shootdowns(triples)
        assert rows.shape == (2, D.N_LANES)
        assert (rows[:, D.LANE_STREAM] == int(D.SHOOTDOWN)).all()
        assert D.decode_shootdowns(rows) == triples

    def test_shootdown_rows_are_directory_inert(self):
        """A SHOOTDOWN lane riding an opcode batch must not touch directory
        state — only the receiving node's TLB consumes it."""
        d = dirx.init_directory(CFG)
        rows = np.concatenate([np.asarray(batch(1, 0, 2)),
                               D.encode_shootdowns([(3, 1, 0)])])
        d, res = dirx.lookup_and_install(d, np.asarray(rows, np.int32),
                                         max_probe=CAP)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_GRANT_E       # the real row worked
        assert len(dirx.to_host_dict(d, CFG)) == 1   # lane added nothing

    def test_shootdown_rides_next_batch_for_the_node(self):
        """Queued shootdowns are delivered by the next opcode batch routed
        on the sharer's behalf — not by an in-process drain."""
        kv = make_kv()
        seed_pages(kv, [5], [0])
        kv.lookup([5], [0], 2)
        kv.lookup([5], [0], 2)                  # cached S-mapping on node 2
        tlbs = kv.proto.tlbs
        kv.proto.reclaim_begin(0, want=1)       # posts the shootdown to 2
        assert (5, 0) in tlbs.entries(2)        # pre-delivery window
        delivered0 = tlbs.stats["delivered"]
        # any unrelated batch routed for node 2 carries the lane
        kv.lookup([99], [0], 2)
        assert (5, 0) not in tlbs.entries(2), \
            "piggybacked shootdown did not ride the node's next batch"
        assert tlbs.stats["delivered"] == delivered0 + 1
        kv.proto.reclaim_ack(5, 0, 2)
        kv.proto.reclaim_finish(0)
        assert kv.proto.counters["oracle_mismatches"] == 0


# ---------------------------------------------------------------------------
# write grants: steady-state re-writes are directory-free, dirty bits never
# lost behind a teardown
# ---------------------------------------------------------------------------


class TestWriteGrants:
    def test_steady_state_rewrite_is_directory_free(self):
        kv = make_kv()
        seed_pages(kv, [1, 1], [0, 1])
        proto = kv.proto
        st = proto.mark_dirty([1, 1], [0, 1], 0)   # buffers, O -> M
        assert (st == D.ST_OK).all()
        reads = proto.counters["reads"]
        st = proto.mark_dirty([1, 1], [0, 1], 0)   # pure MODE_M hits
        assert (st == D.ST_OK).all()
        assert proto.counters["reads"] == reads, \
            "steady-state re-write touched the directory"
        assert proto.counters["tlb_write_hits"] >= 4
        # bits are buffered, not yet registered...
        assert not any(v[4] for v in proto.directory_view().values())
        # ...and land in ONE batched op per node at the flush
        assert kv.flush_dirty_marks() == 2
        assert all(v[4] for v in proto.directory_view().values())
        assert proto.counters["dirty_mark_flushes"] == 1
        assert proto.counters["oracle_mismatches"] == 0

    def test_buffered_dirty_survives_reclaim_without_explicit_flush(self):
        """The fence: reclaim_begin flushes the owner's buffered bits, so an
        eviction that raced the step-boundary flush still writes back."""
        kv = make_kv(storage_backend="memory", writeback_async=False,
                     writeback_batch=4)
        kv.set_page_bytes_fn(lambda key, pfn: np.ones((4,), np.float32))
        seed_pages(kv, [5], [0])        # storage on -> commits mark dirty
        assert kv.proto._dirty_buf[0], "commit's dirty mark was not buffered"
        freed, wrote = kv.proto.reclaim_sync(0, 1)   # no explicit flush
        assert freed == 1 and wrote == 1, \
            "buffered dirty bit was lost behind the eviction"
        assert not kv.proto._dirty_buf[0]
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_buffered_dirty_travels_with_migration(self):
        kv = make_kv(storage_backend="memory", writeback_async=False,
                     writeback_batch=4)
        kv.set_page_bytes_fn(lambda key, pfn: np.ones((4,), np.float32))
        seed_pages(kv, [7], [0])                 # dirty mark buffered @0
        kv.lookup([7], [0], 1)
        moved = kv.proto.migrate_sync([((7, 0), 1)])
        assert len(moved) == 1
        kv.proto.fence_data_lanes()   # checkpoint rides a COPY lane
        # migrate_begin flushed the buffer; the hand-off checkpointed the
        # moving frame exactly as a registered-dirty page would
        assert kv.proto.counters["migration_writebacks"] == 1
        assert not kv.proto._dirty_buf[0]
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_write_grant_dies_with_ownership(self):
        kv = make_kv()
        seed_pages(kv, [9], [0])
        kv.proto.mark_dirty([9], [0], 0)         # M-cached at node 0
        kv.flush_dirty_marks()
        kv.lookup([9], [0], 1)
        kv.proto.migrate_sync([((9, 0), 1)])     # ownership moves away
        assert (9, 0) not in kv.proto.tlbs.entries(0)
        # a late write from the old owner falls through to the directory
        # and is refused — never silently served from a stale grant
        st = kv.proto.mark_dirty([9], [0], 0)
        assert st[0] == D.ST_BAD
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_sc_rewrite_keeps_pages_hot(self):
        """TLB-served write_prepare owner hits must still feed CLOCK heat
        (the directory path touched HIT_OWNER rows) — hot re-written pages
        must not look cold to the eviction scan."""
        kv = make_kv()
        proto = kv.proto
        coh = CoherenceManager(proto, "dpc_sc")
        coh.commit(coh.prepare([4, 4], [0, 1], 0))     # first write: locks
        slots = [v[3] % kv.dpc.pool_pages_per_shard
                 for v in proto.directory_view().values()]
        hot_before = np.asarray(proto.state.pools[0].hot)[slots]
        for _ in range(3):
            coh.commit(coh.prepare([4, 4], [0, 1], 0))  # TLB-served
        proto.flush_dirty_marks()                       # heat + dirty land
        hot_after = np.asarray(proto.state.pools[0].hot)[slots]
        assert (hot_after >= np.minimum(hot_before + 3, pp.HOT_MAX)).all()

    def test_strong_write_rehit_is_directory_free(self):
        kv = make_kv()
        proto = kv.proto
        coh = CoherenceManager(proto, "dpc_sc")
        coh.commit(coh.prepare([3, 3], [0, 1], 0))   # first write: locks E
        reads = proto.counters["reads"]
        t = coh.prepare([3, 3], [0, 1], 0)           # re-write: TLB-served
        assert len(t.owner_rows) == 2
        assert coh.commit(t) == 2
        assert proto.counters["reads"] == reads, \
            "DPC_SC re-write of owned pages touched the directory"
        kv.flush_dirty_marks()
        assert all(v[4] for v in proto.directory_view().values())
        assert proto.counters["oracle_mismatches"] == 0


# ---------------------------------------------------------------------------
# kv-level coherence: every cached hit is oracle-checked
# ---------------------------------------------------------------------------


class TestTLBCoherence:
    def test_steady_state_hit_is_directory_free(self):
        kv = make_kv()
        seed_pages(kv, [1, 1], [0, 1])
        kv.lookup([1, 1], [0, 1], 2)          # establish remote mappings
        reads = kv.proto.counters["reads"]
        for node, want_remote in ((0, False), (2, True)):
            lks = kv.lookup([1, 1], [0, 1], node)
            assert all(lk.page_id >= 0 for lk in lks)
            assert all(lk.remote == want_remote for lk in lks)
        assert kv.proto.counters["reads"] == reads, \
            "steady-state re-read touched the directory"
        assert kv.stats["tlb_hits"] >= 4

    def test_buffered_touches_flush_in_one_batch(self):
        kv = make_kv()
        lks = seed_pages(kv, [1, 1], [0, 1])
        slots = [lk.page_id % kv.dpc.pool_pages_per_shard for lk in lks]
        for _ in range(3):
            kv.lookup([1, 1], [0, 1], 0)      # owner TLB hits, buffered
        hot_before = np.asarray(kv.proto.state.pools[0].hot)[slots]
        assert kv.flush_tlb_touches() == 2
        hot_after = np.asarray(kv.proto.state.pools[0].hot)[slots]
        assert (hot_after == np.minimum(hot_before + 3, pp.HOT_MAX)).all()
        assert kv.flush_tlb_touches() == 0    # buffer drained

    def test_reclaim_shoots_down_owner_and_sharers(self):
        kv = make_kv(pool_pages=4)
        seed_pages(kv, [3] * 4, list(range(4)))
        kv.lookup([3] * 4, list(range(4)), 1)   # node 1 caches S-mappings
        kv.lookup([3] * 4, list(range(4)), 1)   # (now TLB-resident)
        kv.reclaim(0, 2)
        # no stale entries survive on either side (oracle would fail the
        # lookup below loudly if one did)
        gone = [k for k, e in kv.proto.directory_view().items()]
        assert len(gone) == 2
        for node in (0, 1):
            lks = kv.lookup([3] * 4, list(range(4)), node)
            assert all(lk.status != D.ST_BAD for lk in lks)
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_shootdown_lands_no_later_than_the_ack(self):
        kv = make_kv()
        seed_pages(kv, [5], [0])
        kv.lookup([5], [0], 2)
        kv.lookup([5], [0], 2)                  # cached on node 2
        tlbs = kv.proto.tlbs
        assert (5, 0) in tlbs.entries(2)
        _, notify = kv.proto.reclaim_begin(0, want=1)
        assert notify == {(5, 0): [2]}
        # pre-ACK window: the entry may still serve (directory still names
        # node 2 a sharer) — and the owner's own entry is already gone
        assert (5, 0) not in tlbs.entries(0)
        lk = kv.lookup([5], [0], 2)[0]
        assert lk.status == D.ST_HIT_SHARER     # legal: bit still set
        kv.proto.reclaim_ack(5, 0, 2)
        assert (5, 0) not in tlbs.entries(2), \
            "ACK completed but the cached mapping survived"
        kv.proto.reclaim_finish(0)
        lk = kv.lookup([5], [0], 2)[0]
        assert lk.status == D.ST_GRANT_E        # entry fully torn down

    def test_migration_moves_cached_ownership(self):
        kv = make_kv()
        seed_pages(kv, [7], [0])
        kv.lookup([7], [0], 1)
        kv.lookup([7], [0], 1)                  # cached shared @1
        moved = kv.proto.migrate_sync([((7, 0), 1)])
        assert len(moved) == 1
        reads = kv.proto.counters["reads"]
        lk = kv.lookup([7], [0], 1)[0]          # dst now owner, TLB-served
        assert lk.status == D.ST_HIT_OWNER and not lk.remote
        assert kv.proto.counters["reads"] == reads
        lk = kv.lookup([7], [0], 0)[0]          # old owner re-maps S
        assert lk.status == D.ST_MAP_S and lk.remote
        assert kv.proto.counters["oracle_mismatches"] == 0

    def test_fail_node_flashes_every_cache(self):
        kv = make_kv()
        seed_pages(kv, [9], [0])
        kv.lookup([9], [0], 2)
        kv.lookup([9], [0], 2)                  # cached @2 -> owner 0
        kv.fail_node(0)                         # owner dies; entries wiped
        lk = kv.lookup([9], [0], 2)[0]          # must NOT stale-hit
        assert lk.status == D.ST_GRANT_E
        assert kv.proto.tlbs.stats["flashes"] == 1

    def test_drop_mapping_drops_cached_entry(self):
        kv = make_kv()
        seed_pages(kv, [2], [0])
        kv.lookup([2], [0], 3)
        kv.lookup([2], [0], 3)
        assert (2, 0) in kv.proto.tlbs.entries(3)
        kv.proto.drop_mapping([2], [0], 3)
        assert (2, 0) not in kv.proto.tlbs.entries(3)
        lk = kv.lookup([2], [0], 3)[0]          # re-maps through directory
        assert lk.status == D.ST_MAP_S


# ---------------------------------------------------------------------------
# interleavings: lookup / reclaim / migrate / fail_node racing cached readers
# ---------------------------------------------------------------------------


N_KEYS = 6
OPS = ["read", "read", "write", "write", "reclaim_begin", "migrate_begin",
       "ack_one", "reclaim_finish", "migrate_finish", "drop",
       "flush_writes", "fail"]


def _run_interleaving(events, piggyback=True):
    """Every event is chased by a cached-reader lookup; the shadow oracle
    (check_tlb_grant / check_tlb_write_grant) asserts
    shootdown-before-complete and zero stale write grants at each one.
    Returns the settled (directory view, writeback count) for the
    piggyback==sync equivalence property."""
    kv = make_kv(pool_pages=4, tlb_shootdown_piggyback=piggyback)
    proto = kv.proto
    keys = [(11, p) for p in range(N_KEYS)]
    failed = set()

    def deliver_one_ack():
        for pend in (proto.pending_inv, proto.pending_mig):
            for key, info in pend.items():
                if info["waiting"]:
                    node = min(info["waiting"])
                    if pend is proto.pending_inv:
                        proto.reclaim_ack(key[0], key[1], node)
                    else:
                        proto.migrate_ack(key[0], key[1], node)
                    return

    for op, ki, node, reader in events:
        s, p = keys[ki]
        if op == "read":
            lks = kv.lookup([s], [p], node)
            kv.commit([s], [p], node, lks)
        elif op == "write":
            # a cached writer: owner-mode entries take the buffered
            # write-grant fast path, everyone else hits the directory (and
            # may legally be refused) — the oracle checks both
            proto.mark_dirty([s], [p], node)
        elif op == "flush_writes":
            proto.flush_dirty_marks()
        elif op == "reclaim_begin":
            proto.reclaim_begin(node, want=1)
        elif op == "migrate_begin":
            proto.migrate_begin([((s, p), node)])
        elif op == "ack_one":
            deliver_one_ack()
        elif op == "reclaim_finish":
            proto.reclaim_finish(node)
        elif op == "migrate_finish":
            proto.migrate_finish()
        elif op == "drop":
            proto.drop_mapping([s], [p], node)
        elif op == "fail":
            if node not in failed and len(failed) < NODES - 2:
                failed.add(node)
                kv.fail_node(node)
        # the racing cached reader: any stale TLB entry fails loudly here
        rs, rp = keys[(ki + reader) % N_KEYS]
        kv.lookup([rs], [rp], (node + reader) % NODES)
        proto.oracle.check_invariants()

    # drain in-flight transactions; the settled state must also be clean
    for _ in range(NODES * N_KEYS):
        if not any(i["waiting"] for i in proto.pending_inv.values()) and \
                not any(i["waiting"] for i in proto.pending_mig.values()):
            break
        deliver_one_ack()
    for node in range(NODES):
        proto.reclaim_finish(node)
    proto.migrate_finish()
    proto.flush_dirty_marks()
    for node in range(NODES):
        for s, p in keys:
            kv.lookup([s], [p], node)
    assert proto.counters["oracle_mismatches"] == 0
    return proto.directory_view(), proto.counters["writebacks"]


def _seeded_events(seed: int, n: int = 70):
    rng = np.random.default_rng(seed)
    return [(OPS[rng.integers(len(OPS))],
             int(rng.integers(N_KEYS)), int(rng.integers(NODES)),
             int(rng.integers(NODES)))
            for _ in range(n)]


@pytest.mark.parametrize("seed", range(4))
def test_tlb_coherence_under_seeded_interleavings(seed):
    """Tier-1 fixed-seed variant (runs even without hypothesis): cached
    readers AND cached writers race reclaim/migrate/fail_node."""
    _run_interleaving(_seeded_events(seed))


@pytest.mark.parametrize("seed", range(4, 7))
def test_piggyback_equals_sync_draining_seeded(seed):
    """Tier-1 fixed-seed equivalence: delivering shootdowns as piggybacked
    lanes must settle to the same directory state and the same writeback
    decisions as the legacy synchronous draining (both oracle-clean)."""
    events = _seeded_events(seed)
    assert _run_interleaving(events, piggyback=True) == \
        _run_interleaving(events, piggyback=False)


if HAVE_HYPOTHESIS:
    EVENTS = st.lists(
        st.tuples(
            st.sampled_from(OPS),
            st.integers(0, N_KEYS - 1),     # key index
            st.integers(0, NODES - 1),      # node
            st.integers(0, NODES - 1),      # racing-reader offset
        ),
        min_size=1, max_size=50,
    )

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(EVENTS)
    def test_tlb_coherence_under_interleavings(events):
        """Hypothesis-driven search over the same space (with shrinking)."""
        _run_interleaving(events)

    @pytest.mark.property
    @settings(deadline=None)
    @given(EVENTS)
    def test_piggyback_equals_sync_draining(events):
        """Property: piggybacked lane delivery ≡ synchronous draining under
        the refimpl oracle — same settled directory, same writebacks."""
        assert _run_interleaving(events, piggyback=True) == \
            _run_interleaving(events, piggyback=False)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_tlb_coherence_under_interleavings():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_piggyback_equals_sync_draining():
        pass
