"""Property tests: the JAX page pool against the Python RefPagePool spec.

Random op sequences (alloc / install / touch / drain / release / clock_scan)
must preserve the pool invariants on both implementations: free slots and
installed slots partition the pool, no slot is double-allocated, CLOCK only
victimizes installed-and-unreferenced slots, and released slots become
allocatable again.
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import pagepool as pp
from repro.core.refimpl import RefPagePool

N_PAGES = 8
OP_NAMES = ["alloc", "release", "touch", "scan"]


def pool_invariants(pool: pp.PoolState):
    key_of = np.asarray(pool.key_of)
    state = np.asarray(pool.slot_state)
    top = int(pool.free_top)
    stack = np.asarray(pool.free_stack)[:top]
    assert len(set(stack.tolist())) == top, "free stack has duplicates"
    for s in stack:
        assert state[s] == pp.S_FREE, f"slot {s} on free stack but not FREE"
    n_free = (state == pp.S_FREE).sum()
    assert n_free == top, "FREE count != stack size"
    installed = state == pp.S_INSTALLED
    assert (key_of[installed, 0] >= 0).all(), "installed slot without key"


def _run_ops(ops):
    pool = pp.init_pool(N_PAGES)
    ref = RefPagePool(N_PAGES)
    live = []  # slots we believe are installed

    for op, arg, want in ops:
        if op == "alloc":
            pool, slots = pp.alloc(pool, jnp.ones((1,), bool))
            r = ref.alloc()
            got = int(np.asarray(slots)[0])
            # both must agree on whether allocation succeeded
            assert (got >= 0) == (r >= 0)
            if got >= 0:
                key = jnp.asarray([[1, arg]], jnp.int32)
                pool = pp.install(pool, slots, key)
                ref.install(r, (1, arg))
                live.append((got, r))
        elif op == "release" and live:
            (g, r) = live.pop(arg % len(live))
            pool = pp.begin_drain(pool, jnp.asarray([g], jnp.int32))
            pool = pp.release(pool, jnp.asarray([g], jnp.int32))
            ref.release(r)
        elif op == "touch" and live:
            (g, r) = live[arg % len(live)]
            pool = pp.touch(pool, jnp.asarray([g], jnp.int32))
            ref.touch(r)
        elif op == "scan":
            pool, victims = pp.clock_scan(pool, want)
            victims = [int(v) for v in np.asarray(victims) if v >= 0]
            for v in victims:
                # CLOCK may only pick installed slots
                assert int(np.asarray(pool.slot_state)[v]) == pp.S_INSTALLED
        pool_invariants(pool)
        ref.check_invariants()

    # final agreement on occupancy
    assert int(pp.num_free(pool)) == ref.num_free


@pytest.mark.parametrize("seed", range(4))
def test_pool_matches_refimpl_seeded(seed):
    """Tier-1 fixed-seed variant (runs even without hypothesis)."""
    rng = np.random.default_rng(seed)
    ops = [(OP_NAMES[rng.integers(len(OP_NAMES))],
            int(rng.integers(N_PAGES)), int(rng.integers(1, 4)))
           for _ in range(40)]
    _run_ops(ops)


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(
            st.sampled_from(OP_NAMES),
            st.integers(0, N_PAGES - 1),   # slot-ish argument
            st.integers(1, 3),             # want
        ),
        min_size=1, max_size=40)

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(OPS)
    def test_pool_matches_refimpl(ops):
        _run_ops(ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pool_matches_refimpl():
        pass


def test_clock_second_chance():
    """A touched slot survives one scan pass; an untouched one is victimized."""
    pool = pp.init_pool(4)
    pool, slots = pp.alloc(pool, jnp.ones((2,), bool))
    pool = pp.install(pool, slots, jnp.asarray([[1, 0], [1, 1]], jnp.int32))
    # both have ref=1 from alloc: first scan clears bits, no victims...
    pool, v1 = pp.clock_scan(pool, 1)
    s0, s1 = int(np.asarray(slots)[0]), int(np.asarray(slots)[1])
    # keep s0 hot
    pool = pp.touch(pool, jnp.asarray([s0], jnp.int32))
    pool, v2 = pp.clock_scan(pool, 1)
    picked = [int(v) for v in np.asarray(v2) if v >= 0]
    assert picked and picked[0] == s1, "cold slot must be victimized first"


def test_gclock_hot_slot_resists_eviction():
    """Beyond the one-bit second chance: a frequently-touched slot outlives
    a once-touched one even after both ref bits are cleared (GCLOCK)."""
    pool = pp.init_pool(4)
    pool, slots = pp.alloc(pool, jnp.ones((2,), bool))
    pool = pp.install(pool, slots, jnp.asarray([[1, 0], [1, 1]], jnp.int32))
    s0, s1 = (int(np.asarray(slots)[0]), int(np.asarray(slots)[1]))
    for _ in range(6):
        pool = pp.touch(pool, jnp.asarray([s0], jnp.int32))
    # classic CLOCK would victimize s0 (first under the hand once both ref
    # bits clear); the hotness counter buys it extra passes
    pool, v = pp.clock_scan(pool, 1)
    picked = [int(x) for x in np.asarray(v) if x >= 0]
    assert picked == [s1]

    ref = RefPagePool(4)
    r0, r1 = ref.alloc(), ref.alloc()
    ref.install(r0, (1, 0)), ref.install(r1, (1, 1))
    for _ in range(6):
        ref.touch(r0)
    assert ref.clock_scan(1) == [r1]


def test_exhaustion_and_reuse():
    pool = pp.init_pool(3)
    pool, slots = pp.alloc(pool, jnp.ones((4,), bool))
    got = np.asarray(slots)
    assert (got >= 0).sum() == 3 and got[3] == -1
    pool = pp.release(pool, jnp.asarray(got[:2], jnp.int32))
    pool, again = pp.alloc(pool, jnp.ones((3,), bool))
    again = np.asarray(again)
    assert (again >= 0).sum() == 2 and again[2] == -1
