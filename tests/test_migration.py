"""Ownership-migration tests: MIGRATE state machine vs the refimpl oracle,
the MIGRATE/TBI race with reclamation, abort paths, and the single-copy
invariant across randomized read/write/reclaim/migrate interleavings.

Tier map: unit + protocol tests run in tier 1; the hypothesis interleaving
test carries the ``property`` marker (slow tier, shrunk under the CI
profile — see conftest.py).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core import pagepool as pp
from repro.core import refimpl as R
from repro.core.migration import (HotnessLedger, MigrationConfig,
                                  OwnershipMigrator)
from repro.core.protocol import DPCProtocol, ProtocolConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property tier degrades to the seeded variant
    HAVE_HYPOTHESIS = False

CAP = 64
NODES = 4
CFG = dirx.DirectoryConfig(capacity=CAP, num_nodes=NODES, max_probe=CAP)


def batch(stream, page, node, aux=0):
    return D.make_batch([stream], [page], [node], [aux])


def _install(d, ref, s, p, owner, pfn):
    d, _ = dirx.lookup_and_install(d, batch(s, p, owner), max_probe=CAP)
    ref.lookup_and_install(s, p, owner)
    d, _ = dirx.commit(d, batch(s, p, owner, aux=pfn))
    ref.commit(s, p, owner, pfn)
    return d


# ---------------------------------------------------------------------------
# directory-level state machine (array impl ≡ refimpl)
# ---------------------------------------------------------------------------


class TestMigrateStateMachine:
    def fresh(self):
        return dirx.init_directory(CFG), R.RefDirectory(CAP, NODES)

    def test_begin_migrate_absent_is_bad(self):
        d, ref = self.fresh()
        d, res, _ = dirx.begin_migrate(d, batch(1, 1, 2))
        assert np.asarray(res)[0, 0] == D.ST_BAD == \
            ref.begin_migrate(1, 1, 2)[0]

    def test_begin_migrate_noop_when_already_owner(self):
        d, ref = self.fresh()
        d = _install(d, ref, 1, 0, owner=2, pfn=7)
        d, res, masks = dirx.begin_migrate(d, batch(1, 0, 2))
        want = ref.begin_migrate(1, 0, 2)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_HIT_OWNER == want[0]
        assert int(np.asarray(masks)[0].sum()) == 0
        # state untouched: still O@2 and readable
        d, r2 = dirx.lookup_and_install(d, batch(1, 0, 2), max_probe=CAP)
        assert np.asarray(r2)[0, 0] == D.ST_HIT_OWNER

    def test_full_migration_round_with_sharers(self):
        d, ref = self.fresh()
        d = _install(d, ref, 5, 0, owner=0, pfn=11)
        for n in (1, 2):  # nodes 1, 2 map it remotely
            d, _ = dirx.lookup_and_install(d, batch(5, 0, n), max_probe=CAP)
            ref.lookup_and_install(5, 0, n)

        # hand ownership to node 1 (itself a sharer — the hot case)
        d, res, masks = dirx.begin_migrate(d, batch(5, 0, 1))
        st_ref, old_owner, old_pfn, sharers = ref.begin_migrate(5, 0, 1)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK == st_ref
        assert res[0, 1] == 0 == old_owner       # copy source
        assert res[0, 2] == 11 == old_pfn
        assert int(np.asarray(masks)[0, 0]) == (1 << 1) | (1 << 2)
        assert sharers == {1, 2}
        assert ref.node_state((5, 0), 0) == "TBM"

        # reads block mid-transaction
        d, r = dirx.lookup_and_install(d, batch(5, 0, 3), max_probe=CAP)
        assert np.asarray(r)[0, 0] == D.ST_BLOCKED == \
            ref.lookup_and_install(5, 0, 3)[0]

        # completion blocked until both sharers ACK
        d, r = dirx.complete_migrate(d, batch(5, 0, 1, aux=0))
        assert np.asarray(r)[0, 0] == D.ST_BLOCKED
        assert ref.complete_migrate(5, 0, 1, 0)[0] == D.ST_BLOCKED
        for n in (1, 2):
            d, _ = dirx.ack_invalidate(d, batch(5, 0, n))
            ref.ack_invalidate(5, 0, n, False)

        d, r = dirx.complete_migrate(d, batch(5, 0, 1, aux=0))
        st_ref, _ = ref.complete_migrate(5, 0, 1, 0)
        assert np.asarray(r)[0, 0] == D.ST_OK == st_ref
        assert ref.node_state((5, 0), 1) == "E"

        # ordinary COMMIT publishes the new frame: E@1 -> O@1
        d, r = dirx.commit(d, batch(5, 0, 1, aux=42))
        assert np.asarray(r)[0, 0] == D.ST_OK == ref.commit(5, 0, 1, 42)
        host = dirx.to_host_dict(d, CFG)
        assert host[(5, 0)][:2] == (dirx.O, 1)
        assert host[(5, 0)][3] == 42

    def test_migrate_blocked_while_reclaim_tbi(self):
        """Reclaim wins the race: its TBI blocks the MIGRATE begin."""
        d, ref = self.fresh()
        d = _install(d, ref, 2, 0, owner=0, pfn=3)
        d, _, _ = dirx.begin_invalidate(d, batch(2, 0, 0))
        ref.begin_invalidate(2, 0, 0)
        d, res, _ = dirx.begin_migrate(d, batch(2, 0, 1))
        assert np.asarray(res)[0, 0] == D.ST_BLOCKED == \
            ref.begin_migrate(2, 0, 1)[0]
        # and the reclaim can't be completed by a migration completion
        d, res = dirx.complete_migrate(d, batch(2, 0, 1, aux=0))
        assert np.asarray(res)[0, 0] == D.ST_BAD
        assert ref.complete_migrate(2, 0, 1, 0)[0] == D.ST_BAD

    def test_reclaim_blocked_while_migrate_tbm(self):
        """Migration wins the race: its TBM refuses the invalidation begin."""
        d, ref = self.fresh()
        d = _install(d, ref, 2, 0, owner=0, pfn=3)
        d, _, _ = dirx.begin_migrate(d, batch(2, 0, 1))
        ref.begin_migrate(2, 0, 1)
        d, res, _ = dirx.begin_invalidate(d, batch(2, 0, 0))
        assert np.asarray(res)[0, 0] == D.ST_BAD == \
            ref.begin_invalidate(2, 0, 0)[0]
        d, res = dirx.complete_invalidate(d, batch(2, 0, 0))
        assert np.asarray(res)[0, 0] == D.ST_BAD
        assert ref.complete_invalidate(2, 0, 0)[0] == D.ST_BAD

    def test_same_batch_migrate_serialization(self):
        """Two destinations claim the same page in ONE batch: first wins,
        second observes the in-flight transaction (BLOCKED)."""
        d, ref = self.fresh()
        d = _install(d, ref, 9, 4, owner=0, pfn=1)
        descs = D.make_batch([9, 9], [4, 4], [1, 2])
        d, res, _ = dirx.begin_migrate(d, descs, max_probe=CAP)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK
        assert res[1, 0] == D.ST_BLOCKED

    def test_abort_returns_ownership_to_source(self):
        d, ref = self.fresh()
        d = _install(d, ref, 3, 0, owner=2, pfn=9)
        d, _, _ = dirx.begin_migrate(d, batch(3, 0, 1))
        ref.begin_migrate(3, 0, 1)
        # abort: complete back to the source, recommit the original frame
        d, res = dirx.complete_migrate(d, batch(3, 0, 2, aux=2))
        assert np.asarray(res)[0, 0] == D.ST_OK == \
            ref.complete_migrate(3, 0, 2, 2)[0]
        d, res = dirx.commit(d, batch(3, 0, 2, aux=9))
        assert np.asarray(res)[0, 0] == D.ST_OK == ref.commit(3, 0, 2, 9)
        host = dirx.to_host_dict(d, CFG)
        assert host[(3, 0)][:2] == (dirx.O, 2) and host[(3, 0)][3] == 9

    def test_dirty_travels_with_ownership(self):
        d, ref = self.fresh()
        d = _install(d, ref, 4, 0, owner=0, pfn=5)
        d, _ = dirx.mark_dirty(d, batch(4, 0, 0))
        ref.mark_dirty(4, 0, 0)
        d, _, _ = dirx.begin_migrate(d, batch(4, 0, 1))
        ref.begin_migrate(4, 0, 1)
        d, res = dirx.complete_migrate(d, batch(4, 0, 1, aux=0))
        st_ref, dirty_ref = ref.complete_migrate(4, 0, 1, 0)
        res = np.asarray(res)
        assert res[0, 0] == D.ST_OK == st_ref
        assert bool(res[0, 2]) and dirty_ref  # writeback obligation moved


# ---------------------------------------------------------------------------
# protocol-level flows (directory + pools + pending-transaction bookkeeping)
# ---------------------------------------------------------------------------


def assert_single_copy(proto: DPCProtocol):
    """The paper's core invariant, checked cluster-wide: every key resident
    in any pool has exactly one frame holding it, and every O entry's PFN
    points at that frame on the recorded owner."""
    pool_copies = {}
    for node, pool in enumerate(proto.state.pools):
        key_of = np.asarray(pool.key_of)
        slot_state = np.asarray(pool.slot_state)
        for slot in range(key_of.shape[0]):
            if slot_state[slot] in (pp.S_INSTALLED, pp.S_DRAINING) \
                    and key_of[slot, 0] >= 0:
                key = (int(key_of[slot, 0]), int(key_of[slot, 1]))
                pool_copies.setdefault(key, []).append((node, slot))
    for key, copies in pool_copies.items():
        assert len(copies) == 1, f"{key}: multiple copies {copies}"
    for key, ent in proto.directory_view().items():
        state, owner, _, pfn, _ = ent
        if state == dirx.O:
            assert pfn // proto.cfg.pool_pages == owner, (key, ent)
            assert pool_copies.get(key) == [(owner, pfn %
                                             proto.cfg.pool_pages)], (key, ent)


class TestProtocolMigration:
    def make(self, pool_pages=8):
        return DPCProtocol(ProtocolConfig(
            num_nodes=NODES, pool_pages=pool_pages, directory_capacity=256))

    def seed(self, proto, n=3, owner=0):
        streams, pages = [7] * n, list(range(n))
        res = proto.read_pages(streams, pages, owner)
        proto.commit_pages(streams, pages, owner, res.slot)
        return streams, pages

    def test_migrate_moves_frames_and_sharers_torn_down(self):
        proto = self.make()
        streams, pages = self.seed(proto)
        proto.read_pages(streams, pages, 1)   # node 1 shares everything
        copies = []
        moved = proto.migrate_sync(
            [((7, p), 1) for p in pages],
            copy_fn=lambda key, src, dst: copies.append((key, src, dst)))
        assert len(moved) == 3
        # source frees and data-plane copies ride COPY lanes: settle first
        proto.fence_data_lanes()
        assert len(copies) == 3
        assert_single_copy(proto)
        view = proto.directory_view()
        assert all(v[0] == dirx.O and v[1] == 1 and v[2] == set()
                   for v in view.values())
        # frames physically moved: source pool drained, destination filled
        assert int(proto.state.pools[0].free_top) == 8
        assert int(proto.state.pools[1].free_top) == 5
        # the mover now local-hits; the old owner becomes the sharer
        r = proto.read_pages(streams, pages, 1)
        assert (r.status == D.ST_HIT_OWNER).all()
        r = proto.read_pages(streams, pages, 0)
        assert (r.status == D.ST_MAP_S).all()

    def test_migrate_noop_same_owner(self):
        proto = self.make()
        self.seed(proto, n=1)
        st, notify = proto.migrate_begin([((7, 0), 0)])
        assert st[0] == D.ST_HIT_OWNER and not notify
        assert not proto.pending_mig
        assert proto.migrate_finish() == []
        assert proto.counters["migrations"] == 0
        assert proto.counters["migration_noops"] == 1
        assert_single_copy(proto)

    def test_migrate_vs_reclaim_same_round_single_copy(self):
        """The MIGRATE/TBI race: both transactions target the same page in
        one round; exactly one wins, the invariant holds throughout, and the
        loser's drain is backed out (no leaked DRAINING frame)."""
        proto = self.make(pool_pages=4)
        streams, pages = self.seed(proto, n=1)
        proto.read_pages(streams, pages, 1)

        # reclaim begins first (O -> TBI) ...
        victims, notify = proto.reclaim_begin(0, want=1)
        assert notify == {(7, 0): [1]}
        # ... migration of the same page in the same round is refused
        st, mnotify = proto.migrate_begin([((7, 0), 1)])
        assert st[0] == D.ST_BLOCKED and not mnotify
        assert_single_copy(proto)
        proto.reclaim_ack(7, 0, 1)
        freed, _ = proto.reclaim_finish(0)
        assert freed == 1
        assert_single_copy(proto)

        # now the other order: migrate first, reclaim refused + backed out
        streams, pages = self.seed(proto, n=1, owner=0)
        proto.read_pages(streams, pages, 1)
        st, mnotify = proto.migrate_begin([((7, 0), 1)])
        assert st[0] == D.ST_OK and mnotify == {(7, 0): [1]}
        n_draining = int((np.asarray(proto.state.pools[0].slot_state)
                          == pp.S_DRAINING).sum())
        assert n_draining == 1
        victims, notify = proto.reclaim_begin(0, want=1)
        assert notify == {}          # nothing reclaimable: page is mid-move
        # no extra frame got stuck in DRAINING on the losing side
        n_draining = int((np.asarray(proto.state.pools[0].slot_state)
                          == pp.S_DRAINING).sum())
        assert n_draining == 1
        assert_single_copy(proto)
        proto.migrate_ack(7, 0, 1)
        moved = proto.migrate_finish()
        assert len(moved) == 1
        assert_single_copy(proto)

    def test_migrate_aborts_when_destination_full(self):
        proto = self.make(pool_pages=2)
        streams, pages = self.seed(proto, n=1)
        # fill node 1's pool completely
        r = proto.read_pages([8, 8], [0, 1], 1)
        proto.commit_pages([8, 8], [0, 1], 1, r.slot)
        moved = proto.migrate_sync([((7, 0), 1)])
        assert moved == []
        assert proto.counters["migration_aborts"] == 1
        assert_single_copy(proto)
        # ownership stayed home and the page still serves reads
        r = proto.read_pages([7], [0], 0)
        assert r.status[0] == D.ST_HIT_OWNER

    def test_destination_failure_aborts_handoff(self):
        proto = self.make()
        streams, pages = self.seed(proto, n=1)
        proto.read_pages(streams, pages, 1)
        proto.read_pages(streams, pages, 2)
        st, notify = proto.migrate_begin([((7, 0), 1)])
        assert st[0] == D.ST_OK
        proto.fail_node(1)           # destination dies mid-round
        proto.migrate_ack(7, 0, 2)   # surviving sharer still ACKs
        moved = proto.migrate_finish()
        assert moved == [] and proto.counters["migration_aborts"] == 1
        assert_single_copy(proto)
        view = proto.directory_view()
        assert view[(7, 0)][:2] == (dirx.O, 0)

    def test_source_failure_drops_transaction(self):
        proto = self.make()
        streams, pages = self.seed(proto, n=1)
        proto.read_pages(streams, pages, 1)
        proto.migrate_begin([((7, 0), 1)])
        proto.fail_node(0)           # the only copy dies with its owner
        assert not proto.pending_mig
        assert proto.migrate_finish() == []
        # page is gone but reinstallable
        r = proto.read_pages([7], [0], 2)
        assert r.status[0] == D.ST_GRANT_E


# ---------------------------------------------------------------------------
# policy layer
# ---------------------------------------------------------------------------


class TestPolicy:
    def test_ledger_decay_forgets_cold_pages(self):
        led = HotnessLedger()
        for _ in range(3):
            led.note((1, 0), 2)
        led.note((1, 1), 3)
        led.decay()
        assert led.hottest((1, 0)) == (2, 1)
        assert led.hottest((1, 1)) == (-1, 0)   # cooled to zero: forgotten
        assert (1, 1) not in led.counts

    def test_promotion_threshold_and_cooldown(self):
        proto = DPCProtocol(ProtocolConfig(num_nodes=NODES, pool_pages=8,
                                           directory_capacity=256))
        res = proto.read_pages([7], [0], 0)
        proto.commit_pages([7], [0], 0, res.slot)
        mig = OwnershipMigrator(proto, MigrationConfig(
            threshold=3, batch_size=8, decay_every=0, cooldown_rounds=4))
        proto.read_pages([7], [0], 1)
        for _ in range(2):
            mig.note_remote_access((7, 0), 1)
        assert mig.run_round() == []            # below threshold
        mig.note_remote_access((7, 0), 1)
        moved = mig.run_round()                 # crossed it
        assert len(moved) == 1
        assert proto.directory_view()[(7, 0)][1] == 1
        # cooldown: the old owner hammering it back is ignored for now
        proto.read_pages([7], [0], 0)
        for _ in range(5):
            mig.note_remote_access((7, 0), 0)
        assert mig.run_round() == []
        assert mig.stats["cooldown_skips"] >= 1

    def test_pool_hotness_counter_decays(self):
        pool = pp.init_pool(4)
        pool, slots = pp.alloc(pool, jnp.ones((1,), bool))
        for _ in range(4):
            pool = pp.touch(pool, slots)
        s = int(np.asarray(slots)[0])
        assert int(np.asarray(pool.hot)[s]) == 5   # 1 from alloc + 4 touches
        pool = pp.decay_hot(pool)
        assert int(np.asarray(pool.hot)[s]) == 2
        pool = pp.begin_drain(pool, slots)
        pool = pp.release(pool, slots)
        assert int(np.asarray(pool.hot)[s]) == 0


# ---------------------------------------------------------------------------
# convergence (the acceptance bar for the skewed-workload benchmark)
# ---------------------------------------------------------------------------


def test_skewed_workload_remote_fraction_drops_2x():
    """The benchmark's smoke workload must converge: the remote-read
    fraction after migration settles is at least 2x below the shifted
    traffic's starting point (it lands near zero in practice)."""
    from benchmarks import migration as bench
    ratio = bench.run(smoke=True)
    assert ratio >= 2.0, f"remote-read fraction only dropped {ratio:.2f}x"


# ---------------------------------------------------------------------------
# serving-engine wiring
# ---------------------------------------------------------------------------


class TestEngineIntegration:
    def _mk_engines(self, migrate=True):
        import jax
        from repro.configs import get_smoke_arch
        from repro.configs.base import (DPCConfig, MeshConfig, RunConfig,
                                        ShapeConfig)
        from repro.core.dpc_cache import DistributedKVCache
        from repro.models import registry
        from repro.models.spec import init_params
        from repro.serving.engine import ServingEngine
        cfg = get_smoke_arch("granite-3-2b")
        api = registry.get_model(cfg)
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
        dpc = DPCConfig(page_size=8, pool_pages_per_shard=128,
                        migrate_threshold=2,
                        migrate_interval_steps=1 if migrate else 0,
                        migrate_decay_every=0, migrate_cooldown=1)
        run = RunConfig(arch=cfg, shape=ShapeConfig("s", 64, 4, "decode"),
                        mesh=MeshConfig((1,), ("data",)), dpc=dpc)
        kv = DistributedKVCache(run.dpc, 2)
        e0 = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                           node=0, num_nodes=2, kv_cache=kv)
        e1 = ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                           node=1, num_nodes=2, kv_cache=kv)
        return kv, e0, e1

    @staticmethod
    def _drain(engine):
        for _ in range(40):
            if engine.step() == 0:
                break

    def test_hot_prefix_ownership_follows_replica_traffic(self):
        from repro.serving import prefix_index
        kv, e0, e1 = self._mk_engines()
        prompt = list(range(7, 31))            # 3 full pages
        keys = prefix_index.page_keys(prompt, 8)[:3]

        e0.submit(prompt, max_new_tokens=2)    # node 0 first-touches
        self._drain(e0)
        view = kv.proto.directory_view()
        assert all(view[tuple(k)][1] == 0 for k in keys)

        # the prefix goes hot on replica 1: repeated admissions hit remotely
        # until the promotion threshold trips, then ownership walks over
        for _ in range(3):
            e1.submit(prompt, max_new_tokens=2)
            self._drain(e1)
        assert kv.stats["migrations"] >= 3
        view = kv.proto.directory_view()
        assert all(view[tuple(k)][1] == 1 for k in keys)
        assert_single_copy(kv.proto)

        # replica 1 now admits the prefix as LOCAL pages
        before_local, before_remote = (e1.prefix_stats.pages_local,
                                       e1.prefix_stats.pages_remote)
        e1.submit(prompt, max_new_tokens=2)
        self._drain(e1)
        assert e1.prefix_stats.pages_local > before_local
        assert e1.prefix_stats.pages_remote == before_remote
        # and the old owner can still serve it (as a sharer now)
        e0.submit(prompt, max_new_tokens=2)
        self._drain(e0)

    def test_copy_page_moves_kv_rows_and_remap_rewrites_tables(self):
        import jax.numpy as jnp
        from repro.serving import steps
        kv, e0, _ = self._mk_engines(migrate=False)
        pc = steps.paged_part(e0.cache)
        P = kv.dpc.pool_pages_per_shard
        marked = pc._replace(
            k_pools=pc.k_pools.at[:, 3].set(1.5),
            v_pools=pc.v_pools.at[:, 3].set(-2.5))
        e0.cache = steps.replace_paged(e0.cache, marked)

        e0._copy_page((9, 0), src_pfn=3, dst_pfn=P + 5)   # slot 3 -> slot 5
        pc = steps.paged_part(e0.cache)
        assert bool(jnp.all(pc.k_pools[:, 5] == 1.5))
        assert bool(jnp.all(pc.v_pools[:, 5] == -2.5))

        e0._pt[0, :2] = [3, 7]
        moved = [((9, 0), 3, P + 5)]
        remap = {old: new for _, old, new in moved}
        for old, new in remap.items():
            e0._pt[e0._pt == old] = new
        assert e0._pt[0, 0] == P + 5 and e0._pt[0, 1] == 7


# ---------------------------------------------------------------------------
# property test: single-copy invariant under randomized interleavings
# ---------------------------------------------------------------------------


N_KEYS = 6
OPS = ["read", "write", "reclaim_begin", "migrate_begin",
       "ack_one", "reclaim_finish", "migrate_finish"]


def _run_interleaving(events):
    """Drive an arbitrary event interleaving — reads, writes, reclamation,
    and migration with ACK delivery and completion reordered against new
    traffic — asserting after every event that no page ever has a second
    resident copy."""
    proto = DPCProtocol(ProtocolConfig(num_nodes=NODES, pool_pages=4,
                                       directory_capacity=256))
    keys = [(11, p) for p in range(N_KEYS)]

    def deliver_one_ack():
        for pend in (proto.pending_inv, proto.pending_mig):
            for key, info in pend.items():
                if info["waiting"]:
                    node = min(info["waiting"])
                    if pend is proto.pending_inv:
                        proto.reclaim_ack(key[0], key[1], node)
                    else:
                        proto.migrate_ack(key[0], key[1], node)
                    return

    for op, ki, node in events:
        s, p = keys[ki]
        if op == "read":
            res = proto.read_pages([s], [p], node)
            if res.status[0] == D.ST_GRANT_E:
                proto.commit_pages([s], [p], node, res.slot)
        elif op == "write":
            proto.mark_dirty([s], [p], node)
        elif op == "reclaim_begin":
            proto.reclaim_begin(node, want=1)
        elif op == "migrate_begin":
            proto.migrate_begin([((s, p), node)])
        elif op == "ack_one":
            deliver_one_ack()
        elif op == "reclaim_finish":
            proto.reclaim_finish(node)
        elif op == "migrate_finish":
            proto.migrate_finish()
        assert_single_copy(proto)

    # drain every in-flight transaction and check the settled state
    for _ in range(NODES * N_KEYS):
        if not any(i["waiting"] for i in proto.pending_inv.values()) and \
                not any(i["waiting"] for i in proto.pending_mig.values()):
            break
        deliver_one_ack()
    for node in range(NODES):
        proto.reclaim_finish(node)
    proto.migrate_finish()
    assert not proto.pending_mig
    assert_single_copy(proto)


@pytest.mark.parametrize("seed", range(4))
def test_single_copy_under_seeded_interleavings(seed):
    """Tier-1 randomized variant: fixed-seed interleavings so the invariant
    is exercised even where hypothesis isn't installed."""
    rng = np.random.default_rng(seed)
    events = [(OPS[rng.integers(len(OPS))],
               int(rng.integers(N_KEYS)), int(rng.integers(NODES)))
              for _ in range(60)]
    _run_interleaving(events)


if HAVE_HYPOTHESIS:
    EVENTS = st.lists(
        st.tuples(
            st.sampled_from(OPS),
            st.integers(0, N_KEYS - 1),     # key index
            st.integers(0, NODES - 1),      # node
        ),
        min_size=1, max_size=50,
    )

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(EVENTS)
    def test_single_copy_under_interleavings(events):
        """Hypothesis-driven search over the same interleaving space (with
        shrinking) — the slow/property tier's stronger version of the seeded
        test above."""
        _run_interleaving(events)
