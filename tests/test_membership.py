"""Membership transitions: join / drain / failover as protocol scenarios.

ISSUE 6 coverage: joins grow directory/TLB/pool state without re-hashing
shard placement, drains evacuate ownership (racing in-flight MIGRATEs and
cached writers) with precise TLB retirement, failovers re-home orphans from
the durable tier with last-committed bytes, and — tier-2 property — drain
is observably equivalent to (fail + refill-from-store) on settled state.
Also the satellite regression: sharer-side mark_dirty rides the buffered
per-node dirty sets instead of paying a per-call directory op.
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import pagepool as pp
from repro.core.dpc_cache import DistributedKVCache
from repro.core.protocol import DPCProtocol, ProtocolConfig
from repro.core.tlb import MODE_S
from repro.runtime.liveness import Membership

PAGE = 8


def make_proto(nodes=4, pool=16, cap=256, **kw):
    return DPCProtocol(ProtocolConfig(
        num_nodes=nodes, pool_pages=pool, directory_capacity=cap,
        shadow_oracle=True, **kw))


def put(proto, s, p, node, dirty=False):
    """Install + commit one page at ``node``; returns its slot."""
    rr = proto.read_pages([s], [p], node)
    assert int(rr.status[0]) == D.ST_GRANT_E, int(rr.status[0])
    slot = int(rr.slot[0])
    proto.commit_pages([s], [p], node, [slot],
                       dirty=[dirty] if dirty else None)
    return slot


def make_kv(nodes=4, pool=32, store=True):
    dpc = DPCConfig(page_size=PAGE, pool_pages_per_shard=pool,
                    directory_capacity=1 << 9, shadow_oracle=True,
                    storage_backend="memory" if store else "none",
                    writeback_async=False,
                    migrate_threshold=3, migrate_batch=64)
    return DistributedKVCache(dpc, nodes)


def seed_kv(kv, frames, node, streams):
    lks = kv.lookup(streams, [0] * len(streams), node)
    for s in streams:
        frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
    kv.commit(streams, [0] * len(streams), node, lks)


# ---------------------------------------------------------------------------
# join
# ---------------------------------------------------------------------------


class TestJoin:
    def test_join_grows_cluster_and_serves(self):
        proto = make_proto(nodes=3)
        put(proto, 1, 0, 0)
        node = proto.add_node()
        assert node == 3 and proto.cfg.num_nodes == 4
        assert len(proto.state.pools) == 4
        assert proto.tlbs is None or len(proto.tlbs.nodes) == 4
        # the newcomer reads an existing page (maps S) and faults a new one
        rr = proto.read_pages([1], [0], node)
        assert int(rr.status[0]) == D.ST_MAP_S
        put(proto, 9, 0, node)
        assert proto.directory_view()[(9, 0)][1] == node

    def test_join_never_rehashes_shard_placement(self):
        from repro.core.protocol import dir_shard_of
        proto = make_proto(nodes=3)
        keys = [(s, 0) for s in range(1, 20)]
        before = {k: dir_shard_of(proto.cfg, *k) for k in keys}
        proto.add_node()
        assert {k: dir_shard_of(proto.cfg, *k) for k in keys} == before
        assert proto.cfg.num_shards == 3   # frozen at founding layout

    def test_join_across_sharer_word_boundary(self):
        # 32 -> 33 nodes crosses the uint32 sharer-mask word boundary: the
        # mask must widen in place with every existing bit preserved
        proto = make_proto(nodes=32, pool=4, cap=64, placement="central")
        put(proto, 1, 0, 0)
        for n in (1, 5, 31):
            rr = proto.read_pages([1], [0], n)
            assert int(rr.status[0]) == D.ST_MAP_S
        assert proto.state.dirs[0].sharers.shape[1] == 1
        node = proto.add_node()
        assert node == 32
        assert proto.state.dirs[0].sharers.shape[1] == 2
        st, owner, sharers, _, _ = proto.directory_view()[(1, 0)]
        assert owner == 0 and sharers == {1, 5, 31}
        rr = proto.read_pages([1], [0], node)   # bit 32 lands in word 1
        assert int(rr.status[0]) == D.ST_MAP_S
        assert node in proto.directory_view()[(1, 0)][2]

    def test_join_then_rebalance_converges(self):
        kv = make_kv(nodes=3)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        for n in range(3):
            seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(8)])
        node = kv.join_node()
        moved = kv.rebalance_join(node)
        assert moved, "rebalance moved nothing to the joiner"
        view = kv.proto.directory_view()
        owned = [sum(1 for v in view.values() if v[1] == n)
                 for n in range(kv.num_nodes)]
        assert owned[node] == len(moved)
        # even share (24 pages / 4 nodes = 6), and nothing lost
        assert owned[node] == 6 and sum(owned) == 24
        assert kv.proto.counters["lost_dirty_pages"] == 0


# ---------------------------------------------------------------------------
# drain
# ---------------------------------------------------------------------------


class TestDrain:
    def test_drain_evacuates_and_preserves_dirty(self):
        kv = make_kv(nodes=3)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        seed_kv(kv, frames, 1, list(range(1, 9)))    # fills commit dirty
        kv.lookup([1, 2], [0, 0], 2)                  # node 2 maps S
        kv.lookup([3, 4], [0, 0], 0)
        tlbs = kv.proto.tlbs
        flashes = tlbs.stats["flashes"]
        st = kv.drain_node(1)
        assert st["migrated"] == 8 and st["aborted"] == 0
        view = kv.proto.directory_view()
        assert not any(v[1] == 1 for v in view.values())
        # precise retirement: no global epoch flash, the node wiped once,
        # and the *other* nodes' warm mappings survived
        assert tlbs.stats["flashes"] == flashes
        assert tlbs.stats["wipes"] == 1
        assert tlbs.lookup(1, 1, 0) is None
        assert tlbs.lookup(2, 1, 0) is not None
        # every dirty page's bytes became durable across the hand-off
        assert kv.proto.counters["lost_dirty_pages"] == 0
        for s in range(1, 9):
            data = kv._storage_read((s, 0))
            assert data is not None
            np.testing.assert_array_equal(np.asarray(data, np.float32),
                                          frames[(s, 0)])

    def test_drain_races_inflight_migrate_from_victim(self):
        # the drain must complete a MIGRATE the victim already sources,
        # not strand it: the page lands at its planned destination
        proto = make_proto()
        put(proto, 1, 0, 1)
        rr = proto.read_pages([1], [0], 2)            # sharer must ACK
        assert int(rr.status[0]) == D.ST_MAP_S
        _, notify = proto.migrate_begin([((1, 0), 3)])
        assert (1, 0) in proto.pending_mig
        st = proto.drain_node(1)
        assert not proto.pending_mig
        assert proto.directory_view()[(1, 0)][1] == 3
        assert any(k == (1, 0) for k, _, _ in st["moved"])

    def test_drain_races_inflight_migrate_to_victim(self):
        # a MIGRATE headed *to* the draining node retargets at the source:
        # ownership stays put instead of landing on the leaver
        proto = make_proto()
        put(proto, 1, 0, 0)
        rr = proto.read_pages([1], [0], 2)
        assert int(rr.status[0]) == D.ST_MAP_S
        proto.migrate_begin([((1, 0), 1)])            # dst = the leaver
        proto.drain_node(1)
        # retargeted at the source; the live sharer still owes its ACK
        assert proto.pending_mig[(1, 0)]["dst"] == 0
        proto.migrate_ack(1, 0, 2)
        proto.migrate_finish()
        assert not proto.pending_mig
        assert proto.directory_view()[(1, 0)][1] == 0

    def test_drain_with_cached_writer(self):
        # a sharer-mode cached writer has dirty marks only in its buffered
        # set; draining that sharer must surface the bit via the voluntary
        # drop's dirty lane, not lose it
        proto = make_proto()
        put(proto, 1, 0, 0)
        rr = proto.read_pages([1], [0], 1)
        assert int(rr.status[0]) == D.ST_MAP_S
        res = proto.mark_dirty([1], [0], 1)           # buffered, no dir op
        assert int(res[0]) == D.ST_OK
        assert (1, 0) in proto._dirty_buf[1]
        assert proto.directory_view()[(1, 0)][4] is False
        proto.drain_node(1)
        assert proto.directory_view()[(1, 0)][4] is True

    def test_drain_aborts_uncommitted_installs(self):
        proto = make_proto()
        rr = proto.read_pages([5], [0], 1)            # E, never committed
        assert int(rr.status[0]) == D.ST_GRANT_E
        st = proto.drain_node(1)
        assert st["e_aborted"] == 1
        assert (5, 0) not in proto.directory_view()
        assert int(pp.num_free(proto.state.pools[1])) == proto.cfg.pool_pages


# ---------------------------------------------------------------------------
# sharer-side dirty buffering (satellite regression)
# ---------------------------------------------------------------------------


class TestSharerDirtyBuffering:
    def test_s_mode_mark_dirty_pays_zero_directory_ops(self):
        proto = make_proto()
        put(proto, 1, 0, 0)
        proto.read_pages([1], [0], 1)                 # S mapping + TLB entry
        assert proto.tlbs.lookup(1, 1, 0)[2] == MODE_S
        hits = proto.counters["tlb_write_hits"]
        buffered = proto.counters["dirty_buffered"]
        for _ in range(5):                            # steady-state re-write
            res = proto.mark_dirty([1], [0], 1)
            assert int(res[0]) == D.ST_OK
        assert proto.counters["tlb_write_hits"] == hits + 5
        assert proto.counters["dirty_buffered"] == buffered + 1  # dedup'd
        assert proto.directory_view()[(1, 0)][4] is False  # not yet visible
        assert proto.flush_dirty_marks() == 1         # ONE batched op
        assert proto.directory_view()[(1, 0)][4] is True
        if proto.oracle is not None:
            assert proto.oracle.entries[(1, 0)].dirty

    def test_held_back_mark_rides_migrate_ack(self):
        # a sharer mark buffered AFTER the key entered teardown (a cached
        # writer racing an in-flight MIGRATE) is excluded from the batched
        # flush — TBM refuses mark_dirty — and must ride the sharer's
        # INV_ACK dirty lane instead
        proto = make_proto()
        put(proto, 1, 0, 0)
        proto.read_pages([1], [0], 1)
        proto.migrate_begin([((1, 0), 2)])            # key now TBM
        proto.mark_dirty([1], [0], 1)                 # buffered on node 1
        assert proto.flush_dirty_marks() == 0         # held back, not lost
        assert (1, 0) in proto._dirty_buf[1]
        proto.migrate_ack(1, 0, 1)                    # ACK folds the bit in
        assert (1, 0) not in proto._dirty_buf[1]
        proto.migrate_finish()
        assert proto.directory_view()[(1, 0)][1] == 2
        assert proto.directory_view()[(1, 0)][4] is True


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


class TestFailover:
    def test_failover_mid_writeback_refills_last_committed(self):
        # bytes still pending in the writeback queue (never flushed) must
        # re-home read-your-writes: the LAST committed copy wins
        kv = make_kv(nodes=3)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        seed_kv(kv, frames, 1, [7])
        kv.checkpoint_dirty()                         # v1 enqueued
        frames[(7, 0)] = np.full(PAGE, 777.0, np.float32)
        kv.proto.mark_dirty([7], [0], 1)
        kv.checkpoint_dirty()                         # v2 supersedes, pending
        assert kv.store.read(7, 0) is None            # nothing durable yet
        got = {}
        kv.fail_node(1, rehome_to=0,
                     install_fn=lambda k, pfn, d: got.update({k: d}))
        assert kv.proto.counters["rehomed_pages"] == 1
        assert kv.proto.counters["lost_dirty_pages"] == 0
        np.testing.assert_array_equal(
            np.asarray(got[(7, 0)], np.float32).reshape(-1),
            np.full(PAGE, 777.0, np.float32))
        assert kv.proto.directory_view()[(7, 0)][1] == 0

    def test_failover_rehomes_from_durable_store(self):
        kv = make_kv(nodes=3)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
        seed_kv(kv, frames, 1, list(range(1, 7)))
        kv.checkpoint_dirty()
        kv.flush()                                    # durable in the store
        got = {}
        lost = kv.fail_node(1, rehome_to=2,
                            install_fn=lambda k, pfn, d: got.update({k: d}))
        assert lost == 6
        assert kv.proto.counters["rehomed_pages"] == 6
        view = kv.proto.directory_view()
        for s in range(1, 7):
            assert view[(s, 0)][1] == 2               # re-homed, not dropped
            np.testing.assert_array_equal(
                np.asarray(got[(s, 0)], np.float32).reshape(-1),
                frames[(s, 0)])
        # re-homed entries committed CLEAN: the durable copy backstops them
        assert not any(view[(s, 0)][4] for s in range(1, 7))

    def test_fail_without_durable_tier_keeps_legacy_drop(self):
        proto = make_proto()
        put(proto, 1, 0, 1, dirty=True)
        lost = proto.fail_node(1)
        assert lost == 1
        assert (1, 0) not in proto.directory_view()
        assert proto.counters["rehomed_pages"] == 0

    def test_membership_wiring_rolls_through_epochs(self):
        kv = make_kv(nodes=4)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))

        def install(key, pfn, data):
            frames[key] = np.asarray(data)

        m = Membership(num_nodes=4)
        kv.attach_membership(m, install_fn=install)
        for n in range(4):
            seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(4)])
        m.drain(0)
        assert not any(v[1] == 0 for v in kv.proto.directory_view().values())
        m.join(0)
        assert kv.proto.counters["rejoins"] == 1
        kv.checkpoint_dirty()
        m.evict(2, "fail")
        assert kv.proto.counters["rehomed_pages"] > 0
        assert kv.proto.counters["lost_dirty_pages"] == 0

    def test_seeded_interleavings(self):
        # randomized churn under the shadow oracle: lookups, buffered
        # writes, migrations, drains, rejoins, and checkpointed failovers
        # interleave; the oracle asserts every transition and no committed
        # dirty byte may be lost
        for seed in (0, 1, 2):
            rng = np.random.default_rng(seed)
            kv = make_kv(nodes=4, pool=48)
            frames = {}
            kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
            m = Membership(num_nodes=4)
            kv.attach_membership(
                m, install_fn=lambda k, pfn, d: frames.update(
                    {k: np.asarray(d)}))
            for n in range(4):
                seed_kv(kv, frames, n, [n * 10 + i + 1 for i in range(6)])
            all_streams = [n * 10 + i + 1 for n in range(4) for i in
                           range(6)]
            for step in range(30):
                op = rng.integers(0, 10)
                node = int(rng.integers(0, 4))
                if node not in m.alive:
                    m.join(node)
                    continue
                if op < 5:
                    picks = rng.choice(all_streams, 4)
                    lks = kv.lookup([int(s) for s in picks], [0] * 4, node)
                    kv.commit([int(s) for s in picks], [0] * 4, node, lks)
                elif op < 7:
                    s = int(rng.choice(all_streams))
                    kv.proto.mark_dirty([s], [0], node)
                elif op == 7:
                    kv.run_migrations()
                elif op == 8 and len(m.alive) > 2:
                    m.drain(node)
                else:
                    if len(m.alive) > 2:
                        kv.checkpoint_dirty()
                        m.evict(node, "fail")
            kv.flush_dirty_marks()
            assert kv.proto.counters["lost_dirty_pages"] == 0, seed


# ---------------------------------------------------------------------------
# drain ≡ fail + refill-from-store on settled state
# ---------------------------------------------------------------------------


def _settled_pair(n_pages, dirty_mask, victim):
    """Two identical settled clusters; returns (kv, frames) twice."""
    out = []
    for _ in range(2):
        kv = make_kv(nodes=3, pool=48)
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn, f=frames: f.get(key))
        for n in range(3):
            seed_kv(kv, frames, n,
                    [n * 20 + i + 1 for i in range(n_pages)])
        for i, d in enumerate(dirty_mask[:n_pages]):
            if d:
                kv.proto.mark_dirty([victim * 20 + i + 1], [0], victim)
        # settle: marks registered, dirty bytes durable, queue drained
        kv.proto.flush_dirty_marks()
        kv.checkpoint_dirty()
        kv.flush()
        out.append((kv, frames))
    return out


def _observable(kv, frames, departed):
    """(key -> owner-alive?, key -> bytes) for every surviving entry."""
    view = kv.proto.directory_view()
    assert not any(v[1] == departed for v in view.values())
    assert kv.proto.counters["lost_dirty_pages"] == 0
    content = {}
    for key in view:
        data = kv._storage_read(key)
        content[key] = (None if data is None
                        else np.asarray(data, np.float32).reshape(-1)
                        .tobytes())
    return set(view), content


def _check_drain_equiv_fail(n_pages, dirty_mask, victim):
    (kv_a, fr_a), (kv_b, fr_b) = _settled_pair(n_pages, dirty_mask, victim)
    kv_a.drain_node(victim)
    kv_b.fail_node(victim, rehome_to=(victim + 1) % 3,
                   install_fn=lambda k, pfn, d, f=fr_b: f.update(
                       {k: np.asarray(d)}))
    keys_a, content_a = _observable(kv_a, fr_a, victim)
    keys_b, content_b = _observable(kv_b, fr_b, victim)
    # equivalence on the settled observables: the same keys survive, and
    # every key whose bytes are durable reads back identically
    assert keys_a == keys_b
    for key in keys_a:
        if content_a[key] is not None and content_b[key] is not None:
            assert content_a[key] == content_b[key], key
    # every page the victim owned stays reachable in both worlds
    for i in range(n_pages):
        assert (victim * 20 + i + 1, 0) in keys_a


class TestDrainFailEquivalence:
    def test_fixed_cases(self):
        """Tier-1 fixed-seed variant (runs even without hypothesis)."""
        _check_drain_equiv_fail(4, [True, False, True, False], 1)
        _check_drain_equiv_fail(3, [True, True, True], 2)
        _check_drain_equiv_fail(2, [False, False], 0)

    if HAVE_HYPOTHESIS:
        @pytest.mark.property
        @settings(max_examples=15, deadline=None)
        @given(n_pages=st.integers(1, 6),
               dirty_mask=st.lists(st.booleans(), min_size=6, max_size=6),
               victim=st.integers(0, 2))
        def test_property(self, n_pages, dirty_mask, victim):
            _check_drain_equiv_fail(n_pages, dirty_mask, victim)
