"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import descriptors as D
from repro.core import directory as dirx


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("b,sq,sk,hq,hkv,d,causal", [
        (1, 128, 128, 4, 4, 64, True),      # MHA square
        (2, 256, 256, 8, 2, 64, True),      # GQA 4x
        (1, 200, 200, 4, 1, 32, True),      # MQA, ragged seq (padding path)
        (1, 64, 256, 4, 4, 64, True),       # Sq < Sk (chunked prefill)
        (2, 128, 96, 4, 2, 64, False),      # cross attention
    ])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_vs_naive_oracle(self, b, sq, sk, hq, hkv, d, causal, dtype):
        from repro.kernels.flash_attention import ops, ref
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        dt = jnp.dtype(dtype)
        q = rand(k1, (b, sq, hq, d), dt)
        k = rand(k2, (b, sk, hkv, d), dt)
        v = rand(k3, (b, sk, hkv, d), dt)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                  block_k=64, interpret=True)
        want = ref.reference_attention(q, k, v, causal=causal)
        tol = 2e-2 if dtype == "bfloat16" else 2e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=tol, rtol=tol)

    def test_dv_neq_dk(self):
        """MLA prefill shape: qk dim 64, v dim 32."""
        from repro.kernels.flash_attention import ops, ref
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(k1, (1, 128, 4, 64), jnp.float32)
        k = rand(k2, (1, 128, 4, 64), jnp.float32)
        v = rand(k3, (1, 128, 4, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, block_q=64,
                                  block_k=64, interpret=True)
        want = ref.reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_tiled_ref_matches_naive(self):
        from repro.kernels.flash_attention import ref
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        q = rand(k1, (2, 300, 8, 64), jnp.float32)
        k = rand(k2, (2, 300, 2, 64), jnp.float32)
        v = rand(k3, (2, 300, 2, 64), jnp.float32)
        got = ref.tiled_causal_attention(q, k, v, chunk=128)
        want = ref.reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------


def make_paged_case(key, b, hq, hkv, d, p_phys, page, n_pages, dtype,
                    frac_valid=0.8):
    ks = jax.random.split(key, 5)
    q = rand(ks[0], (b, hq, d), dtype)
    k_pool = rand(ks[1], (p_phys, page, hkv, d), dtype)
    v_pool = rand(ks[2], (p_phys, page, hkv, d), dtype)
    # unique physical slots per request, some invalid
    rng = np.random.RandomState(0)
    pt = np.full((b, n_pages), -1, np.int32)
    seq_lens = np.zeros((b,), np.int32)
    for i in range(b):
        n_valid = max(1, int(n_pages * frac_valid) - (i % 2))
        pt[i, :n_valid] = rng.choice(p_phys, n_valid, replace=False)
        seq_lens[i] = (n_valid - 1) * page + rng.randint(1, page + 1)
    return q, k_pool, v_pool, jnp.asarray(pt), jnp.asarray(seq_lens)


def paged_oracle(q, k_pool, v_pool, pt, seq_lens):
    """Dense gather + masked softmax (independent of both impls)."""
    b, hq, d = q.shape
    p, page, hkv, _ = k_pool.shape
    n = pt.shape[1]
    n_rep = hq // hkv
    safe = jnp.maximum(pt, 0)
    k = k_pool[safe].reshape(b, n * page, hkv, d).astype(jnp.float32)
    v = v_pool[safe].reshape(b, n * page, hkv, d).astype(jnp.float32)
    k = jnp.repeat(k, n_rep, 2)
    v = jnp.repeat(v, n_rep, 2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), k) / np.sqrt(d)
    pos = jnp.arange(n * page)
    ok = (jnp.repeat(pt >= 0, page, 1)) & (pos[None] < seq_lens[:, None])
    s = jnp.where(ok[:, None], s, -1e30)
    p_ = jax.nn.softmax(s, -1)
    return jnp.einsum("bht,bthd->bhd", p_, v)


class TestPagedAttention:
    @pytest.mark.parametrize("b,hq,hkv,d,page,n_pages", [
        (2, 4, 4, 64, 16, 8),
        (3, 8, 2, 64, 32, 4),     # GQA 4x
        (1, 4, 1, 32, 8, 16),     # MQA
    ])
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_pallas_vs_oracle(self, b, hq, hkv, d, page, n_pages, dtype):
        from repro.kernels.paged_attention import ops
        dt = jnp.dtype(dtype)
        q, kp, vp, pt, sl = make_paged_case(
            jax.random.PRNGKey(3), b, hq, hkv, d, 64, page, n_pages, dt)
        got, (m, l) = ops.paged_attention(q, kp, vp, pt, sl, interpret=True)
        want = paged_oracle(q, kp, vp, pt, sl)
        tol = 3e-2 if dtype == "bfloat16" else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=tol, rtol=tol)
        assert np.isfinite(np.asarray(m)).all()
        assert (np.asarray(l) > 0).all()

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_ref_vs_oracle(self, dtype):
        from repro.kernels.paged_attention import ref
        dt = jnp.dtype(dtype)
        q, kp, vp, pt, sl = make_paged_case(
            jax.random.PRNGKey(4), 2, 8, 2, 64, 64, 16, 8, dt)
        got, _ = ref.paged_attention(q, kp, vp, pt, sl, pages_per_step=3)
        want = paged_oracle(q, kp, vp, pt, sl)
        tol = 3e-2 if dtype == "bfloat16" else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=tol, rtol=tol)

    def test_pallas_matches_ref_stats(self):
        """ship_compute needs (m, l): both impls must agree on them."""
        from repro.kernels.paged_attention import ops, ref
        q, kp, vp, pt, sl = make_paged_case(
            jax.random.PRNGKey(5), 2, 4, 2, 32, 32, 8, 6, jnp.float32)
        got, (m1, l1) = ops.paged_attention(q, kp, vp, pt, sl, interpret=True)
        want, (m2, l2) = ref.paged_attention(q, kp, vp, pt, sl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5)


class TestMLAPagedAttention:
    def mla_oracle(self, ql, qr, pool, pt, sl):
        b, h, r = ql.shape
        dr = qr.shape[-1]
        p, page, rd = pool.shape
        n = pt.shape[1]
        lat = pool[jnp.maximum(pt, 0)].reshape(b, n * page, rd)
        lat = lat.astype(jnp.float32)
        s = (jnp.einsum("bhr,btr->bht", ql.astype(jnp.float32), lat[..., :r])
             + jnp.einsum("bhr,btr->bht", qr.astype(jnp.float32), lat[..., r:])
             ) / np.sqrt(r + dr)
        pos = jnp.arange(n * page)
        ok = jnp.repeat(pt >= 0, page, 1) & (pos[None] < sl[:, None])
        s = jnp.where(ok[:, None], s, -1e30)
        p_ = jax.nn.softmax(s, -1)
        return jnp.einsum("bht,btr->bhr", p_, lat[..., :r])

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("impl", ["pallas", "ref"])
    def test_vs_oracle(self, dtype, impl):
        from repro.kernels.paged_attention import ops, ref
        dt = jnp.dtype(dtype)
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        b, h, r, dr, page, n_pages, p_phys = 2, 4, 64, 16, 8, 6, 32
        ql = rand(ks[0], (b, h, r), dt)
        qr = rand(ks[1], (b, h, dr), dt)
        pool = rand(ks[2], (p_phys, page, r + dr), dt)
        rng = np.random.RandomState(1)
        pt = np.full((b, n_pages), -1, np.int32)
        sl = np.zeros((b,), np.int32)
        for i in range(b):
            nv = 3 + i
            pt[i, :nv] = rng.choice(p_phys, nv, replace=False)
            sl[i] = (nv - 1) * page + 3
        pt, sl = jnp.asarray(pt), jnp.asarray(sl)
        if impl == "pallas":
            got, _ = ops.mla_paged_attention(ql, qr, pool, pt, sl,
                                             interpret=True)
        else:
            got, _ = ref.mla_paged_attention(ql, qr, pool, pt, sl,
                                             pages_per_step=2)
        want = self.mla_oracle(ql, qr, pool, pt, sl)
        tol = 3e-2 if dtype == "bfloat16" else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# page gather / scatter
# ---------------------------------------------------------------------------


class TestPageGatherScatter:
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
    @pytest.mark.parametrize("feat", [(4,), (2, 8)])
    def test_gather(self, dtype, feat):
        from repro.kernels.page_gather import ops, ref
        dt = jnp.dtype(dtype)
        pool = jnp.arange(np.prod((16, 8) + feat)).reshape(
            (16, 8) + feat).astype(dt)
        ids = jnp.asarray([3, -1, 0, 15, 7], jnp.int32)
        got = ops.page_gather(pool, ids, interpret=True)
        want = ref.page_gather(pool, ids)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_scatter(self, dtype):
        from repro.kernels.page_gather import ops, ref
        dt = jnp.dtype(dtype)
        pool = jnp.zeros((16, 8, 4), dt)
        ids = jnp.asarray([2, -1, 9], jnp.int32)
        pages = jnp.arange(3 * 8 * 4).reshape(3, 8, 4).astype(dt)
        got = ops.page_scatter(pool, ids, pages, interpret=True)
        want = ref.page_scatter(pool, ids, pages)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_roundtrip(self):
        from repro.kernels.page_gather import ops
        pool = jnp.zeros((8, 4, 2), jnp.float32)
        pages = jnp.ones((2, 4, 2), jnp.float32) * jnp.asarray(
            [[[3.0]], [[5.0]]])
        ids = jnp.asarray([1, 6], jnp.int32)
        pool = ops.page_scatter(pool, ids, pages, interpret=True)
        back = ops.page_gather(pool, ids, interpret=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(pages))


# ---------------------------------------------------------------------------
# directory probe
# ---------------------------------------------------------------------------


class TestDirectoryProbe:
    def test_probe_matches_directory_and_ref(self):
        from repro.kernels.directory_probe import ops
        cfg = dirx.DirectoryConfig(capacity=64, num_nodes=4, max_probe=64)
        d = dirx.init_directory(cfg)
        # install 20 pages, remove 5 (tombstones in probe chains)
        descs = D.make_batch(np.arange(20) % 3 + 1, np.arange(20), 0)
        d, _ = dirx.lookup_and_install(d, descs, max_probe=64)
        kill = D.make_batch(np.arange(5) % 3 + 1, np.arange(5), 0)
        d, _ = dirx.abort_install(d, kill, max_probe=64)

        queries = jnp.asarray(
            [[s % 3 + 1, s] for s in range(25)], jnp.int32)
        got = ops.probe_batch(d.keys, queries, max_probe=64, interpret=True)
        want = ops.probe_batch_ref(d.keys, queries, max_probe=64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

        got = np.asarray(got)
        # removed keys must not be found; live keys must be
        for i in range(25):
            if 5 <= i < 20:
                assert got[i, 0] >= 0, f"live key {i} not found"
            else:
                assert got[i, 0] == -1, f"dead/absent key {i} found"
                assert got[i, 1] >= 0, "insert slot expected"

    def test_probe_agrees_with_install_slots(self):
        """Probe must return exactly the slot lookup_and_install used."""
        from repro.kernels.directory_probe import ops
        cfg = dirx.DirectoryConfig(capacity=32, num_nodes=2, max_probe=32)
        d = dirx.init_directory(cfg)
        streams = np.asarray([7, 7, 7, 9, 9], np.int32)
        pages = np.asarray([0, 1, 2, 0, 1], np.int32)
        d, _ = dirx.lookup_and_install(
            d, D.make_batch(streams, pages, 1), max_probe=32)
        q = jnp.stack([jnp.asarray(streams), jnp.asarray(pages)], -1)
        res = np.asarray(ops.probe_batch(d.keys, q, max_probe=32,
                                         interpret=True))
        keys = np.asarray(d.keys)
        for i in range(5):
            slot = res[i, 0]
            assert slot >= 0
            assert keys[slot, 0] == streams[i] and keys[slot, 1] == pages[i]
