"""Async data plane tests: overlap must be unobservable at settle points.

The tentpole property: running the protocol with ``async_data_plane=True``
(migration KV copies and writeback captures riding COPY/FLUSH descriptor
lanes, deferred source frees, pipelined shard transfers) must settle to
exactly the same directory state, the same per-key store bytes, and the
same writeback decisions as the legacy synchronous stepping — under
arbitrary interleavings of reads, writes, reclamation, migration, ACK
delivery, pump/flush, and node failure, with the refimpl shadow oracle
checking every intermediate step.

Also covers the teardown races the deferral opens up:
  * drain_node's overlapped evacuation rounds (COPY lanes pending while the
    next chunk's DIR_INVs are in flight) — zero lost committed dirty bytes
  * engine failover racing an issued-but-uninstalled page prefetch — the
    stale install is dropped by the generation check
  * reclamation racing a lane-carried flush — a refault settles the lane
    before reading, so read-your-writes holds through the pending capture
"""

import numpy as np
import pytest

try:  # dev-only dep: collection must never hard-fail without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core import pagepool as pp
from repro.core.dpc_cache import DistributedKVCache

NODES = 4


def make_kv(pool_pages=8, num_nodes=NODES, **kw) -> DistributedKVCache:
    dpc = DPCConfig(page_size=8, pool_pages_per_shard=pool_pages,
                    shadow_oracle=True, migrate_threshold=0,
                    directory_capacity=512, **kw)
    return DistributedKVCache(dpc, num_nodes)


# ---------------------------------------------------------------------------
# lane encoding: roundtrip + directory inertness
# ---------------------------------------------------------------------------


class TestLaneEncoding:
    def test_copy_roundtrip(self):
        triples = [(2, 17, 42), (0, 5, 11), (3, 0, 7)]
        rows = D.encode_copies(triples)
        assert rows.shape == (3, D.N_LANES)
        assert (rows[:, D.LANE_STREAM] == int(D.COPY)).all()
        assert D.decode_copies(rows) == triples

    def test_flush_roundtrip(self):
        triples = [(1, 99, 3), (2, 7, 0)]
        rows = D.encode_flushes(triples)
        assert (rows[:, D.LANE_STREAM] == int(D.FLUSH)).all()
        assert D.decode_flushes(rows) == triples

    def test_decoders_ignore_foreign_rows(self):
        """COPY/FLUSH/SHOOTDOWN rows share one batch; each decoder must
        pick out only its own kind."""
        mixed = np.concatenate([
            D.encode_copies([(1, 2, 3)]),
            D.encode_flushes([(2, 9, 1)]),
            D.encode_shootdowns([(0, 4, 5)]),
            np.asarray(D.make_batch([7], [0], [1])),
        ])
        assert D.decode_copies(mixed) == [(1, 2, 3)]
        assert D.decode_flushes(mixed) == [(2, 9, 1)]
        assert D.decode_shootdowns(mixed) == [(0, 4, 5)]

    def test_lane_rows_are_directory_inert(self):
        """A batch carrying COPY and FLUSH lanes through a directory opcode
        must behave exactly as the batch without them: same statuses for the
        real rows, no phantom entries installed."""
        cfg = dirx.DirectoryConfig(capacity=64, num_nodes=NODES, max_probe=64)
        real = np.asarray(D.make_batch([9, 10], [0, 1], [2]))
        lanes = np.concatenate([D.encode_copies([(1, 3, 12)]),
                                D.encode_flushes([(0, 9, 0)])])
        d_plain, res_plain = dirx.lookup_and_install(
            dirx.init_directory(cfg), jnp.asarray(real), max_probe=64)
        d_lane, res_lane = dirx.lookup_and_install(
            dirx.init_directory(cfg),
            jnp.asarray(np.concatenate([real, lanes])), max_probe=64)
        np.testing.assert_array_equal(np.asarray(res_plain)[:2],
                                      np.asarray(res_lane)[:2])
        assert dirx.to_host_dict(d_plain, cfg) == dirx.to_host_dict(d_lane,
                                                                    cfg)


# ---------------------------------------------------------------------------
# equivalence property: async settles to the sync reference state
# ---------------------------------------------------------------------------


N_KEYS = 6
OPS = ["read", "read", "write", "write", "flush_writes", "reclaim_begin",
       "migrate_begin", "ack_one", "reclaim_finish", "migrate_finish",
       "pump", "barrier", "fail"]


def _run_interleaving(events, async_dp: bool):
    """Drive one op interleaving over a storage-integrated cache and return
    the pfn-normalized settled state.  Frame numbers are normalized away:
    deferred frees legally reorder the free stack, so the *same* settled
    protocol state lands in different physical slots between modes."""
    kv = make_kv(pool_pages=16, storage_backend="memory",
                 writeback_async=False, writeback_batch=2,
                 async_data_plane=async_dp)
    proto = kv.proto
    keys = [(11, p) for p in range(N_KEYS)]
    frames = {}     # pfn -> bytes (the simulated data plane)
    expected = {}   # key -> last-written bytes (the model)
    kv.set_page_bytes_fn(lambda key, pfn: frames.get(pfn))
    version = [0]
    failed = set()

    def fresh(key):
        version[0] += 1
        return np.full((4,), version[0], np.float32)

    def do_read(s, p, node):
        lk = kv.lookup([s], [p], node)[0]
        if lk.status == D.ST_FULL and async_dp:
            # a deferred source free can make the pool *transiently*
            # tighter than the sync schedule; settling and retrying makes
            # the allocation decisions line up again (the engine's analog
            # is the reclaim-retry loop in _alloc_page)
            proto.fence_data_lanes()
            lk = kv.lookup([s], [p], node)[0]
        if lk.status == D.ST_GRANT_E:
            if lk.refill is not None:
                np.testing.assert_array_equal(lk.refill, expected[(s, p)])
                frames[lk.page_id] = np.asarray(lk.refill)
            else:
                assert (s, p) not in expected, "committed bytes lost"
                data = fresh((s, p))
                frames[lk.page_id] = data
                expected[(s, p)] = data
            kv.commit([s], [p], node, [lk])

    def deliver_one_ack():
        for pend in (proto.pending_inv, proto.pending_mig):
            for key, info in pend.items():
                if info["waiting"]:
                    node = min(info["waiting"])
                    if pend is proto.pending_inv:
                        proto.reclaim_ack(key[0], key[1], node)
                    else:
                        proto.migrate_ack(key[0], key[1], node)
                    return

    def copy_fn(key, src_pfn, dst_pfn):
        if src_pfn in frames:
            frames[dst_pfn] = frames[src_pfn]

    for op, ki, node in events:
        s, p = keys[ki]
        if node in failed:
            continue
        if op == "read":
            do_read(s, p, node)
        elif op == "write":
            ent = proto.directory_view().get((s, p))
            if ent is not None and ent[0] == dirx.O and \
                    ent[1] not in failed:
                owner, pfn = ent[1], ent[3]
                if proto.mark_dirty([s], [p], owner)[0] == D.ST_OK:
                    data = fresh((s, p))
                    frames[pfn] = data
                    expected[(s, p)] = data
        elif op == "flush_writes":
            proto.flush_dirty_marks()
        elif op == "reclaim_begin":
            proto.reclaim_begin(node, want=1)
        elif op == "migrate_begin":
            proto.migrate_begin([((s, p), node)])
        elif op == "ack_one":
            deliver_one_ack()
        elif op == "reclaim_finish":
            proto.reclaim_finish(node)
        elif op == "migrate_finish":
            proto.migrate_finish(copy_fn=copy_fn)
        elif op == "pump":
            kv.pump_storage(1)
        elif op == "barrier":
            kv.flush()
        elif op == "fail":
            if node not in failed and len(failed) < NODES - 2:
                failed.add(node)
                kv.fail_node(node)
                # re-baseline the model at the durable tier: a key whose
                # entry died with the node loses its unflushed bytes (in
                # both modes — fail_node settles its lanes first) and a
                # refault can only recover the queue/store version
                view_after = proto.directory_view()
                for key in list(expected):
                    if key not in view_after:
                        data = kv._storage_read(key)
                        if data is None:
                            del expected[key]
                        else:
                            expected[key] = np.asarray(data)
        proto.oracle.check_invariants()

    # settle: drain every in-flight transaction, then every obligation
    for _ in range(NODES * N_KEYS):
        if not any(i["waiting"] for i in proto.pending_inv.values()) and \
                not any(i["waiting"] for i in proto.pending_mig.values()):
            break
        deliver_one_ack()
    for node in range(NODES):
        proto.reclaim_finish(node)
    proto.migrate_finish(copy_fn=copy_fn)
    proto.flush_dirty_marks()
    proto.fence_data_lanes()
    kv.flush()

    assert proto.counters["oracle_mismatches"] == 0
    assert proto.counters["flush_before_free_violations"] == 0
    assert kv.writeback.pending_count() == 0

    # every written key must still read back its last bytes (from a live
    # frame, the queue — already flushed — or the durable store)
    reader = next(n for n in range(NODES) if n not in failed)
    for (s, p), want in expected.items():
        ent = proto.directory_view().get((s, p))
        if ent is not None and ent[0] == dirx.O:
            np.testing.assert_array_equal(frames[ent[3]], want)
        else:
            got = kv.store.read(s, p)
            assert got is not None, f"({s},{p}): bytes dropped"
            np.testing.assert_array_equal(got, want)

    norm_dir = {
        key: (ent[0], ent[1], frozenset(ent[2]), bool(ent[4]))
        for key, ent in proto.directory_view().items()
    }
    store = {key: tuple(np.asarray(kv.store.read(*key)).ravel().tolist())
             for key in expected if kv.store.read(*key) is not None}
    byte_view = {key: tuple(np.asarray(v).ravel().tolist())
                 for key, v in expected.items()}
    kv.close()
    return (norm_dir, store, byte_view,
            proto.counters["writebacks"],
            proto.counters["writebacks_committed"],
            proto.counters["migration_writebacks"],
            proto.counters["lost_dirty_pages"])


def _seeded_events(seed: int, n: int = 60):
    rng = np.random.default_rng(seed)
    return [(OPS[rng.integers(len(OPS))],
             int(rng.integers(N_KEYS)), int(rng.integers(NODES)))
            for _ in range(n)]


@pytest.mark.parametrize("seed", range(3))
def test_async_equals_sync_seeded(seed):
    """Tier-1 fixed-seed equivalence: lane-deferred copies/flushes must
    settle to the same directory, store, and writeback decisions as the
    synchronous reference mode (both oracle-clean throughout)."""
    events = _seeded_events(seed)
    assert _run_interleaving(events, async_dp=True) == \
        _run_interleaving(events, async_dp=False)


if HAVE_HYPOTHESIS:
    EVENTS = st.lists(
        st.tuples(
            st.sampled_from(OPS),
            st.integers(0, N_KEYS - 1),     # key index
            st.integers(0, NODES - 1),      # node
        ),
        min_size=1, max_size=50,
    )

    @pytest.mark.property
    @settings(deadline=None)  # example count comes from the profile
    @given(EVENTS)
    def test_async_equals_sync(events):
        """Hypothesis-driven search over the same interleaving space (with
        shrinking) — the slow/property tier's stronger version."""
        assert _run_interleaving(events, async_dp=True) == \
            _run_interleaving(events, async_dp=False)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_async_equals_sync():
        pass


# ---------------------------------------------------------------------------
# teardown races opened by the deferral
# ---------------------------------------------------------------------------


class TestDrainRacesOverlappedEvacuation:
    def test_overlapped_drain_rounds_lose_nothing(self):
        """drain_node evacuates in overlapped MIGRATE rounds: chunk k+1's
        DIR_INVs go out while chunk k's COPY lanes are still pending.  All
        committed bytes (dirty ones included) must survive the hand-offs."""
        kv = make_kv(pool_pages=192, storage_backend="memory",
                     writeback_async=False, writeback_batch=8)
        proto = kv.proto
        n = 150   # > 2 evacuation chunks of 64
        streams, pages = [23] * n, list(range(n))
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(pfn))
        lks = kv.lookup(streams, pages, 0)
        for p, lk in zip(pages, lks):
            frames[lk.page_id] = np.full((4,), 1000 + p, np.float32)
        kv.commit(streams, pages, 0, lks)
        # dirty a third of them: their evacuation must checkpoint bytes
        dirty = pages[::3]
        proto.mark_dirty([23] * len(dirty), dirty, 0)
        proto.flush_dirty_marks()

        def copy_fn(key, src_pfn, dst_pfn):
            frames[dst_pfn] = frames[src_pfn]

        st = kv.drain_node(0, copy_fn=copy_fn)
        assert proto.counters["lane_copies"] > 0      # lanes actually used
        assert st["migrated"] == n
        kv.proto.fence_data_lanes()
        kv.flush()
        view = proto.directory_view()
        for p in pages:
            ent = view[(23, p)]
            assert ent[0] == dirx.O and ent[1] != 0
            np.testing.assert_array_equal(
                frames[ent[3]], np.full((4,), 1000 + p, np.float32))
        for p in dirty:   # checkpoints are durable
            np.testing.assert_array_equal(
                kv.store.read(23, p), np.full((4,), 1000 + p, np.float32))
        assert proto.counters["lost_dirty_pages"] == 0
        assert proto.counters["oracle_mismatches"] == 0
        kv.close()


class TestReclaimRacesLaneFlush:
    def test_refault_settles_pending_flush_lane(self):
        """A dirty eviction's byte capture rides a FLUSH lane.  A refault
        from another node racing that lane must still read the committed
        bytes — _storage_read settles the lanes before touching the queue
        or the store (read-your-writes through the deferral)."""
        kv = make_kv(pool_pages=4, storage_backend="memory",
                     writeback_async=False, writeback_batch=4)
        proto = kv.proto
        frames = {}
        kv.set_page_bytes_fn(lambda key, pfn: frames.get(pfn))
        lks = kv.lookup([31], [0], 0)
        frames[lks[0].page_id] = np.full((4,), 77.0, np.float32)
        kv.commit([31], [0], 0, lks)
        proto.mark_dirty([31], [0], 0)
        proto.flush_dirty_marks()

        proto.reclaim_sync(0, want=1)
        # capture deferred: lane pending, nothing in the queue yet, but the
        # frame is already pinned with its flush token registered
        assert proto.counters["lane_flushes"] == 1
        assert kv.writeback.pending_count() == 0
        assert int(pp.num_writeback(proto.state.pools[0])) == 1
        assert len(proto._wb_outstanding) == 1

        lk = kv.lookup([31], [0], 1)[0]   # refault races the pending lane
        assert lk.status == D.ST_GRANT_E and lk.refill is not None
        np.testing.assert_array_equal(lk.refill,
                                      np.full((4,), 77.0, np.float32))
        kv.flush()
        assert proto.counters["lost_dirty_pages"] == 0
        assert proto.counters["flush_before_free_violations"] == 0
        assert proto.counters["oracle_mismatches"] == 0
        kv.close()


# ---------------------------------------------------------------------------
# engine level: prefetch generation check + async == sync tokens
# ---------------------------------------------------------------------------


def _make_engine(async_dp: bool, num_nodes: int = 2):
    import jax
    from repro.configs import get_smoke_arch
    from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
    from repro.models import registry
    from repro.models.spec import init_params
    from repro.serving.engine import ServingEngine

    arch = get_smoke_arch("granite-3-2b")
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(0))
    run = RunConfig(arch=arch, shape=ShapeConfig("s", 64, 4, "decode"),
                    mesh=MeshConfig((1,), ("data",)),
                    dpc=DPCConfig(page_size=8, pool_pages_per_shard=64,
                                  shadow_oracle=True,
                                  async_data_plane=async_dp))
    kv = DistributedKVCache(run.dpc, num_nodes)
    return ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                         kv_cache=kv), kv


class TestEngineAsyncDataPlane:
    PROMPT = list(range(11, 27))   # 2 full pages

    def test_async_tokens_equal_sync_tokens(self):
        """The overlapped step must be numerically identical to the sync
        reference step — same prompts, same params, same greedy tokens."""
        outs = {}
        hits = {}
        for mode in (True, False):
            eng, kv = _make_engine(mode)
            eng.submit(self.PROMPT, max_new_tokens=12)
            eng.submit(self.PROMPT[:8], max_new_tokens=12)
            finished = {}

            for _ in range(200):
                before = {id(r): r for r in eng.active if r is not None}
                n = eng.step()
                for r in before.values():
                    if r.done:
                        finished[r.rid] = tuple(r.generated)
                if n == 0:
                    break
            assert not any(r is not None for r in eng.active)
            assert set(finished) == {0, 1}
            assert kv.proto.counters["oracle_mismatches"] == 0
            outs[mode] = finished
            hits[mode] = eng.prefetch_hits
        assert outs[True] == outs[False]
        assert hits[True] > 0      # the overlap actually engaged
        assert hits[False] == 0    # reference mode never prefetches

    def test_failover_drops_issued_prefetch_as_stale(self):
        """A prefetch issued during the overlap window races fail_node: the
        generation check must drop the stale install and re-allocate through
        the post-failover directory — no corrupt page table, full output."""
        eng, kv = _make_engine(True)
        eng.submit(self.PROMPT, max_new_tokens=24)
        fired = False
        for _ in range(200):
            n = eng.step()
            if eng._prefetch and not fired:
                fired = True
                eng.fail_node(1)   # bumps the generation mid-flight
            if n == 0:
                break
        assert fired, "no prefetch was ever in flight"
        assert eng.prefetch_stale >= 1
        assert kv.proto.counters["oracle_mismatches"] == 0
        # table integrity: every named frame belongs to a live pool slot
        assert (eng._pt[eng._pt >= 0] <
                kv.dpc.pool_pages_per_shard * 2).all()


# ---------------------------------------------------------------------------
# prediction-sourced prefetches: async == sync, stale-generation drops
# ---------------------------------------------------------------------------


def _make_prediction_cluster(async_dp: bool, num_nodes: int = 2):
    import jax
    from repro.configs import get_smoke_arch
    from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
    from repro.models import registry
    from repro.models.spec import init_params
    from repro.serving.engine import ServingEngine

    arch = get_smoke_arch("granite-3-2b")
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(0))
    run = RunConfig(arch=arch, shape=ShapeConfig("s", 64, 4, "decode"),
                    mesh=MeshConfig((1,), ("data",)),
                    dpc=DPCConfig(mode="dpc", page_size=8,
                                  pool_pages_per_shard=512,
                                  shadow_oracle=True,
                                  async_data_plane=async_dp))
    kv = DistributedKVCache(run.dpc, num_nodes)
    engines = [ServingEngine(run, params, max_batch=2, max_pages_per_seq=10,
                             node=i, num_nodes=num_nodes, kv_cache=kv)
               for i in range(num_nodes)]
    return engines, kv, arch


def _prediction_workload(engines, arch, seed=7):
    """3 shared 32-token prefixes, private 5-token tails, 6 requests per
    node — deeper than max_batch, so later requests sit queued across
    step boundaries and get predicted in the overlap window."""
    rng = np.random.default_rng(seed)
    hots = [rng.integers(0, arch.vocab_size, 32).tolist() for _ in range(3)]
    for i in range(6):
        engines[0].submit(
            hots[i % 3] + rng.integers(0, arch.vocab_size, 5).tolist(),
            max_new_tokens=2)
    for i in range(6):
        engines[1].submit(
            hots[i // 2] + rng.integers(0, arch.vocab_size, 5).tolist(),
            max_new_tokens=2)


@pytest.mark.slow
class TestPredictionAsyncEquivalence:
    def test_predicted_promotions_async_equal_sync(self):
        """Prediction-sourced promotions run inside the overlap window in
        async mode and serialized after the decode in sync mode — the
        settled tokens, prediction accounting, and promotion counters must
        be identical (the async ≡ sync property extended to the predictive
        path)."""
        outs = {}
        for mode in (True, False):
            engines, kv, arch = _make_prediction_cluster(mode)
            _prediction_workload(engines, arch)
            tokens = {}
            for _ in range(500):
                before = [(e.node, r) for e in engines for r in e.active
                          if r is not None]
                n = sum(e.step() for e in engines)
                for node, r in before:
                    if r.done:
                        tokens[(node, r.rid)] = tuple(r.generated)
                if n == 0:
                    break
            assert kv.proto.counters["oracle_mismatches"] == 0
            pred = sum(e.prefix_stats.pages_predicted for e in engines)
            hits = sum(e.prefix_stats.predict_hits for e in engines)
            assert pred > 0 and hits == pred    # nothing evicted under us
            outs[mode] = (tokens, pred, hits,
                          kv.proto.counters["promotes"],
                          kv.proto.counters["promote_hits"])
        assert outs[True] == outs[False]

    def test_generation_bump_drops_queued_prediction(self):
        """A prediction issued for a queued request races a failover: the
        generation check at admit must count the whole prediction stale
        and fall through to ordinary lookups — no corrupt reuse, full
        output, oracle clean."""
        engines, kv, arch = _make_prediction_cluster(True, num_nodes=3)
        _prediction_workload(engines, arch)
        bumped = False
        done = {}
        for _ in range(500):
            before = [(e.node, r) for e in engines[:2] for r in e.active
                      if r is not None]
            n = sum(e.step() for e in engines[:2])
            for node, r in before:
                if r.done:
                    done[(node, r.rid)] = tuple(r.generated)
            if not bumped and any(r.predicted for r in engines[1].queue):
                # node 2 is idle: failing it bumps every engine's view of
                # the membership generation without disturbing ownership
                for e in engines[:2]:
                    e.fail_node(2)
                bumped = True
            if n == 0:
                break
        assert bumped, "no prediction was ever pending on a queued request"
        stale = sum(e.prefix_stats.predict_stale for e in engines)
        assert stale > 0
        assert kv.proto.counters["oracle_mismatches"] == 0
        assert len(done) == 12 and all(len(g) == 2 for g in done.values())
