"""Per-architecture smoke tests (reduced configs, CPU).

Every assigned arch: one train step (loss finite, grads flow) + one decode
step (logit shapes, no NaNs).  For representative archs we additionally check
prefill->decode consistency through the paged cache: decoding token S+1 after
installing prefill KV pages must match running prefill over S+1 tokens.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_arch
from repro.configs.base import DPCConfig
from repro.models import registry
from repro.models.cache import (HybridCache, MLAPagedCache, PagedKVCache,
                                RWKVCache, VLMCache)
from repro.models.spec import abstract_params, init_params

SMOKE_DPC = DPCConfig(page_size=8, pool_pages_per_shard=64)


def assert_decode_matches_prefill(logits_dec, logits_full, *, f32=False):
    """Decode-through-the-paged-cache must reproduce prefill's last-token
    logits.  In bf16 the two computation orders drift by accumulated rounding
    (bounded), but greedy decisions must agree exactly; with f32 params the
    comparison is tight (algorithmic equivalence)."""
    a = np.asarray(logits_full, np.float32)
    d = np.asarray(logits_dec, np.float32)
    if f32:
        np.testing.assert_allclose(d, a, atol=2e-3, rtol=2e-3)
    else:
        np.testing.assert_allclose(d, a, atol=0.5, rtol=0.1)
    assert (a.argmax(-1) == d.argmax(-1)).all(), "greedy decisions diverged"



def setup_arch(arch_id, seed=0):
    cfg = get_smoke_arch(arch_id)
    api = registry.get_model(cfg)
    params = init_params(api.specs(cfg), jax.random.PRNGKey(seed))
    return cfg, api, params


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_finite(arch_id):
    cfg, api, params = setup_arch(arch_id)
    batch = registry.make_train_batch(cfg, 2, 24, jax.random.PRNGKey(1))

    def loss_fn(p):
        loss, metrics = api.train_loss(p, cfg, batch, remat=False)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch_id}: loss not finite"
    flat = jax.tree.leaves(grads)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert all(np.isfinite(n) for n in norms), f"{arch_id}: NaN grads"
    assert sum(norms) > 0, f"{arch_id}: no gradient signal"


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_remat_matches_no_remat(arch_id):
    cfg, api, params = setup_arch(arch_id)
    batch = registry.make_train_batch(cfg, 1, 16, jax.random.PRNGKey(2))
    l1, _ = api.train_loss(params, cfg, batch, remat=False)
    l2, _ = api.train_loss(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_shapes(arch_id):
    cfg, api, params = setup_arch(arch_id)
    b, max_pages = 2, 8
    cache = api.init_cache(cfg, SMOKE_DPC, b, max_pages)
    # give paged caches a first page per request
    cache = _assign_first_pages(cache, b)
    tokens = (jnp.zeros((b, cfg.audio.num_codebooks), jnp.int32)
              if cfg.family == "audio" else jnp.zeros((b,), jnp.int32))
    positions = jnp.zeros((b,), jnp.int32)
    logits, cache2 = api.decode_step(params, cfg, tokens, positions, cache)
    v = (registry.greedy_sample(logits))
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id
    if cfg.family == "audio":
        assert logits.shape[0] == b and logits.shape[1] == 4
    else:
        assert logits.shape[0] == b
    # seq_lens advanced for paged caches
    pc = _paged_of(cache2)
    if pc is not None:
        assert (np.asarray(pc.seq_lens) == 1).all()


def _paged_of(cache):
    if isinstance(cache, (PagedKVCache, MLAPagedCache)):
        return cache
    if isinstance(cache, HybridCache):
        return cache.attn
    if isinstance(cache, VLMCache):
        return cache.self_attn
    return None


def _assign_first_pages(cache, b):
    pc = _paged_of(cache)
    if pc is None:
        return cache
    pt = np.asarray(pc.page_table).copy()
    pt[:, 0] = np.arange(b)
    pc2 = pc._replace(page_table=jnp.asarray(pt),
                      append_slot=jnp.arange(b, dtype=jnp.int32))
    if isinstance(cache, HybridCache):
        return cache._replace(attn=pc2)
    if isinstance(cache, VLMCache):
        return cache._replace(self_attn=pc2)
    return pc2


# ---------------------------------------------------------------------------
# prefill -> decode consistency through the paged cache
# ---------------------------------------------------------------------------


def _install_prefill_kv(cfg, cache, kv, page_size):
    """Pack prefill kv [L, 2, B, S, H, hd] (or latents [L, B, S, R]) into the
    pool: request b's page p -> slot b * n_pages + p."""
    pc = _paged_of(cache)
    if isinstance(cache, VLMCache):
        kv, cross = kv
    if isinstance(pc, MLAPagedCache):
        lat = kv                                  # [L, B, S, RD]
        l, b, s, rd = lat.shape
        n_pages = s // page_size
        pages = lat.reshape(l, b * n_pages, page_size, rd)
        pools = pc.latent_pools.at[:, :b * n_pages].set(
            pages.astype(pc.latent_pools.dtype))
        pc2 = pc._replace(latent_pools=pools)
    else:
        k, v = kv[:, 0], kv[:, 1]                 # [L, B, S, H, hd]
        l, b, s, h, hd = k.shape
        n_pages = s // page_size
        kp = pc.k_pools.at[:, :b * n_pages].set(
            k.reshape(l, b * n_pages, page_size, h, hd).astype(
                pc.k_pools.dtype))
        vp = pc.v_pools.at[:, :b * n_pages].set(
            v.reshape(l, b * n_pages, page_size, h, hd).astype(
                pc.v_pools.dtype))
        pc2 = pc._replace(k_pools=kp, v_pools=vp)

    pt = np.full(np.asarray(pc.page_table).shape, -1, np.int32)
    for bb in range(b):
        for p in range(n_pages + 1):              # +1: page for new tokens
            if p < pt.shape[1]:
                pt[bb, p] = bb * n_pages + p if p < n_pages else \
                    b * n_pages + bb
    pc2 = pc2._replace(
        page_table=jnp.asarray(pt),
        seq_lens=jnp.full((b,), s, jnp.int32),
        append_slot=jnp.asarray(
            [b * n_pages + bb for bb in range(b)], jnp.int32))
    return pc2


@pytest.mark.parametrize("arch_id", [
    "granite-3-2b", "qwen3-1.7b", "deepseek-v2-lite-16b",
    "qwen3-moe-235b-a22b", "musicgen-large",
])
def test_prefill_decode_consistency_lm(arch_id):
    cfg, api, params = setup_arch(arch_id)
    if cfg.moe is not None:
        # expert-capacity drops legitimately differ between a 32-token
        # prefill dispatch and a 1-token decode dispatch; disable drops so
        # the comparison isolates the cache datapath
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
        params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 16
    page = SMOKE_DPC.page_size
    batch = registry.make_train_batch(cfg, b, s + 1, jax.random.PRNGKey(3))
    tokens_full = batch["tokens"]
    tokens_pre = tokens_full[..., :s]

    logits_pre, kv = api.prefill(params, cfg, {"tokens": tokens_pre},
                                 remat=False)
    logits_full, _ = api.prefill(params, cfg, {"tokens": tokens_full},
                                 remat=False)

    cache = api.init_cache(cfg, SMOKE_DPC, b, max_pages=4)
    pc = _install_prefill_kv(cfg, cache, kv, page)
    tok_last = tokens_full[..., s]
    positions = jnp.full((b,), s, jnp.int32)
    logits_dec, cache2 = api.decode_step(params, cfg, tok_last, positions, pc)

    assert_decode_matches_prefill(logits_dec, logits_full)


@pytest.mark.slow
def test_prefill_decode_consistency_rwkv():
    cfg, api, params = setup_arch("rwkv6-3b")
    b, s = 2, 16
    batch = registry.make_train_batch(cfg, b, s + 1, jax.random.PRNGKey(4))
    tokens_full = batch["tokens"]
    logits_full, _ = api.prefill(params, cfg, {"tokens": tokens_full},
                                 remat=False)
    # decode token-by-token from scratch; state carries everything
    cache = api.init_cache(cfg, SMOKE_DPC, b, max_pages=4)
    from repro.models import lm as lm_mod
    from repro.models import layers as L
    x = tokens_full
    # run prefill for s tokens via forward, grabbing states
    from repro.models import rwkv6 as r6
    positions = jnp.broadcast_to(jnp.arange(s + 1, dtype=jnp.int32),
                                 (b, s + 1))
    logits = None
    for i in range(s + 1):
        logits, cache = api.decode_step(params, cfg, x[:, i],
                                        jnp.full((b,), i, jnp.int32), cache)
    assert_decode_matches_prefill(logits, logits_full)


@pytest.mark.slow
def test_prefill_decode_consistency_hybrid():
    cfg, api, params = setup_arch("zamba2-1.2b")
    b, s = 2, 16
    page = SMOKE_DPC.page_size
    batch = registry.make_train_batch(cfg, b, s + 1, jax.random.PRNGKey(5))
    tokens_full = batch["tokens"]
    logits_full, _, _ = api.prefill(params, cfg, {"tokens": tokens_full},
                                    remat=False)
    _, kv, (conv, ssd) = api.prefill(params, cfg,
                                     {"tokens": tokens_full[:, :s]},
                                     remat=False)
    cache = api.init_cache(cfg, SMOKE_DPC, b, max_pages=4)
    pc = _install_prefill_kv(cfg, cache._replace(), kv, page)
    from repro.models.cache import SSMCache
    cache = cache._replace(ssm=SSMCache(conv=conv, state=ssd), attn=pc)
    logits_dec, _ = api.decode_step(params, cfg, tokens_full[:, s],
                                    jnp.full((b,), s, jnp.int32), cache)
    assert_decode_matches_prefill(logits_dec, logits_full)


def test_prefill_decode_consistency_vlm():
    cfg, api, params = setup_arch("llama-3.2-vision-90b")
    b, s = 1, 16
    page = SMOKE_DPC.page_size
    key = jax.random.PRNGKey(6)
    batch = registry.make_train_batch(cfg, b, s + 1, key)
    tokens_full, img = batch["tokens"], batch["image_embeds"]
    logits_full, _, _ = api.prefill(
        params, cfg, {"tokens": tokens_full, "image_embeds": img},
        remat=False)
    _, kv, (ck, cv) = api.prefill(
        params, cfg, {"tokens": tokens_full[:, :s], "image_embeds": img},
        remat=False)
    cache = api.init_cache(cfg, SMOKE_DPC, b, max_pages=4)
    pc = _install_prefill_kv(cfg, cache, (kv, None), page)
    cache = cache._replace(self_attn=pc,
                           cross_k=ck.astype(cache.cross_k.dtype),
                           cross_v=cv.astype(cache.cross_v.dtype))
    logits_dec, _ = api.decode_step(params, cfg, tokens_full[:, s],
                                    jnp.full((b,), s, jnp.int32), cache)
    assert_decode_matches_prefill(logits_dec, logits_full)


def test_prefill_decode_consistency_f32_exact():
    """Algorithmic equivalence in f32 (no bf16 rounding): tight tolerance."""
    import dataclasses
    cfg = get_smoke_arch("granite-3-2b")
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              activation_dtype="float32")
    api = registry.get_model(cfg)
    params = init_params(api.specs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = registry.make_train_batch(cfg, b, s + 1, jax.random.PRNGKey(3))
    tokens_full = batch["tokens"]
    logits_pre, kv = api.prefill(params, cfg, {"tokens": tokens_full[:, :s]},
                                 remat=False)
    logits_full, _ = api.prefill(params, cfg, {"tokens": tokens_full},
                                 remat=False)
    import dataclasses as dc
    dpc_f32 = dc.replace(SMOKE_DPC, kv_dtype="float32")
    cache = api.init_cache(cfg, dpc_f32, b, max_pages=4)
    pc = _install_prefill_kv(cfg, cache, kv, dpc_f32.page_size)
    logits_dec, _ = api.decode_step(params, cfg, tokens_full[:, s],
                                    jnp.full((b,), s, jnp.int32), pc)
    assert_decode_matches_prefill(logits_dec, logits_full, f32=True)


def test_abstract_params_match_concrete():
    for arch_id in ARCH_IDS:
        cfg, api, params = setup_arch(arch_id)
        ab = abstract_params(api.specs(cfg))
        concrete_shapes = jax.tree.map(lambda a: (a.shape, str(a.dtype)),
                                       params)
        abstract_shapes = jax.tree.map(lambda a: (a.shape, str(a.dtype)), ab)
        assert concrete_shapes == abstract_shapes, arch_id
