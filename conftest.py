"""Repo-root pytest configuration: src on the path, test tiers, hypothesis
profiles.

Tiers (see pytest.ini / README):
  tier 1 (default)      ``pytest`` / ``pytest -m "not slow"`` — fast guard,
                        runs on every push, well under two minutes
  tier 2 (non-blocking) ``pytest -m "slow or property"`` — distributed
                        subprocess suites, full-arch train sweeps, and the
                        hypothesis property searches
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))


def pytest_collection_modifyitems(config, items):
    # property suites always belong to the slow tier: one marker to filter on
    for item in items:
        if "property" in item.keywords:
            item.add_marker(pytest.mark.slow)


try:
    from hypothesis import settings

    # example counts live here, not on the tests: CI shrinks the searches so
    # the non-blocking tier stays minutes-scale, dev keeps them thorough
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.register_profile("dev", max_examples=40, deadline=None)
    settings.load_profile("ci" if os.environ.get("CI") else "dev")
except ImportError:  # dev-only dep — tests guard their own imports
    pass
