"""End-to-end training driver: a ~100M-param qwen3-style model for a few
hundred steps on the synthetic pipeline, with checkpointing + the host-tier
DPC data cache.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(This drives repro.launch.train — the same driver that jits with production
mesh shardings on a real pod.)
"""

import argparse
import dataclasses
import sys

from repro.configs.base import ArchConfig


def arch_100m() -> ArchConfig:
    """~100M params: 12L, d=768, proper GQA + swiglu (qwen3 family)."""
    return ArchConfig(name="qwen3-100m", family="dense", num_layers=12,
                      d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
                      d_ff=2048, vocab_size=32000, qk_norm=True,
                      source="examples")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # register the example arch so --arch resolves it
    import repro.configs as C
    mod = type(sys)("example_arch")
    mod.config = arch_100m
    mod.smoke_config = arch_100m
    sys.modules["repro.configs._example"] = mod
    C._ARCH_MODULES["qwen3-100m"] = "repro.configs._example"

    from repro.launch import train
    return train.main([
        "--arch", "qwen3-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--n-micro", "2", "--lr", "6e-4", "--warmup", "30",
        "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
