"""Quickstart: the DPC page cache in five minutes.

Walks the paper's core protocol end to end on a 4-node cluster:
  1. a node misses -> directory grants E -> materialize -> COMMIT (owner)
  2. other nodes read the same page -> single-copy remote mappings (S)
  3. the owner reclaims under pressure -> TBI -> DIR_INV -> ACKs -> freed
  4. a node dies mid-invalidation -> liveness completes eviction anyway

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core.dpc_cache import DistributedKVCache


def main():
    dpc = DPCConfig(page_size=64, pool_pages_per_shard=8)
    kv = DistributedKVCache(dpc, num_nodes=4)

    print("== 1. node 0 misses on pages of stream 42 (ACC_MISS_ALLOC) ==")
    streams, pages = [42] * 3, [0, 1, 2]
    lks = kv.lookup(streams, pages, node=0)
    for p, lk in zip(pages, lks):
        print(f"  page {p}: status={D.STATUS_NAMES[lk.status]} "
              f"-> fill then commit (global page id {lk.page_id})")
    kv.commit(streams, pages, 0, lks)

    print("== 2. nodes 1..3 read the same pages (ACC_MISS_RMAP) ==")
    for node in (1, 2, 3):
        lks = kv.lookup(streams, pages, node)
        kinds = [D.STATUS_NAMES[lk.status] for lk in lks]
        print(f"  node {node}: {kinds} — remote mappings, no copies made")
    print(f"  cluster copies of each page: exactly 1 "
          f"(directory occupancy={kv.directory_occupancy()})")

    print("== 3. owner reclaims one page (deterministic invalidation) ==")
    victims, notify = kv.proto.reclaim_begin(0, want=1)
    (key, sharers), = notify.items()
    print(f"  LOCAL_INV on {key}; DIR_INV -> sharers {sharers}")
    for s in sharers[:-1]:
        kv.proto.reclaim_ack(key[0], key[1], s)
    freed, _ = kv.proto.reclaim_finish(0)
    print(f"  after {len(sharers)-1}/{len(sharers)} ACKs: freed={freed} "
          f"(blocked — deterministic reclamation waits)")

    print("== 4. the last sharer dies; liveness unblocks eviction ==")
    kv.fail_node(sharers[-1])
    freed, _ = kv.proto.reclaim_finish(0)
    print(f"  freed={freed} — eviction completed without the dead node")

    print("\nhit rate:", round(kv.hit_rate(), 3),
          "| counters:", kv.proto.counters)


if __name__ == "__main__":
    main()
