"""Fault-tolerance drill: training with a simulated node failure +
restart-from-checkpoint, and serving through the full membership
lifecycle — join, drain (planned departure with ownership evacuation), and
failover (heartbeat loss with re-homing from the durable backing store).

Run:  PYTHONPATH=src python examples/failover.py [--smoke]
"""

import argparse

import jax

from repro.configs import get_smoke_arch
from repro.configs.base import (DPCConfig, MeshConfig, RunConfig,
                                ShapeConfig)
from repro.core.dpc_cache import DistributedKVCache
from repro.models import registry
from repro.models.spec import init_params
from repro.runtime.liveness import Membership, elastic_mesh_shape
from repro.serving.engine import ServingEngine


def train_failover(smoke: bool = False):
    print("== training: kill node mid-run, restart from checkpoint ==")
    from repro.launch import train
    steps, kill_at = ("60", "30") if smoke else ("100", "60")
    train.main(["--arch", "qwen3-1.7b", "--steps", steps, "--batch", "4",
                "--seq", "64", "--ckpt-dir", "/tmp/repro_failover",
                "--ckpt-every", "25", "--kill-at", kill_at,
                "--log-every", "25"])


# per-phase summary columns: (subsystem, counter) rows of the registry
_PHASE_COLS = (("cache", "lookups"), ("cache", "tlb_hits"),
               ("protocol", "commits"), ("protocol", "migrations"),
               ("writeback", "flushed_pages"), ("tlb_group", "posted"),
               ("membership", "detect_to_fence_us"))


def _phase_counters(kv) -> dict:
    snap = kv.stats()
    return {f"{s}.{n}": snap.get("counters", {}).get(s, {}).get(n, 0)
            for s, n in _PHASE_COLS}


def _print_phase_table(phases) -> None:
    cols = [f"{s}.{n}" for s, n in _PHASE_COLS]
    widths = [max(len(c), 10) for c in cols]
    print("  per-phase counter deltas:")
    print("    " + "phase".ljust(10) +
          " ".join(c.rjust(w) for c, w in zip(cols, widths)))
    for (name, cur), (_, prev) in zip(phases[1:], phases):
        row = " ".join(str(cur[c] - prev[c]).rjust(w)
                       for c, w in zip(cols, widths))
        print("    " + name.ljust(10) + row)


def serving_failover(smoke: bool = False, trace=None):
    print("\n== serving: drain replica 2 (planned), fail replica 1 "
          "(crash), re-home from the durable store ==")
    arch = get_smoke_arch("granite-3-2b")
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(0))
    run = RunConfig(arch=arch, shape=ShapeConfig("s", 64, 4, "decode"),
                    mesh=MeshConfig((1,), ("data",)),
                    dpc=DPCConfig(page_size=8, pool_pages_per_shard=64,
                                  storage_backend="memory",
                                  writeback_async=False,
                                  shadow_oracle=True,
                                  obs_level="full" if trace else "counters"))
    n_nodes = 3
    kv = DistributedKVCache(run.dpc, n_nodes)
    engines = [ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                             node=i, num_nodes=n_nodes, kv_cache=kv)
               for i in range(n_nodes)]
    membership = Membership(num_nodes=n_nodes)
    membership.attach_obs(kv.obs)
    phases = [("start", _phase_counters(kv))]

    prompt = list(range(10, 34))
    for node, toks in ((1, prompt), (2, list(range(50, 74)))):
        engines[node].submit(toks, max_new_tokens=2)
        for _ in range(20):
            if engines[node].step() == 0:
                break
    print(f"  directory holds {kv.directory_occupancy()} pages "
          f"across {n_nodes} replicas")
    phases.append(("serve", _phase_counters(kv)))

    # planned departure: replica 2 evacuates before leaving — ownership
    # batch-MIGRATEs to the survivors, dirty obligations flush, and its
    # mapping cache retires precisely (no cluster-wide TLB flash)
    membership.drain(2)
    st = engines[0].drain_node(2, alive=sorted(membership.alive))
    print(f"  replica 2 drained: {st['migrated']} pages evacuated, "
          f"{st['shares_dropped']} sharer mappings retired, "
          f"{st['aborted']} aborted (epoch={membership.epoch})")
    phases.append(("drain", _phase_counters(kv)))

    # crash: replica 1's heartbeat lapses.  Its pages' last-committed bytes
    # are in the durable tier (fills flush through the writeback queue), so
    # the survivor re-homes them into E-state instead of dropping them.
    kv.checkpoint_dirty()
    membership.evict(1, "fail")
    lost = engines[0].fail_node(1, rehome_to=0)
    c = kv.proto.counters
    print(f"  replica 1 failed -> {lost} owned entries dropped, "
          f"{c['rehomed_pages']} re-homed from the store, "
          f"{c['rehome_deferred']} deferred, "
          f"{c['lost_dirty_pages']} committed dirty pages lost")
    assert c["lost_dirty_pages"] == 0, "durability broken across failover"
    print(f"  membership epoch={membership.epoch}; new mesh for 16 "
          f"chips/replica: {elastic_mesh_shape(16, 16)}")
    phases.append(("failover", _phase_counters(kv)))

    # replica 0 keeps serving through the shrunken pool
    engines[0].submit(prompt, max_new_tokens=2)
    for _ in range(20):
        if engines[0].step() == 0:
            break
    print(f"  replica 0 kept serving; directory occupancy="
          f"{kv.directory_occupancy()}, "
          f"stats={engines[0].prefix_stats.as_dict()}")
    phases.append(("resume", _phase_counters(kv)))

    # the drained replica rejoins empty and is re-seeded with cold pages
    membership.join(2)
    kv.rejoin_node(2)
    moved = kv.rebalance_join(2, copy_fn=engines[0]._copy_page)
    print(f"  replica 2 rejoined (epoch={membership.epoch}) and inherited "
          f"{len(moved)} cold pages")
    phases.append(("rejoin", _phase_counters(kv)))
    kv.close()

    _print_phase_table(phases)
    snap = kv.stats()
    print(f"  incarnations={snap.get('incarnations', {})} "
          f"membership={snap.get('counters', {}).get('membership', {})}")

    if trace:
        # export the whole history and replay it through the invariant
        # checker — the CI gate runs `python -m repro.obs.audit` on the
        # same file afterwards
        from repro.obs import audit
        doc = kv.obs.tracer.export_chrome(trace)
        violations = audit.audit_trace(doc)
        kinds = {e[1] for e in doc["dpcEvents"]}
        print(f"  trace: {len(doc['dpcEvents'])} events, {len(kinds)} "
              f"kinds -> {trace}; audit: {len(violations)} violation(s)")
        for v in violations[:10]:
            print(f"    {v}")
        assert not violations, "trace-replay invariant check failed"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter train leg for CI")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="capture the serving leg at obs_level=full and "
                         "export a Chrome trace JSON here (also replays "
                         "it through repro.obs.audit)")
    args = ap.parse_args()
    train_failover(smoke=args.smoke)
    serving_failover(smoke=args.smoke, trace=args.trace)
