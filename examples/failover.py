"""Fault-tolerance drill: training with a simulated node failure +
restart-from-checkpoint, and serving with a replica failure mid-stream.

Run:  PYTHONPATH=src python examples/failover.py
"""

import jax

from repro.configs import get_smoke_arch
from repro.configs.base import (DPCConfig, MeshConfig, RunConfig,
                                ShapeConfig)
from repro.core.dpc_cache import DistributedKVCache
from repro.models import registry
from repro.models.spec import init_params
from repro.runtime.liveness import Membership, elastic_mesh_shape
from repro.serving.engine import ServingEngine


def train_failover():
    print("== training: kill node at step 60, restart from checkpoint ==")
    from repro.launch import train
    train.main(["--arch", "qwen3-1.7b", "--steps", "100", "--batch", "4",
                "--seq", "64", "--ckpt-dir", "/tmp/repro_failover",
                "--ckpt-every", "25", "--kill-at", "60", "--log-every", "25"])


def serving_failover():
    print("\n== serving: replica 1 dies; its pages are lost, cluster "
          "recovers ==")
    arch = get_smoke_arch("granite-3-2b")
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(0))
    run = RunConfig(arch=arch, shape=ShapeConfig("s", 64, 4, "decode"),
                    mesh=MeshConfig((1,), ("data",)),
                    dpc=DPCConfig(page_size=8, pool_pages_per_shard=64))
    kv = DistributedKVCache(run.dpc, 2)
    engines = [ServingEngine(run, params, max_batch=2, max_pages_per_seq=8,
                             node=i, num_nodes=2, kv_cache=kv)
               for i in range(2)]
    membership = Membership(num_nodes=2)

    prompt = list(range(10, 34))
    engines[1].submit(prompt, max_new_tokens=2)
    for _ in range(20):
        if engines[1].step() == 0:
            break
    print(f"  replica 1 cached {kv.directory_occupancy()} pages")

    # replica 1 dies: directory drops it; epoch bumps; mesh shrinks
    membership.evict(1, "fail")
    lost = kv.fail_node(1)
    print(f"  replica 1 failed -> {lost} owned pages lost "
          f"(cache shrink, not data loss: prefill regenerates)")
    print(f"  membership epoch={membership.epoch}; new mesh for 16 "
          f"chips/replica: {elastic_mesh_shape(16, 16)}")

    # replica 0 re-reads the prompt: misses, refills, keeps serving
    engines[0].submit(prompt, max_new_tokens=2)
    for _ in range(20):
        if engines[0].step() == 0:
            break
    print(f"  replica 0 refilled; directory occupancy="
          f"{kv.directory_occupancy()}, stats={engines[0].stats.as_dict()}")


if __name__ == "__main__":
    train_failover()
    serving_failover()
