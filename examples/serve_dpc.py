"""Serve a small model with batched requests through the DPC cache, comparing
the paper's four configurations on the same shared-prefix workload.

Run:  PYTHONPATH=src python examples/serve_dpc.py
"""

from repro.launch import serve


def main():
    for mode in ("local_only", "replicated", "dpc", "dpc_sc"):
        print(f"\n===== mode={mode} =====")
        serve.main(["--mode", mode, "--requests", "12", "--share", "0.75",
                    "--prompt-len", "48", "--new-tokens", "6"])


if __name__ == "__main__":
    main()
