"""Cluster observability: metrics registry, event tracer, replay audit.

:class:`Obs` is the per-cluster hub every subsystem hangs off.  It is
constructed once (by :class:`~repro.core.dpc_cache.DistributedKVCache`
or :class:`~repro.core.protocol.DPCProtocol`) from
``DPCConfig.obs_level`` and handed down — protocol, TLB group, page
pool, writeback queue, serving engines, and membership all draw their
counter views / histogram handles / tracer from the same hub, so one
``kv.stats()`` call sees the whole cluster and one trace file holds the
whole history.

Levels: ``off`` (plain dicts, seed-identical cost), ``counters``
(registry on — the always-on tier, gated <1.1x by
``bench.obs_overhead``), ``full`` (adds the event tracer).
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.obs.registry import (CLUSTER, LEVEL_COUNTERS, LEVEL_FULL,
                                LEVEL_OFF, Histogram, MetricsRegistry,
                                MetricsView, StatsDict, parse_level)
from repro.obs.trace import EventTracer

__all__ = ["Obs", "MetricsRegistry", "MetricsView", "StatsDict",
           "Histogram", "EventTracer", "CLUSTER", "LEVEL_OFF",
           "LEVEL_COUNTERS", "LEVEL_FULL", "parse_level"]


class Obs:
    """Observability hub: one registry + (at ``full``) one tracer."""

    def __init__(self, level: str = "counters", num_nodes: int = 0,
                 trace_capacity: int = 32768):
        self.level_name = level
        self.level = parse_level(level)
        self.num_nodes = num_nodes
        self.registry: Optional[MetricsRegistry] = (
            MetricsRegistry() if self.level >= LEVEL_COUNTERS else None)
        if self.registry is not None:
            self.registry.hub = self
        self.tracer: Optional[EventTracer] = (
            EventTracer(trace_capacity, meta={"num_nodes": num_nodes})
            if self.level >= LEVEL_FULL else None)

    def view(self, node: int, subsystem: str,
             names: Tuple[str, ...] = ()):
        """Dict-compatible counter view for one ``(node, subsystem)``
        group — a :class:`StatsDict` (plain dict) when obs is off."""
        if self.registry is None:
            return StatsDict({n: 0 for n in names})
        return self.registry.view(node, subsystem, names)

    def histogram(self, node: int, subsystem: str, name: str,
                  min_level: int = LEVEL_COUNTERS) -> Optional[Histogram]:
        """Histogram handle, or None below ``min_level`` (call sites gate
        on it).  Distributions that cost real work per batch on a hot
        path (e.g. the TLB probe-depth depth-mask bookkeeping) pass
        ``min_level=LEVEL_FULL`` so the always-on ``counters`` tier keeps
        its <1.1x overhead budget."""
        if self.registry is None or self.level < min_level:
            return None
        return self.registry.histogram(node, subsystem, name)

    def gauge(self, node: int, subsystem: str, name: str,
              value: float) -> None:
        if self.registry is not None:
            self.registry.set_gauge(node, subsystem, name, value)

    def reset_node(self, node: int) -> None:
        """Incarnation fold for ``node`` (see
        :meth:`MetricsRegistry.reset_node`)."""
        if self.registry is not None:
            self.registry.reset_node(node)

    def snapshot(self) -> dict:
        if self.registry is None:
            return {"level": "off"}
        snap = self.registry.snapshot()
        snap["level"] = self.level_name
        if self.tracer is not None:
            snap["trace"] = {"events": self.tracer.emitted,
                             "dropped": self.tracer.dropped,
                             "capacity": self.tracer.capacity}
        return snap
