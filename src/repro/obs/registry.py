"""Cluster-wide metrics registry — counters, gauges, log2 histograms.

Every metric is keyed ``(node, subsystem, name)``; ``node == CLUSTER``
(-1) is the cluster-scope row used by singleton subsystems (the
directory protocol, the writeback queue).  Design constraints:

* **Cheap enough to stay on in the data path.**  Counters live in one
  flat ``array('q')`` indexed through an interned key table — an
  increment is a dict probe plus an array store, no per-event
  allocation.  Histograms are 64 fixed log2 buckets behind a bound
  handle (``hist.observe(v)``), again allocation-free.

* **Dict-compatible.**  Subsystems that used an ad-hoc ``self.stats`` /
  ``self.counters`` dict now hold a :class:`MetricsView` over their
  ``(node, subsystem)`` row group — ``view["hits"] += 1``,
  ``view["hits"]``, ``.get``, ``.items`` behave exactly like the old
  dict, so call sites and existing tests did not have to move.  A view
  is also callable: ``view()`` returns the full registry snapshot
  (the ``dpc_cache.stats()`` API rides on this).

* **Membership-aware.**  :meth:`MetricsRegistry.reset_node` is the
  incarnation fold: live per-node rows are added into a cumulative
  ``folded`` array and zeroed.  Cluster totals (``live + folded``) stay
  monotonic across drain / fail / rejoin while per-node live values
  restart per incarnation — the reset semantics ISSUE 8 pins down for
  ``rehomed`` / ``prefetch_stale``-style counters.

At ``obs_level="off"`` none of this is constructed: subsystems get a
:class:`StatsDict` (a plain ``dict`` subclass — seed-identical cost).
"""

from __future__ import annotations

import array
from typing import Dict, Iterator, List, Tuple

import numpy as np

# obs_level ladder: off < counters < full (full adds the event tracer)
LEVEL_OFF = 0
LEVEL_COUNTERS = 1
LEVEL_FULL = 2
_LEVELS = {"off": LEVEL_OFF, "counters": LEVEL_COUNTERS, "full": LEVEL_FULL}

#: node id of cluster-scope rows (subsystems with no per-node identity)
CLUSTER = -1

_Key = Tuple[int, str, str]


def parse_level(level: str) -> int:
    try:
        return _LEVELS[level]
    except KeyError:
        raise ValueError(
            f"obs_level must be one of {sorted(_LEVELS)}, got {level!r}")


class Histogram:
    """Log2-bucketed histogram of non-negative integers.

    Bucket ``b`` counts values with ``bit_length() == b``, i.e. the
    half-open range ``[2**(b-1), 2**b)`` (bucket 0 is exactly 0) — 64
    buckets cover any int64, so ``observe`` never allocates.
    """

    __slots__ = ("count", "total", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0
        self.buckets = array.array("q", bytes(8 * 64))

    def observe(self, value) -> None:
        v = int(value)
        if v < 0:
            v = 0
        self.count += 1
        self.total += v
        self.buckets[v.bit_length()] += 1

    def observe_all(self, values) -> None:
        """Batch entry point for hot loops (one call per batch, not per
        sample — the TLB probe loop appends depths to a list and flushes
        here)."""
        for v in values:
            self.observe(v)

    def observe_array(self, values: np.ndarray) -> None:
        """Vectorized observe for a numpy array of non-negative ints —
        one bincount per batch instead of a Python loop per sample.
        ``frexp``'s exponent equals ``bit_length`` for positive ints
        (exact below 2**53, far beyond any batched quantity here)."""
        v = np.maximum(np.asarray(values), 0)
        n = int(v.size)
        if n == 0:
            return
        self.count += n
        self.total += int(v.sum())
        bl = np.frexp(v.astype(np.float64))[1]
        counts = np.bincount(bl)
        for b in np.nonzero(counts)[0]:
            self.buckets[int(b)] += int(counts[b])

    def percentile(self, q: float) -> int:
        """Upper bound of the bucket holding the q-quantile sample
        (log2 resolution — good for 'p99 is ~2x p50', not for ns-exact
        latencies)."""
        if self.count == 0:
            return 0
        rank = q * self.count
        seen = 0
        for b, n in enumerate(self.buckets):
            seen += n
            if seen >= rank and n:
                return (1 << b) - 1 if b else 0
        return (1 << 63) - 1

    def reset(self) -> None:
        self.count = 0
        self.total = 0
        for i in range(64):
            self.buckets[i] = 0

    def snapshot(self) -> dict:
        mean = self.total / self.count if self.count else 0.0
        return {
            "count": self.count,
            "sum": self.total,
            "mean": round(mean, 3),
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
            "buckets": {b: n for b, n in enumerate(self.buckets) if n},
        }


class MetricsRegistry:
    """Flat array-backed store for every ``(node, subsystem, name)`` row."""

    def __init__(self):
        self._index: Dict[_Key, int] = {}
        self._live = array.array("q")
        self._folded = array.array("q")   # pre-incarnation totals (fold)
        self._hists: Dict[_Key, Histogram] = {}
        self._gauges: Dict[_Key, float] = {}
        self._gauge_providers: List = []
        self.incarnations: Dict[int, int] = {}
        # back-pointer set by the owning Obs hub so callable views return
        # the hub-level snapshot (level name, trace stats) when one exists
        self.hub = None

    def add_gauge_provider(self, fn) -> None:
        """Register a zero-arg callback run at snapshot time to publish
        point-in-time gauges (e.g. pool occupancy) — sampled lazily so
        the data path never pays for them."""
        self._gauge_providers.append(fn)

    # -- row allocation -------------------------------------------------
    def index(self, node: int, subsystem: str, name: str) -> int:
        key = (node, subsystem, name)
        i = self._index.get(key)
        if i is None:
            i = len(self._live)
            self._index[key] = i
            self._live.append(0)
            self._folded.append(0)
        return i

    def view(self, node: int, subsystem: str,
             names: Tuple[str, ...] = ()) -> "MetricsView":
        return MetricsView(self, node, subsystem, names)

    def histogram(self, node: int, subsystem: str, name: str) -> Histogram:
        key = (node, subsystem, name)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram()
        return h

    def set_gauge(self, node: int, subsystem: str, name: str,
                  value: float) -> None:
        self._gauges[(node, subsystem, name)] = float(value)

    # -- reads ----------------------------------------------------------
    def value(self, node: int, subsystem: str, name: str) -> int:
        i = self._index.get((node, subsystem, name))
        return 0 if i is None else self._live[i]

    def total(self, subsystem: str, name: str) -> int:
        """Monotonic cluster total: live + folded, summed over nodes."""
        out = 0
        for (n, sub, nm), i in self._index.items():
            if sub == subsystem and nm == name:
                out += self._live[i] + self._folded[i]
        return out

    # -- membership (incarnation fold) ----------------------------------
    def reset_node(self, node: int) -> None:
        """Fold ``node``'s live rows into the cumulative totals and zero
        them: cluster totals stay monotonic, per-node live values restart
        for the new incarnation.  Histograms are per-incarnation
        distributions and simply reset; gauges are dropped (the next
        sample re-publishes them)."""
        for (n, _sub, _nm), i in self._index.items():
            if n == node:
                self._folded[i] += self._live[i]
                self._live[i] = 0
        for (n, _sub, _nm), h in self._hists.items():
            if n == node:
                h.reset()
        for key in [k for k in self._gauges if k[0] == node]:
            del self._gauges[key]
        self.incarnations[node] = self.incarnations.get(node, 0) + 1

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict:
        """Nested dict: cluster totals, per-node live rows, histogram
        summaries, gauges, incarnation counts."""
        for fn in self._gauge_providers:
            fn()
        counters: Dict[str, Dict[str, int]] = {}
        nodes: Dict[int, Dict[str, Dict[str, int]]] = {}
        for (node, sub, name), i in sorted(self._index.items()):
            total = self._live[i] + self._folded[i]
            if total == 0:
                continue
            row = counters.setdefault(sub, {})
            row[name] = row.get(name, 0) + total
            if node != CLUSTER:
                nodes.setdefault(node, {}).setdefault(sub, {})[name] = \
                    self._live[i]
        hists: Dict[str, Dict[str, dict]] = {}
        for (node, sub, name), h in sorted(self._hists.items()):
            if h.count == 0:
                continue
            label = name if node == CLUSTER else f"{name}.n{node}"
            hists.setdefault(sub, {})[label] = h.snapshot()
        gauges: Dict[str, Dict[str, float]] = {}
        for (node, sub, name), v in sorted(self._gauges.items()):
            label = name if node == CLUSTER else f"{name}.n{node}"
            gauges.setdefault(sub, {})[label] = v
        return {
            "counters": counters,
            "nodes": nodes,
            "histograms": hists,
            "gauges": gauges,
            "incarnations": dict(self.incarnations),
        }


class MetricsView:
    """Dict-compatible counter view over one ``(node, subsystem)`` group.

    Unknown names allocate a zero row on first touch, so ad-hoc
    ``view["new_counter"] += 1`` keeps working exactly like it did on the
    plain dicts this replaces.
    """

    __slots__ = ("_reg", "_node", "_sub", "_idx")

    def __init__(self, reg: MetricsRegistry, node: int, subsystem: str,
                 names: Tuple[str, ...] = ()):
        self._reg = reg
        self._node = node
        self._sub = subsystem
        self._idx = {n: reg.index(node, subsystem, n) for n in names}

    def _i(self, name: str) -> int:
        i = self._idx.get(name)
        if i is None:
            i = self._reg.index(self._node, self._sub, name)
            self._idx[name] = i
        return i

    # dict protocol (the compatibility surface the migration rides on)
    def __getitem__(self, name: str) -> int:
        return self._reg._live[self._i(name)]

    def __setitem__(self, name: str, value) -> None:
        self._reg._live[self._i(name)] = int(value)

    def __contains__(self, name) -> bool:
        return name in self._idx

    def __iter__(self) -> Iterator[str]:
        return iter(self._idx)

    def __len__(self) -> int:
        return len(self._idx)

    def get(self, name: str, default=0):
        i = self._idx.get(name)
        return default if i is None else self._reg._live[i]

    def keys(self):
        return self._idx.keys()

    def values(self) -> List[int]:
        live = self._reg._live
        return [live[i] for i in self._idx.values()]

    def items(self) -> List[Tuple[str, int]]:
        live = self._reg._live
        return [(n, live[i]) for n, i in self._idx.items()]

    def update(self, other=(), **kw) -> None:
        pairs = other.items() if hasattr(other, "items") else other
        for n, v in pairs:
            self[n] = v
        for n, v in kw.items():
            self[n] = v

    def copy(self) -> Dict[str, int]:
        return dict(self.items())

    def total(self, name: str) -> int:
        """Monotonic live+folded value of this row (survives rejoin)."""
        i = self._i(name)
        return self._reg._live[i] + self._reg._folded[i]

    # snapshot API: ``kv.stats()`` / ``engine.stats()`` ride on this
    def __call__(self) -> dict:
        hub = self._reg.hub
        return self._reg.snapshot() if hub is None else hub.snapshot()

    def __repr__(self) -> str:
        return repr(dict(self.items()))

    def __eq__(self, other) -> bool:
        if isinstance(other, MetricsView):
            other = dict(other.items())
        return dict(self.items()) == other


class StatsDict(dict):
    """``obs_level='off'`` fallback: a plain dict (seed-identical data
    path cost) that still honors the callable-snapshot shape so
    ``kv.stats()`` stays valid with obs disabled."""

    def __call__(self) -> dict:
        return {"level": "off"}

    def total(self, name: str) -> int:
        return self.get(name, 0)
