"""Trace-replay invariant checker.

Replays a captured event stream (from a live :class:`EventTracer` or an
exported Chrome-trace JSON) and *independently* re-derives the three
invariants the protocol promises, over the whole history rather than
just the settled end state the shadow oracle sees:

* **single-copy** — at no point do two frames name one ``(stream,
  page)``, and no frame names two pages (``EV_BIND``/``EV_UNBIND``
  bracket every residency interval);
* **flush-before-free** — a frame with a registered-but-uncommitted
  writeback obligation (``EV_WB_REG`` without its ``EV_WB_COMMIT``) is
  never released (``EV_FRAME_FREE``);
* **shootdown-before-remap** — a page is never re-bound while a posted
  TLB shootdown for it is still undelivered (``EV_SD_POST`` without
  ``EV_SD_DELIVER``/``EV_SD_WIPE``/``EV_SD_FLASH``): a stale mapping
  could still serve the old frame;
* **epoch/fence monotonicity** — committed epochs (``EV_EPOCH``) are
  strictly increasing and fencing tokens (``EV_FENCE``/``EV_UNFENCE``)
  never regress: a token going backwards means a stale membership view
  committed a transition;
* **TBI/TBM span balance** — every transaction that begins either ends
  or is legitimately discarded by a node failure (``EV_FAIL`` retires
  open invalidations owned by — and migrations sourced at — the dead
  node, exactly like ``protocol.fail_node`` deletes them); an end with
  no begin, a double begin, or a span left open at end-of-stream (when
  the ring dropped nothing) is a leaked transaction.

Membership edges reset scoped state exactly like the protocol does:
``EV_FAIL``/``EV_POOL_RESET`` retire the node's frame range and its
writeback obligations (the frames are gone, not freed), ``EV_SD_WIPE``
retires one node's posted shootdowns, ``EV_SD_FLASH`` all of them.

CLI (exit 1 on any violation, 2 on unreadable input)::

    python -m repro.obs.audit trace.json [--max-print 20]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List, NamedTuple, Optional, Set, Tuple

from repro.obs.trace import (EV_BIND, EV_EPOCH, EV_FAIL, EV_FENCE,
                             EV_FRAME_FREE, EV_POOL_RESET, EV_SD_DELIVER,
                             EV_SD_FLASH, EV_SD_POST, EV_SD_WIPE,
                             EV_TBI_BEGIN, EV_TBI_END, EV_TBM_BEGIN,
                             EV_TBM_END, EV_UNBIND, EV_UNFENCE,
                             EV_WB_COMMIT, EV_WB_REG, KIND_NAMES)

Key = Tuple[int, int]          # (stream, page)


class Violation(NamedTuple):
    seq: int
    rule: str                  # single-copy | flush-before-free | ...
    detail: str

    def __str__(self) -> str:
        return f"seq={self.seq} [{self.rule}] {self.detail}"


def audit_events(events: Iterable[Tuple[int, ...]], *,
                 pool_pages: int = 0, dropped: int = 0) -> List[Violation]:
    """Replay ``(seq, kind, node, a, b, c, d[, t])`` tuples and collect
    violations.  ``pool_pages`` (frames per node, from the trace meta)
    scopes frame-range cleanup on fail/pool-reset; 0 disables it (fine
    for synthetic traces that never fail a node).  ``dropped`` > 0 (ring
    wrap lost the oldest prefix) relaxes the span-balance begin checks —
    an end whose begin predates the surviving window is not a leak."""
    bound: Dict[Key, int] = {}            # (stream, page) -> pfn
    frame_of: Dict[int, Key] = {}         # pfn -> (stream, page)
    wb_out: Dict[Tuple[int, int], int] = {}   # (node, slot) -> reg seq
    sd_out: Dict[Key, Dict[int, int]] = {}    # key -> {target: n_posted}
    # open transaction spans: key -> (begin seq, owner/src node)
    tbi_open: Dict[Key, Tuple[int, int]] = {}
    tbm_open: Dict[Key, Tuple[int, int]] = {}
    last_epoch: Optional[int] = None
    last_fence: Optional[int] = None
    last_seq = 0
    out: List[Violation] = []

    def _drop_node_frames(node: int) -> None:
        if pool_pages <= 0:
            return
        lo, hi = node * pool_pages, (node + 1) * pool_pages
        for pfn in [p for p in frame_of if lo <= p < hi]:
            key = frame_of.pop(pfn)
            if bound.get(key) == pfn:
                del bound[key]

    for ev in events:
        seq, kind, node, a, b, c, d = (int(x) for x in tuple(ev)[:7])
        last_seq = seq
        key = (a, b)
        if kind == EV_BIND:
            posts = sd_out.get(key)
            if posts:
                targets = sorted(posts)
                out.append(Violation(
                    seq, "shootdown-before-remap",
                    f"page {key} re-bound to pfn={c} with "
                    f"{sum(posts.values())} undelivered shootdown(s) "
                    f"posted to node(s) {targets}"))
            old = bound.get(key)
            if old is not None and old != c:
                out.append(Violation(
                    seq, "single-copy",
                    f"page {key} double-resident: bound to pfn={old} "
                    f"and re-bound to pfn={c} with no unbind between"))
                frame_of.pop(old, None)
            other = frame_of.get(c)
            if other is not None and other != key:
                out.append(Violation(
                    seq, "single-copy",
                    f"frame pfn={c} aliased: names page {other} and "
                    f"page {key} simultaneously"))
                bound.pop(other, None)
            bound[key] = c
            frame_of[c] = key
        elif kind == EV_UNBIND:
            if bound.get(key) == c:
                del bound[key]
            if frame_of.get(c) == key:
                del frame_of[c]
        elif kind == EV_FRAME_FREE:
            # a=slot, c=pfn, node=frame owner
            reg = wb_out.pop((node, a), None)
            if reg is not None:
                out.append(Violation(
                    seq, "flush-before-free",
                    f"frame node={node} slot={a} (pfn={c}) freed with "
                    f"writeback registered at seq={reg} still "
                    f"uncommitted"))
            stale = frame_of.pop(c, None)
            if stale is not None and bound.get(stale) == c:
                del bound[stale]
        elif kind == EV_WB_REG:
            wb_out[(node, a)] = seq
        elif kind == EV_WB_COMMIT:
            wb_out.pop((node, a), None)
        elif kind == EV_SD_POST:
            posts = sd_out.setdefault(key, {})
            posts[node] = posts.get(node, 0) + 1
        elif kind == EV_SD_DELIVER:
            posts = sd_out.get(key)
            if posts is not None:
                n = posts.get(node, 0)
                if n <= 1:
                    posts.pop(node, None)
                else:
                    posts[node] = n - 1
                if not posts:
                    del sd_out[key]
        elif kind == EV_SD_WIPE:
            for k in list(sd_out):
                sd_out[k].pop(node, None)
                if not sd_out[k]:
                    del sd_out[k]
        elif kind == EV_SD_FLASH:
            sd_out.clear()
        elif kind == EV_FAIL:
            _drop_node_frames(node)
            for nk in [k for k in wb_out if k[0] == node]:
                del wb_out[nk]
            # the protocol deletes pending rounds the dead node owned /
            # sourced without emitting END events — retire their spans
            for k in [k for k, (_s, owner) in tbi_open.items()
                      if owner == node]:
                del tbi_open[k]
            for k in [k for k, (_s, src) in tbm_open.items()
                      if src == node]:
                del tbm_open[k]
        elif kind == EV_POOL_RESET:
            _drop_node_frames(node)
            for nk in [k for k in wb_out if k[0] == node]:
                del wb_out[nk]
        elif kind == EV_TBI_BEGIN:
            prev = tbi_open.get(key)
            if prev is not None:
                out.append(Violation(
                    seq, "span-balance",
                    f"TBI begin for {key} while the round begun at "
                    f"seq={prev[0]} is still open (double begin)"))
            tbi_open[key] = (seq, c)
        elif kind == EV_TBI_END:
            if tbi_open.pop(key, None) is None and dropped <= 0:
                out.append(Violation(
                    seq, "span-balance",
                    f"TBI end for {key} with no matching begin"))
        elif kind == EV_TBM_BEGIN:
            prev = tbm_open.get(key)
            if prev is not None:
                out.append(Violation(
                    seq, "span-balance",
                    f"TBM begin for {key} while the hand-off begun at "
                    f"seq={prev[0]} is still open (double begin)"))
            tbm_open[key] = (seq, c)
        elif kind == EV_TBM_END:
            if tbm_open.pop(key, None) is None and dropped <= 0:
                out.append(Violation(
                    seq, "span-balance",
                    f"TBM end for {key} with no matching begin"))
        elif kind == EV_EPOCH:
            if last_epoch is not None and a <= last_epoch:
                out.append(Violation(
                    seq, "epoch-monotonic",
                    f"committed epoch went {last_epoch} -> {a} (must be "
                    f"strictly increasing)"))
            last_epoch = a
            if last_fence is not None and b < last_fence:
                out.append(Violation(
                    seq, "fence-monotonic",
                    f"fence token regressed {last_fence} -> {b}"))
            last_fence = b if last_fence is None else max(last_fence, b)
        elif kind in (EV_FENCE, EV_UNFENCE):
            if last_fence is not None and a < last_fence:
                out.append(Violation(
                    seq, "fence-monotonic",
                    f"fence token regressed {last_fence} -> {a} on "
                    f"{KIND_NAMES[kind]} of node {node}"))
            last_fence = a if last_fence is None else max(last_fence, a)
        # other kinds (batches, membership phases) carry no invariant
        # state — they exist for the timeline
    if dropped <= 0:
        end_seq = last_seq
        for k, (bseq, owner) in sorted(tbi_open.items()):
            out.append(Violation(
                end_seq, "span-balance",
                f"TBI for {k} (owner {owner}) begun at seq={bseq} never "
                f"completed or retired"))
        for k, (bseq, src) in sorted(tbm_open.items()):
            out.append(Violation(
                end_seq, "span-balance",
                f"TBM for {k} (src {src}) begun at seq={bseq} never "
                f"completed or retired"))
    return out


def audit_trace(doc: dict) -> List[Violation]:
    """Audit an exported Chrome-trace doc (``dpcEvents`` + ``dpcMeta``)."""
    events = doc.get("dpcEvents")
    if events is None:
        raise ValueError("no dpcEvents in trace doc — was it exported by "
                         "repro.obs.trace.EventTracer.export_chrome?")
    meta = doc.get("dpcMeta", {})
    return audit_events(events, pool_pages=int(meta.get("pool_pages", 0)),
                        dropped=int(meta.get("dropped", 0)))


def audit_file(path: str) -> List[Violation]:
    with open(path) as f:
        return audit_trace(json.load(f))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.audit",
        description="replay a captured DPC trace and re-check single-copy,"
                    " flush-before-free, and shootdown-before-remap")
    ap.add_argument("trace", help="Chrome-trace JSON exported by "
                                  "EventTracer.export_chrome")
    ap.add_argument("--max-print", type=int, default=20,
                    help="cap on violations printed (all are counted)")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            doc = json.load(f)
        violations = audit_trace(doc)
    except (OSError, ValueError) as e:
        print(f"audit: cannot read {args.trace}: {e}", file=sys.stderr)
        return 2
    n_events = len(doc.get("dpcEvents", ()))
    dropped = doc.get("dpcMeta", {}).get("dropped", 0)
    kinds: Set[str] = {KIND_NAMES.get(int(e[1]), "?")
                       for e in doc.get("dpcEvents", ())}
    print(f"audit: {n_events} events ({dropped} dropped to ring wrap), "
          f"{len(kinds)} kinds, {len(violations)} violation(s)")
    for v in violations[:args.max_print]:
        print(f"  {v}")
    if len(violations) > args.max_print:
        print(f"  ... and {len(violations) - args.max_print} more")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
