"""Structured protocol event tracer — ring-buffered binary events.

At ``obs_level="full"`` every protocol transaction edge (TBI/TBM
begin→ACK→finish, opcode-batch dispatch with lane composition,
membership join/drain/failover/fence phases, engine step boundaries
with their async overlap windows) and every invariant-relevant state
edge (page bind/unbind, frame free, writeback register/commit,
shootdown post/deliver/wipe/flash) lands in a fixed-size numpy
structured ring — 32 bytes per event, one element assignment, no
per-event allocation.

Every event carries two clocks.  The *logical* clock is the event
sequence number: this is a single-process reproduction, so emission
order *is* the cluster's linearization, and the replay checker
(:mod:`repro.obs.audit`) leans on exactly that.  The *wall* clock
(``t``, µs since tracer construction, from ``perf_counter_ns``) makes
real durations measurable — detection→fence→recovery latency and async
overlap windows read directly off the Chrome export in µs instead of
"1 logical tick per event".  Two exports:

* :meth:`EventTracer.events` — the buffered ``(seq, kind, node, a, b,
  c, d, t)`` tuples, oldest first (the ring drops the oldest prefix
  once it wraps; ``dropped`` says how many).
* :meth:`EventTracer.export_chrome` — Chrome ``trace_event`` JSON
  (openable in Perfetto / ``chrome://tracing``): nodes render as
  processes, subsystems as threads, transactions as async spans, and
  the raw event stream rides along under ``dpcEvents`` + ``dpcMeta`` so
  ``python -m repro.obs.audit trace.json`` can replay the file
  standalone.
"""

from __future__ import annotations

import json
import time
from typing import List, Optional, Tuple

import numpy as np

# (seq, kind, node, a, b, c, d, t) — 32-byte packed record; ``t`` is the
# wall clock in µs since tracer construction (appended last so positional
# consumers of the original 7-field layout keep working on ``ev[:7]``)
EVENT_DTYPE = np.dtype([("seq", "<i8"), ("kind", "<i2"), ("node", "<i2"),
                        ("a", "<i4"), ("b", "<i4"), ("c", "<i4"),
                        ("d", "<i4"), ("t", "<i8")])

# -- event kinds --------------------------------------------------------
# directory / data plane            args (a, b, c, d)
EV_BATCH = 1          # opcode batch: a=shard b=n_real c=n_copy d=n_flush
EV_BIND = 2           # page committed: a=stream b=page c=pfn
EV_UNBIND = 3         # mapping retired: a=stream b=page c=pfn
EV_FRAME_FREE = 4     # frame released: a=slot c=pfn (node = frame owner)
EV_WB_REG = 5         # writeback obligation: a=slot b=stream c=page
EV_WB_COMMIT = 6      # obligation flushed/harvested: a=slot
# invalidation (TBI) / migration (TBM) transactions
EV_TBI_BEGIN = 7      # a=stream b=page c=owner d=n_sharers
EV_TBI_ACK = 8        # a=stream b=page c=acking_node d=dirty
EV_TBI_END = 9        # a=stream b=page c=status
EV_TBM_BEGIN = 10     # a=stream b=page c=src d=dst
EV_TBM_ACK = 11       # a=stream b=page c=acking_node
EV_TBM_END = 12       # a=stream b=page c=status d=new_pfn
# TLB shootdown lifecycle (node = shootdown target)
EV_SD_POST = 13       # a=stream b=page
EV_SD_DELIVER = 14    # a=stream b=page
EV_SD_WIPE = 15       # whole-node TLB retire (drain/rejoin)
EV_SD_FLASH = 16      # global epoch flash (failover)
# membership phases
EV_JOIN = 17          # a=epoch
EV_REJOIN = 18        # a=epoch
EV_DRAIN_BEGIN = 19   # a=pages_resident
EV_DRAIN_END = 20     # a=pages_moved b=pages_flushed
EV_FAIL = 21          # a=rehome_to
EV_POOL_RESET = 22    # frame pool discarded (rejoin)
# serving engine
EV_STEP_BEGIN = 23    # a=step_index b=batch_size
EV_STEP_END = 24      # a=step_index
EV_OVERLAP_BEGIN = 25  # a=step_index  (async host-work window opens)
EV_OVERLAP_END = 26    # a=step_index  (window closes at sample)
EV_LANE_FENCE = 27    # a=n_copy b=n_flush drained at a data-lane fence
# quorum membership / partition fencing
EV_EPOCH = 28         # committed epoch bump: a=epoch b=fence_token
EV_FENCE = 29         # node fenced (stale epoch): a=fence_token
EV_UNFENCE = 30       # node unfenced (rejoined): a=fence_token

KIND_NAMES = {
    EV_BATCH: "batch", EV_BIND: "bind", EV_UNBIND: "unbind",
    EV_FRAME_FREE: "frame_free", EV_WB_REG: "wb_reg",
    EV_WB_COMMIT: "wb_commit",
    EV_TBI_BEGIN: "tbi_begin", EV_TBI_ACK: "tbi_ack", EV_TBI_END: "tbi_end",
    EV_TBM_BEGIN: "tbm_begin", EV_TBM_ACK: "tbm_ack", EV_TBM_END: "tbm_end",
    EV_SD_POST: "sd_post", EV_SD_DELIVER: "sd_deliver",
    EV_SD_WIPE: "sd_wipe", EV_SD_FLASH: "sd_flash",
    EV_JOIN: "join", EV_REJOIN: "rejoin",
    EV_DRAIN_BEGIN: "drain_begin", EV_DRAIN_END: "drain_end",
    EV_FAIL: "fail", EV_POOL_RESET: "pool_reset",
    EV_STEP_BEGIN: "step_begin", EV_STEP_END: "step_end",
    EV_OVERLAP_BEGIN: "overlap_begin", EV_OVERLAP_END: "overlap_end",
    EV_LANE_FENCE: "lane_fence",
    EV_EPOCH: "epoch", EV_FENCE: "fence", EV_UNFENCE: "unfence",
}

# Chrome export: which thread lane each kind renders on
_TID_DIRECTORY, _TID_TLB, _TID_WRITEBACK, _TID_MEMBER, _TID_ENGINE = \
    0, 1, 2, 3, 4
_TID_NAMES = {_TID_DIRECTORY: "directory", _TID_TLB: "tlb",
              _TID_WRITEBACK: "writeback", _TID_MEMBER: "membership",
              _TID_ENGINE: "engine"}
_KIND_TID = {
    EV_BATCH: _TID_DIRECTORY, EV_BIND: _TID_DIRECTORY,
    EV_UNBIND: _TID_DIRECTORY, EV_FRAME_FREE: _TID_DIRECTORY,
    EV_TBI_BEGIN: _TID_DIRECTORY, EV_TBI_ACK: _TID_DIRECTORY,
    EV_TBI_END: _TID_DIRECTORY, EV_TBM_BEGIN: _TID_DIRECTORY,
    EV_TBM_ACK: _TID_DIRECTORY, EV_TBM_END: _TID_DIRECTORY,
    EV_LANE_FENCE: _TID_DIRECTORY,
    EV_SD_POST: _TID_TLB, EV_SD_DELIVER: _TID_TLB,
    EV_SD_WIPE: _TID_TLB, EV_SD_FLASH: _TID_TLB,
    EV_WB_REG: _TID_WRITEBACK, EV_WB_COMMIT: _TID_WRITEBACK,
    EV_JOIN: _TID_MEMBER, EV_REJOIN: _TID_MEMBER,
    EV_DRAIN_BEGIN: _TID_MEMBER, EV_DRAIN_END: _TID_MEMBER,
    EV_FAIL: _TID_MEMBER, EV_POOL_RESET: _TID_MEMBER,
    EV_EPOCH: _TID_MEMBER, EV_FENCE: _TID_MEMBER,
    EV_UNFENCE: _TID_MEMBER,
    EV_STEP_BEGIN: _TID_ENGINE, EV_STEP_END: _TID_ENGINE,
    EV_OVERLAP_BEGIN: _TID_ENGINE, EV_OVERLAP_END: _TID_ENGINE,
}

# async-span pairing for the Chrome export: kind -> (peer_end, span name,
# id fields) — spans are matched at export time, no runtime span ids
_SPANS = {
    EV_TBI_BEGIN: (EV_TBI_END, "TBI", ("a", "b")),
    EV_TBM_BEGIN: (EV_TBM_END, "TBM", ("a", "b")),
    EV_DRAIN_BEGIN: (EV_DRAIN_END, "DRAIN", ()),
    EV_STEP_BEGIN: (EV_STEP_END, "STEP", ("a",)),
    EV_OVERLAP_BEGIN: (EV_OVERLAP_END, "OVERLAP", ("a",)),
}
_SPAN_ENDS = {end for end, _, _ in _SPANS.values()}


class EventTracer:
    """Fixed-capacity binary event ring with a logical clock."""

    def __init__(self, capacity: int = 32768, meta: Optional[dict] = None):
        capacity = max(8, int(capacity))
        capacity = 1 << (capacity - 1).bit_length()   # round up to pow2
        self._mask = capacity - 1
        self._buf = np.zeros(capacity, EVENT_DTYPE)
        self._n = 0
        self._t0_ns = time.perf_counter_ns()
        self.meta = dict(meta or {})

    @property
    def capacity(self) -> int:
        return len(self._buf)

    @property
    def emitted(self) -> int:
        """Total events ever emitted (the logical clock's next value)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events lost to ring wrap (oldest-first)."""
        return max(0, self._n - len(self._buf))

    def emit(self, kind: int, node: int = -1, a: int = 0, b: int = 0,
             c: int = 0, d: int = 0) -> None:
        n = self._n
        self._buf[n & self._mask] = (
            n, kind, node, a, b, c, d,
            (time.perf_counter_ns() - self._t0_ns) // 1000)
        self._n = n + 1

    def events(self) -> List[Tuple[int, int, int, int, int, int, int, int]]:
        """Buffered ``(seq, kind, node, a, b, c, d, t)`` tuples, oldest
        first (``t`` = wall µs since tracer construction)."""
        n, cap = self._n, len(self._buf)
        if n <= cap:
            return self._buf[:n].tolist()
        start = n & self._mask
        return self._buf[start:].tolist() + self._buf[:start].tolist()

    # -- Chrome trace_event export --------------------------------------
    def export_chrome(self, path: Optional[str] = None,
                      extra_meta: Optional[dict] = None) -> dict:
        """Build (and optionally write) a Chrome ``trace_event`` JSON doc.

        ``ts`` is the wall clock (µs since tracer construction), so span
        widths in Perfetto are real durations; the logical clock rides
        along as ``args.seq`` for tie-breaking and cross-referencing the
        audit.  pid = node (-1 = cluster), tid = subsystem lane.
        Transactions render as async spans (``ph: b``/``e``) matched by
        their id fields; every event also lands as an instant so nothing
        is hidden.  The raw stream is preserved under
        ``dpcEvents``/``dpcMeta`` for :mod:`repro.obs.audit`.
        """
        events = self.events()
        trace: List[dict] = []
        pids = sorted({e[2] for e in events})
        for pid in pids:
            name = "cluster" if pid < 0 else f"node{pid}"
            trace.append({"ph": "M", "name": "process_name", "pid": pid,
                          "args": {"name": name}})
            for tid, tname in _TID_NAMES.items():
                trace.append({"ph": "M", "name": "thread_name", "pid": pid,
                              "tid": tid, "args": {"name": tname}})
        for seq, kind, node, a, b, c, d, t in events:
            tid = _KIND_TID.get(kind, _TID_DIRECTORY)
            kname = KIND_NAMES.get(kind, f"kind{kind}")
            span = _SPANS.get(kind)
            if span is not None or kind in _SPAN_ENDS:
                if span is not None:
                    _end, sname, idf = span
                    ph = "b"
                else:
                    sname, idf = next(
                        (nm, f) for bk, (ek, nm, f) in _SPANS.items()
                        if ek == kind)
                    ph = "e"
                fields = dict(zip("abcd", (a, b, c, d)))
                sid = ":".join([sname] + [str(fields[f]) for f in idf])
                trace.append({"ph": ph, "cat": "txn", "name": sname,
                              "id": sid, "pid": node, "tid": tid,
                              "ts": t,
                              "args": {"a": a, "b": b, "c": c, "d": d,
                                       "seq": seq}})
                continue
            trace.append({"ph": "i", "s": "t", "name": kname, "cat": kname,
                          "pid": node, "tid": tid, "ts": t,
                          "args": {"a": a, "b": b, "c": c, "d": d,
                                   "seq": seq}})
        meta = dict(self.meta)
        meta.update(extra_meta or {})
        meta["kinds"] = {v: k for k, v in KIND_NAMES.items()}
        meta["dropped"] = self.dropped
        doc = {"traceEvents": trace, "displayTimeUnit": "ms",
               "dpcEvents": [list(e) for e in events], "dpcMeta": meta}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc
