"""RWKV6 "Finch" block: data-dependent-decay time-mix + channel-mix.

Attention-free: decode state is O(1) per layer (token-shift vectors + the
[H, N, V] wkv state), so there is no growing KV cache and the DPC page
technique does not apply to this arch (DESIGN.md §4) — long_500k decode runs
entirely on recurrent state.

Chunked parallel form for train/prefill: within a chunk the pairwise decay
exp(cum[t-1] - cum[j]) (j <= t-1) is always <= 1, so the O(Q^2 N) 3-tensor
einsum is numerically safe (no factored exp(+cum) overflow); across chunks the
state recurrence is a scan.  Matches the token-by-token oracle exactly.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.spec import ParamSpec

TM_LORA_RANK = 32
DECAY_LORA_RANK = 64
MIX_NAMES = ("r", "k", "v", "w", "g")  # ddlerp targets


def rwkv6_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    h = cfg.d_model // s.head_dim
    return h, s.state_dim, s.head_dim  # (heads, N key dim, V value dim)


def rwkv6_timemix_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.param_dtype
    h, n, v = rwkv6_dims(cfg)
    return {
        "mu_x": ParamSpec((d,), ("embed",), "float32", init="zeros"),
        "mu": ParamSpec((5, d), (None, "embed"), "float32", init="zeros"),
        "tm_w1": ParamSpec((d, 5 * TM_LORA_RANK), ("embed", None), dt),
        "tm_w2": ParamSpec((5, TM_LORA_RANK, d), (None, None, "embed"), dt,
                           fan_in=TM_LORA_RANK),
        "w0": ParamSpec((d,), ("embed",), "float32", init="zeros"),
        "w_lora1": ParamSpec((d, DECAY_LORA_RANK), ("embed", None), dt),
        "w_lora2": ParamSpec((DECAY_LORA_RANK, d), (None, "embed"), dt,
                             fan_in=DECAY_LORA_RANK),
        "u": ParamSpec((h, n), (None, None), "float32", init="zeros"),
        "w_r": ParamSpec((d, d), ("embed", "heads"), dt),
        "w_k": ParamSpec((d, d), ("embed", "heads"), dt),
        "w_v": ParamSpec((d, d), ("embed", "heads"), dt),
        "w_g": ParamSpec((d, d), ("embed", "heads"), dt),
        "w_o": ParamSpec((d, d), ("heads", "embed"), dt),
        "ln_x": ParamSpec((d,), ("embed",), "float32", init="ones"),
    }


def rwkv6_channelmix_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "mu_k": ParamSpec((d,), ("embed",), "float32", init="zeros"),
        "mu_r": ParamSpec((d,), ("embed",), "float32", init="zeros"),
        "c_wk": ParamSpec((d, f), ("embed", "mlp"), dt),
        "c_wv": ParamSpec((f, d), ("mlp", "embed"), dt),
        "c_wr": ParamSpec((d, d), ("embed", "heads"), dt),
    }


def _token_shift(x: jax.Array, state: Optional[jax.Array]) -> jax.Array:
    """x: [B, T, D] -> previous token per position (state = last token of the
    previous segment, zeros at stream start)."""
    b, t, d = x.shape
    first = (jnp.zeros((b, 1, d), x.dtype) if state is None
             else state[:, None].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(params, x: jax.Array, xprev: jax.Array):
    """Data-dependent lerp (RWKV6): five mixed inputs r,k,v,w,g."""
    sx = (xprev - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xxx = xf + sx * params["mu_x"]
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xxx.astype(x.dtype),
                               params["tm_w1"]).astype(jnp.float32))
    lora = lora.reshape(*lora.shape[:-1], 5, TM_LORA_RANK)
    lora = jnp.einsum("btfr,frd->btfd", lora.astype(x.dtype),
                      params["tm_w2"]).astype(jnp.float32)
    mixes = params["mu"][None, None] + lora                   # [B,T,5,D]
    outs = [(xf + sx * mixes[:, :, i]).astype(x.dtype) for i in range(5)]
    return outs  # xr, xk, xv, xw, xg


def _decay_log(params, xw: jax.Array) -> jax.Array:
    """log-decay  logw = -exp(w0 + lora(xw))  (negative)."""
    w = params["w0"] + jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, params["w_lora1"])
                 .astype(jnp.float32)).astype(xw.dtype),
        params["w_lora2"]).astype(jnp.float32)
    return -jnp.exp(w)


def rwkv6_timemix(params, cfg: ArchConfig, x: jax.Array, *,
                  shift_state: Optional[jax.Array] = None,
                  wkv_state: Optional[jax.Array] = None,
                  return_state: bool = False):
    """x: [B, T, D] -> out [B, T, D] (+ (last_token, wkv_state'))."""
    s = cfg.ssm
    b, t, d = x.shape
    h, n, vd = rwkv6_dims(cfg)

    xprev = _token_shift(x, shift_state)
    xr, xk, xv, xw, xg = _ddlerp(params, x, xprev)

    r = jnp.einsum("btd,de->bte", xr, params["w_r"]).reshape(b, t, h, n)
    k = jnp.einsum("btd,de->bte", xk, params["w_k"]).reshape(b, t, h, n)
    v = jnp.einsum("btd,de->bte", xv, params["w_v"]).reshape(b, t, h, vd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, params["w_g"])
                    .astype(jnp.float32))
    logw = _decay_log(params, xw).reshape(b, t, h, n)         # [B,T,H,N] < 0

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    u = params["u"]                                            # [H,N]

    # --- chunked wkv
    q = min(s.chunk_size, t)
    tp = (t + q - 1) // q * q
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0), (0, 0))
        rf, kf, vf = (jnp.pad(a, pad) for a in (rf, kf, vf))
        logw = jnp.pad(logw, pad)
    nc = tp // q

    def to_chunks(arr):
        return arr.reshape((b, nc, q) + arr.shape[2:]).swapaxes(0, 1)

    r_c, k_c, v_c, w_c = map(to_chunks, (rf, kf, vf, logw))
    strict_mask = jnp.tril(jnp.ones((q, q), jnp.float32), k=-1)

    def chunk_step(state, inp):
        rq, kq, vq, wq = inp              # [B,Q,H,N] ([B,Q,H,V] for vq)
        cum = jnp.cumsum(wq, axis=1)      # inclusive [B,Q,H,N]
        cum_m1 = cum - wq                 # exclusive (up to t-1)
        # inter: o_t += (r_t * exp(cum_{t-1})) . state_in
        y_inter = jnp.einsum("bqhn,bhnv->bqhv", rq * jnp.exp(cum_m1), state)
        # intra (j < t): A[t,j] = sum_n r[t,n] k[j,n] exp(cum_m1[t,n]-cum[j,n])
        dec = jnp.exp(jnp.clip(cum_m1[:, :, None] - cum[:, None], None, 0.0))
        a_tj = jnp.einsum("bqhn,bjhn,bqjhn->bqjh", rq, kq, dec)
        a_tj = a_tj * strict_mask[None, :, :, None]
        y_intra = jnp.einsum("bqjh,bjhv->bqhv", a_tj, vq)
        # diagonal bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bqhn,hn,bqhn->bqh", rq, u, kq)
        y_diag = bonus[..., None] * vq
        # state update: s' = exp(cum[-1]) * s + sum_j exp(cum[-1]-cum[j]) k_j v_j
        dec_last = jnp.exp(cum[:, -1:] - cum)                  # [B,Q,H,N]
        state = state * jnp.exp(cum[:, -1])[..., None]
        state = state + jnp.einsum("bqhn,bqhv->bhnv", kq * dec_last, vq)
        return state, y_inter + y_intra + y_diag

    state0 = (wkv_state if wkv_state is not None
              else jnp.zeros((b, h, n, vd), jnp.float32))
    state, y = jax.lax.scan(chunk_step, state0, (r_c, k_c, v_c, w_c))
    y = y.swapaxes(0, 1).reshape(b, tp, h, vd)[:, :t]

    # per-head group norm + gate + out projection
    y = _head_norm(y, params["ln_x"], cfg.norm_eps).reshape(b, t, d)
    out = jnp.einsum("btd,de->bte", (y * g).astype(x.dtype), params["w_o"])
    if return_state:
        return out, (x[:, -1], state)
    return out


def _head_norm(y: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """GroupNorm(heads) over the V dim; scale is [D] reshaped per head."""
    b, t, h, vd = y.shape
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mean) * jax.lax.rsqrt(var + eps)
    return yn * scale.reshape(1, 1, h, vd)


def rwkv6_timemix_decode(params, cfg: ArchConfig, x1: jax.Array,
                         shift_state: jax.Array, wkv_state: jax.Array):
    """One token: x1 [B, D].  Returns (out [B, D], x1, wkv_state')."""
    b, d = x1.shape
    h, n, vd = rwkv6_dims(cfg)
    x = x1[:, None]
    xprev = shift_state[:, None].astype(x.dtype)
    xr, xk, xv, xw, xg = _ddlerp(params, x, xprev)

    r = jnp.einsum("btd,de->bte", xr, params["w_r"]).reshape(b, h, n)
    k = jnp.einsum("btd,de->bte", xk, params["w_k"]).reshape(b, h, n)
    v = jnp.einsum("btd,de->bte", xv, params["w_v"]).reshape(b, h, vd)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, params["w_g"])
                    .astype(jnp.float32)).reshape(b, h, vd)
    w = jnp.exp(_decay_log(params, xw).reshape(b, h, n))       # [B,H,N]

    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    kv = kf[..., None] * vf[:, :, None, :]                     # [B,H,N,V]
    o = jnp.einsum("bhn,bhnv->bhv", rf,
                   wkv_state + params["u"][None, :, :, None] * kv)
    wkv_state = wkv_state * w[..., None] + kv

    o = _head_norm(o[:, None].reshape(b, 1, h, vd), params["ln_x"],
                   cfg.norm_eps).reshape(b, h, vd)
    out = jnp.einsum("bd,de->be", (o * g).reshape(b, d).astype(x1.dtype),
                     params["w_o"])
    return out, x1, wkv_state


def rwkv6_channelmix(params, x: jax.Array, *,
                     shift_state: Optional[jax.Array] = None,
                     return_state: bool = False):
    xprev = _token_shift(x, shift_state)
    sx = (xprev - x).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xk = (xf + sx * params["mu_k"]).astype(x.dtype)
    xr = (xf + sx * params["mu_r"]).astype(x.dtype)
    kk = jnp.einsum("btd,df->btf", xk, params["c_wk"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    out = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", xr, params["c_wr"]).astype(jnp.float32)
    ).astype(x.dtype) * jnp.einsum("btf,fd->btd", kk, params["c_wv"])
    if return_state:
        return out, x[:, -1]
    return out


def rwkv6_channelmix_decode(params, x1: jax.Array, shift_state: jax.Array):
    out = rwkv6_channelmix(params, x1[:, None],
                           shift_state=shift_state)
    return out[:, 0], x1


def rwkv6_recurrent_oracle(params, cfg: ArchConfig, x: jax.Array):
    """Token-by-token time-mix oracle for the chunked form."""
    b, t, d = x.shape
    h, n, vd = rwkv6_dims(cfg)
    shift = jnp.zeros((b, d), x.dtype)
    wkv = jnp.zeros((b, h, n, vd), jnp.float32)
    outs = []
    for i in range(t):
        o, shift, wkv = rwkv6_timemix_decode(params, cfg, x[:, i], shift, wkv)
        outs.append(o)
    return jnp.stack(outs, 1), (shift, wkv)
