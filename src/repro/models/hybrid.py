"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every N layers (weights reused at each invocation; each invocation has its
own growing KV, cached as DPC pages).

The layer stack is scanned in static segments of ``hybrid_attn_every`` mamba
layers; the shared block runs between segments.  (Real Zamba2 additionally
concatenates the original embedding into the shared block input and applies
per-invocation LoRA — omitted; noted in DESIGN.md.)
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers, ssm_mamba2
from repro.models.cache import HybridCache, LocalBackend, PagedKVCache, SSMCache
from repro.models.lm import stack_specs
from repro.models.spec import ParamSpec


def hybrid_segments(cfg: ArchConfig) -> List[int]:
    """Sizes of consecutive mamba segments; shared attn runs after each
    *full* segment (not after a trailing remainder)."""
    e = cfg.hybrid_attn_every
    n_full, rem = divmod(cfg.num_layers, e)
    return [e] * n_full + ([rem] if rem else [])


def n_attn_invocations(cfg: ArchConfig) -> int:
    return cfg.num_layers // cfg.hybrid_attn_every


def _mamba_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln": layers.rms_norm_spec(cfg.d_model),
        "mamba": ssm_mamba2.mamba2_specs(cfg),
    }


def _shared_block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": layers.rms_norm_spec(cfg.d_model),
        "ln2": layers.rms_norm_spec(cfg.d_model),
        "attn": layers.gqa_specs(cfg),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_variant,
                                cfg.param_dtype),
    }


def hybrid_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "embedding": layers.embedding_specs(cfg),
        "mamba_layers": stack_specs(_mamba_layer_specs(cfg), cfg.num_layers),
        "shared_attn": _shared_block_specs(cfg),   # ONE block, reused
        "final_norm": layers.rms_norm_spec(cfg.d_model),
    }


def _shared_fwd(sp, cfg, x, positions):
    h = sharding.act(layers.rms_norm(x, sp["ln1"], cfg.norm_eps),
                     ("batch", None, None))
    attn_out, (k, v) = layers.self_attention_block(sp["attn"], cfg, h,
                                                   positions)
    x = x + attn_out
    h = sharding.act(layers.rms_norm(x, sp["ln2"], cfg.norm_eps),
                     ("batch", None, None))
    return x + layers.mlp_apply(sp["mlp"], h, cfg.mlp_variant), (k, v)


def _tree_slice(tree, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def forward_hidden(params, cfg: ArchConfig, embeds, positions, *,
                   collect_kv: bool = False, collect_state: bool = False,
                   remat: bool = True, pools=None, writer=None):
    """Returns (hidden, kv [n_invoc, 2, B, S, Hkv, hd] | pools' | None,
    ssm_states).  With (pools, writer) each shared-attn invocation's KV is
    installed into its pool slice."""
    segs = hybrid_segments(cfg)
    x = embeds
    kv_all, conv_all, ssd_all = [], [], []
    ofs = 0
    for i, seg in enumerate(segs):
        seg_params = _tree_slice(params["mamba_layers"], ofs, ofs + seg)

        def mamba_body(x, lp):
            h = layers.rms_norm(x, lp["ln"], cfg.norm_eps)
            if collect_state:
                out, (conv, st) = ssm_mamba2.mamba2_forward(
                    lp["mamba"], cfg, h, return_state=True)
                return sharding.act(x + out, ("batch", "seq", None)), \
                    (conv, st)
            out = x + ssm_mamba2.mamba2_forward(lp["mamba"], cfg, h)
            return sharding.act(out, ("batch", "seq", None)), None

        body = jax.checkpoint(mamba_body) if remat else mamba_body
        x, states = jax.lax.scan(body, x, seg_params)
        if collect_state:
            conv_all.append(states[0])
            ssd_all.append(states[1])
        ofs += seg
        if seg == cfg.hybrid_attn_every:   # full segment -> shared block
            x, (k, v) = _shared_fwd(params["shared_attn"], cfg, x, positions)
            if pools is not None:
                inv = len(kv_all)
                new_pool = writer.write((pools[0][inv], pools[1][inv]),
                                        jnp.stack([k, v]))
                kv_all.append(new_pool)
            elif collect_kv:
                kv_all.append(jnp.stack([k, v]))

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if pools is not None and kv_all:
        kv = (jnp.stack([p[0] for p in kv_all]),
              jnp.stack([p[1] for p in kv_all]))
    else:
        kv = jnp.stack(kv_all) if (collect_kv and kv_all) else None
    ssm_states = ((jnp.concatenate(conv_all), jnp.concatenate(ssd_all))
                  if collect_state else None)
    return x, kv, ssm_states


def train_loss(params, cfg: ArchConfig, batch, *, remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = layers.embed_tokens(params["embedding"], tokens)
    hidden, _, _ = forward_hidden(params, cfg, x, positions, remat=remat)
    loss = layers.chunked_lm_loss(hidden, labels, params["embedding"], cfg)
    return loss, {"ce": loss}


def prefill(params, cfg: ArchConfig, batch, *, remat: bool = True,
            pools=None, writer=None):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = layers.embed_tokens(params["embedding"], tokens)
    hidden, kv, states = forward_hidden(params, cfg, x, positions,
                                        collect_kv=True, collect_state=True,
                                        remat=remat, pools=pools,
                                        writer=writer)
    logits = layers.unembed(params["embedding"], cfg, hidden[:, -1])
    return logits, kv, states


def decode_step(params, cfg: ArchConfig, tokens, positions,
                cache: HybridCache, backend=None):
    pc = cache.attn
    if backend is None:
        backend = LocalBackend(pc.page_table, pc.seq_lens, pc.append_slot)
    segs = hybrid_segments(cfg)
    x1 = layers.embed_tokens(params["embedding"], tokens[:, None])[:, 0]

    new_conv, new_ssd, new_k, new_v = [], [], [], []
    ofs, inv = 0, 0
    for seg in segs:
        seg_params = _tree_slice(params["mamba_layers"], ofs, ofs + seg)
        conv_seg = cache.ssm.conv[ofs:ofs + seg]
        ssd_seg = cache.ssm.state[ofs:ofs + seg]

        def mamba_body(x1, xs):
            lp, conv, st = xs
            h = layers.rms_norm(x1[:, None], lp["ln"], cfg.norm_eps)[:, 0]
            out, conv, st = ssm_mamba2.mamba2_decode(lp["mamba"], cfg, h,
                                                     conv, st)
            return x1 + out, (conv, st)

        x1, (conv_out, ssd_out) = jax.lax.scan(
            mamba_body, x1, (seg_params, conv_seg, ssd_seg))
        new_conv.append(conv_out)
        new_ssd.append(ssd_out)
        ofs += seg
        if seg == cfg.hybrid_attn_every:
            sp = params["shared_attn"]
            h = layers.rms_norm(x1[:, None], sp["ln1"], cfg.norm_eps)
            q, k, v = layers.gqa_project_qkv(sp["attn"], cfg, h,
                                             positions[:, None])
            out, kp, vp = backend.attend(q[:, 0], k[:, 0], v[:, 0],
                                         pc.k_pools[inv], pc.v_pools[inv])
            x1 = x1 + layers.gqa_output(sp["attn"], out[:, None])[:, 0]
            h = layers.rms_norm(x1[:, None], sp["ln2"], cfg.norm_eps)
            x1 = x1 + layers.mlp_apply(sp["mlp"], h, cfg.mlp_variant)[:, 0]
            new_k.append(kp)
            new_v.append(vp)
            inv += 1

    new_cache = HybridCache(
        ssm=SSMCache(jnp.concatenate(new_conv), jnp.concatenate(new_ssd)),
        attn=pc._replace(k_pools=jnp.stack(new_k), v_pools=jnp.stack(new_v),
                         seq_lens=pc.seq_lens + 1))
    x1 = layers.rms_norm(x1[:, None], params["final_norm"],
                         cfg.norm_eps)[:, 0]
    logits = layers.unembed(params["embedding"], cfg, x1)
    return logits, new_cache
