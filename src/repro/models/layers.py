"""Common transformer building blocks (pure JAX, spec-tree style)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models.spec import ParamSpec, pad_to_multiple

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), ("embed",), init="ones", dtype="float32")


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk-norm: normalize over the head_dim (last axis), scale: [head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, variant: str,
              dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    if variant == "swiglu":
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
            "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
            "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype),
        }
    # squared_relu / gelu: two matrices
    return {
        "w_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype),
    }


def mlp_apply(params: Dict[str, jax.Array], x: jax.Array, variant: str) -> jax.Array:
    # The hidden is pinned seq-UNSHARDED / ffn-sharded: under sequence
    # parallelism XLA otherwise resolves the x(seq-sharded) x w(ffn-sharded)
    # conflict by fully replicating the (huge) weights instead of gathering
    # the activations — EXPERIMENTS.md §Perf iteration B2.
    from repro import sharding as shardlib

    def pin(h):
        return shardlib.act(h, ("batch",) + (None,) * (h.ndim - 2) + ("mlp",))

    if variant == "swiglu":
        g = pin(jnp.einsum("...d,df->...f", x, params["w_gate"]))
        u = pin(jnp.einsum("...d,df->...f", x, params["w_up"]))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif variant == "squared_relu":
        u = pin(jnp.einsum("...d,df->...f", x, params["w_up"]))
        r = jax.nn.relu(u.astype(jnp.float32))
        h = (r * r).astype(x.dtype)
    elif variant == "gelu":
        u = pin(jnp.einsum("...d,df->...f", x, params["w_up"]))
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(variant)
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=shardlib.tp_dot_dtype())


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = cfg.param_dtype
    specs = {
        "w_q": ParamSpec((d, hq, hd), ("embed", "heads", None), dt),
        "w_k": ParamSpec((d, hkv, hd), ("embed", "kv_heads", None), dt),
        "w_v": ParamSpec((d, hkv, hd), ("embed", "kv_heads", None), dt),
        "w_o": ParamSpec((hq, hd, d), ("heads", None, "embed"), dt, fan_in=hq * hd),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), "float32", init="ones")
        specs["k_norm"] = ParamSpec((hd,), (None,), "float32", init="ones")
    return specs


def gqa_project_qkv(params, cfg: ArchConfig, x: jax.Array,
                    positions: jax.Array, *, rope: bool = True):
    """x: [B, S, D] -> q [B,S,Hq,hd], k/v [B,S,Hkv,hd] (rope+norm applied).

    Under sequence parallelism the projections consume seq-sharded x; the
    attention itself needs the full key sequence, so q/k/v are explicitly
    constrained seq-UNSHARDED here — one all-gather per layer at this
    boundary instead of XLA re-gathering inside every attention tile
    iteration (a 2080x blowup observed in the 32k dry-run; EXPERIMENTS.md
    §Perf iteration A1)."""
    from repro import sharding as shardlib
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qk_norm:
        q = head_rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shardlib.act(q, ("batch", None, "heads", None))
    k = shardlib.act(k, ("batch", None, "kv_heads", None))
    v = shardlib.act(v, ("batch", None, "kv_heads", None))
    return q, k, v


def gqa_output(params, attn: jax.Array) -> jax.Array:
    """attn: [B, S, Hq, hd] -> [B, S, D]."""
    from repro import sharding as shardlib
    return jnp.einsum("bshk,hkd->bsd", attn, params["w_o"],
                      preferred_element_type=shardlib.tp_dot_dtype())


def self_attention_block(params, cfg: ArchConfig, x: jax.Array,
                         positions: jax.Array, *, causal: bool = True):
    """Full prefill/train self-attention; returns (out, (k, v)) for caching."""
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    attn = dispatch.flash_attention(q, k, v, causal=causal)
    return gqa_output(params, attn), (k, v)


def cross_attention_block(params, cfg: ArchConfig, x: jax.Array,
                          kv_embeds: jax.Array):
    """Cross-attn against precomputed (image) embeddings [B, T, D]."""
    b, s, _ = x.shape
    zero_pos = jnp.zeros((b, s), jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("btd,dhk->bthk", kv_embeds, params["w_k"])
    v = jnp.einsum("btd,dhk->bthk", kv_embeds, params["w_v"])
    if cfg.qk_norm:
        q = head_rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, params["k_norm"], cfg.norm_eps)
    del zero_pos
    attn = dispatch.flash_attention(q, k, v, causal=False)
    return gqa_output(params, attn)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    v = pad_to_multiple(cfg.vocab_size, 128)
    specs = {"tok_embed": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                                    cfg.param_dtype, fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                                     cfg.param_dtype)
    return specs


def embed_tokens(params, tokens: jax.Array) -> jax.Array:
    return params["tok_embed"][tokens]


def unembed(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["tok_embed"])
    return jnp.einsum("...d,dv->...v", x, params["unembed"])


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          true_vocab: int) -> jax.Array:
    """Mean CE over tokens; logits may be vocab-padded (padded ids masked)."""
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if v > true_vocab:
        pad_mask = jnp.arange(v) >= true_vocab
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def chunked_lm_loss(x: jax.Array, labels: jax.Array, params, cfg: ArchConfig,
                    *, chunk: int = 1024) -> jax.Array:
    """CE computed in sequence chunks to bound the [*, vocab] logits buffer."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    n = (s + chunk - 1) // chunk
    sp = n * chunk
    xp = jnp.pad(x, ((0, 0), (0, sp - s), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, sp - s)))
    valid = jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, sp - s)))
    xc = xp.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = lp.reshape(b, n, chunk).swapaxes(0, 1)
    vc = valid.reshape(b, n, chunk).swapaxes(0, 1)

    def step(acc, inp):
        from repro import sharding as shardlib
        xi, li, vi = inp
        # vocab-sharded logits want seq-unsharded inputs (see mlp_apply note)
        xi = shardlib.act(xi, ("batch", None, None))
        logits = shardlib.act(unembed(params, cfg, xi),
                              ("batch", None, "vocab"))
        v = logits.shape[-1]
        lf = logits.astype(jnp.float32)
        if v > cfg.vocab_size:
            lf = jnp.where(jnp.arange(v) >= cfg.vocab_size, -1e30, lf)
        lse = jax.nn.logsumexp(lf, axis=-1)
        picked = jnp.take_along_axis(lf, li[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - picked) * vi), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc, vc))
    return total / (b * s)
