"""Llama-3.2-Vision-style backbone: self-attn decoder with interleaved
cross-attention layers over precomputed image patch embeddings.

Layer pattern: every ``cross_attn_every``-th layer is a cross-attn block;
layers are scanned in groups of (E-1 self + 1 cross).  The vision frontend is
a STUB per the brief — ``input_specs`` supplies patch embeddings at d_model.

Cross-attn KV is *per-request static* state: computed once at prefill and
cached densely ([G, B, T_img, Hkv, hd]); image reuse across requests is the
"hot file" DPC case — the serving engine keys those pages by image hash.
Self-attn KV is paged as usual.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.cache import LocalBackend, PagedKVCache, VLMCache
from repro.models.lm import stack_specs
from repro.models.spec import ParamSpec


def vlm_groups(cfg: ArchConfig) -> Tuple[int, int]:
    e = cfg.vision.cross_attn_every
    assert cfg.num_layers % e == 0, "layers must divide into cross groups"
    return cfg.num_layers // e, e - 1   # (n_groups, self layers per group)


def _self_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": layers.rms_norm_spec(cfg.d_model),
        "ln2": layers.rms_norm_spec(cfg.d_model),
        "attn": layers.gqa_specs(cfg),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_variant,
                                cfg.param_dtype),
    }


def _cross_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    specs = _self_layer_specs(cfg)
    # cross-attn gating (llama-vision uses tanh gates on attn & mlp)
    specs["gate_attn"] = ParamSpec((1,), (None,), "float32", init="zeros")
    specs["gate_mlp"] = ParamSpec((1,), (None,), "float32", init="zeros")
    return specs


def vlm_specs(cfg: ArchConfig) -> Dict[str, Any]:
    g, n_self = vlm_groups(cfg)
    self_stack = stack_specs(_self_layer_specs(cfg), n_self)
    self_stack = jax.tree.map(
        lambda s: ParamSpec((g,) + s.shape, ("groups",) + s.logical_axes,
                            s.dtype, s.init, s.fan_in),
        self_stack, is_leaf=lambda x: isinstance(x, ParamSpec))
    cross_stack = stack_specs(_cross_layer_specs(cfg), g)
    return {
        "embedding": layers.embedding_specs(cfg),
        "self_layers": self_stack,       # [G, n_self, ...]
        "cross_layers": cross_stack,     # [G, ...]
        "final_norm": layers.rms_norm_spec(cfg.d_model),
    }


def _self_fwd(lp, cfg, x, positions):
    h = sharding.act(layers.rms_norm(x, lp["ln1"], cfg.norm_eps),
                     ("batch", None, None))
    attn_out, (k, v) = layers.self_attention_block(lp["attn"], cfg, h,
                                                   positions)
    x = x + attn_out
    h = sharding.act(layers.rms_norm(x, lp["ln2"], cfg.norm_eps),
                     ("batch", None, None))
    out = sharding.act(x + layers.mlp_apply(lp["mlp"], h, cfg.mlp_variant),
                       ("batch", "seq", None))
    return out, jnp.stack([k, v])


def _cross_kv(lp, cfg, image_embeds):
    k = jnp.einsum("btd,dhk->bthk", image_embeds, lp["attn"]["w_k"])
    v = jnp.einsum("btd,dhk->bthk", image_embeds, lp["attn"]["w_v"])
    if cfg.qk_norm:
        k = layers.head_rms_norm(k, lp["attn"]["k_norm"], cfg.norm_eps)
    return k, v


def _cross_fwd(lp, cfg, x, k, v):
    h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["w_q"])
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, lp["attn"]["q_norm"], cfg.norm_eps)
    from repro.kernels import dispatch
    attn = dispatch.flash_attention(q, k, v, causal=False)
    attn_out = layers.gqa_output(lp["attn"], attn)
    x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * attn_out
    h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * layers.mlp_apply(
        lp["mlp"], h, cfg.mlp_variant)


def forward_hidden(params, cfg: ArchConfig, embeds, positions, image_embeds,
                   *, collect_kv: bool = False, remat: bool = True,
                   pools=None, writer=None):
    """Returns (hidden, self_kv [L_self, 2, B, S, Hkv, hd] | pools' | None,
    cross_kv ([G,B,T,H,hd], [G,...]) | None).

    With (pools, writer) self-attn KV streams into page pools per layer."""
    g, n_self = vlm_groups(cfg)
    install = pools is not None
    if install:
        pools_g = (pools[0].reshape((g, n_self) + pools[0].shape[1:]),
                   pools[1].reshape((g, n_self) + pools[1].shape[1:]))

    def group_body(x, gp):
        if install:
            self_p, cross_p, pk, pv = gp
        else:
            self_p, cross_p = gp

        def self_body(carry, xs):
            x = carry
            if install:
                lp, pool_k, pool_v = xs
                x, kv = _self_fwd(lp, cfg, x, positions)
                pool_k, pool_v = writer.write((pool_k, pool_v), kv)
                return x, (pool_k, pool_v)
            lp = xs
            x, kv = _self_fwd(lp, cfg, x, positions)
            return x, kv if collect_kv else None

        if install:
            x, pools_out = jax.lax.scan(self_body, x, (self_p, pk, pv))
        else:
            x, kv_seg = jax.lax.scan(self_body, x, self_p)
            pools_out = None
        ck, cv = _cross_kv(cross_p, cfg, image_embeds)
        x = _cross_fwd(cross_p, cfg, x, ck, cv)
        cross_out = jnp.stack([ck, cv]) if (collect_kv or install) else None
        if install:
            return x, (pools_out, cross_out)
        return x, (kv_seg, cross_out)

    body = jax.checkpoint(group_body) if remat else group_body
    xs = ((params["self_layers"], params["cross_layers"], pools_g[0],
           pools_g[1]) if install
          else (params["self_layers"], params["cross_layers"]))
    x, (kv_groups, cross_groups) = jax.lax.scan(body, embeds, xs)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)

    if install:
        new_pools = (
            kv_groups[0].reshape((g * n_self,) + kv_groups[0].shape[2:]),
            kv_groups[1].reshape((g * n_self,) + kv_groups[1].shape[2:]))
        return x, new_pools, (cross_groups[:, 0], cross_groups[:, 1])
    if not collect_kv:
        return x, None, None
    # kv_groups: [G, n_self, 2, B, S, Hkv, hd] -> [G*n_self, 2, ...]
    kv = kv_groups.reshape((g * n_self,) + kv_groups.shape[2:])
    cross_k, cross_v = cross_groups[:, 0], cross_groups[:, 1]
    return x, kv, (cross_k, cross_v)


def train_loss(params, cfg: ArchConfig, batch, *, remat: bool = True):
    tokens, labels = batch["tokens"], batch["labels"]
    image_embeds = batch["image_embeds"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = layers.embed_tokens(params["embedding"], tokens)
    hidden, _, _ = forward_hidden(params, cfg, x, positions, image_embeds,
                                  remat=remat)
    loss = layers.chunked_lm_loss(hidden, labels, params["embedding"], cfg)
    return loss, {"ce": loss}


def prefill(params, cfg: ArchConfig, batch, *, remat: bool = True,
            pools=None, writer=None):
    tokens = batch["tokens"]
    image_embeds = batch["image_embeds"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = layers.embed_tokens(params["embedding"], tokens)
    hidden, kv, cross = forward_hidden(params, cfg, x, positions,
                                       image_embeds, collect_kv=True,
                                       remat=remat, pools=pools,
                                       writer=writer)
    logits = layers.unembed(params["embedding"], cfg, hidden[:, -1])
    return logits, kv, cross


def decode_step(params, cfg: ArchConfig, tokens, positions, cache: VLMCache,
                backend=None):
    """tokens: [B]; cache.self_attn pools: [L_self, P, page, Hkv, hd]."""
    pc = cache.self_attn
    if backend is None:
        backend = LocalBackend(pc.page_table, pc.seq_lens, pc.append_slot)
    g, n_self = vlm_groups(cfg)
    x1 = layers.embed_tokens(params["embedding"], tokens[:, None])[:, 0]

    def group_body(x1, xs):
        self_p, cross_p, pools_k, pools_v, ck, cv = xs
        pools_g = (pools_k, pools_v)

        def self_body(x1, xs2):
            lp, pools = xs2
            h = layers.rms_norm(x1[:, None], lp["ln1"], cfg.norm_eps)
            q, k, v = layers.gqa_project_qkv(lp["attn"], cfg, h,
                                             positions[:, None])
            out, kp, vp = backend.attend(q[:, 0], k[:, 0], v[:, 0],
                                         pools[0], pools[1])
            x1 = x1 + layers.gqa_output(lp["attn"], out[:, None])[:, 0]
            h = layers.rms_norm(x1[:, None], lp["ln2"], cfg.norm_eps)
            x1 = x1 + layers.mlp_apply(lp["mlp"], h, cfg.mlp_variant)[:, 0]
            return x1, (kp, vp)

        x1, pools_out = jax.lax.scan(self_body, x1,
                                     (self_p, (pools_g[0], pools_g[1])))
        x1 = _cross_fwd(cross_p, cfg, x1[:, None], ck, cv)[:, 0]
        return x1, pools_out

    pools_grouped = (
        pc.k_pools.reshape((g, n_self) + pc.k_pools.shape[1:]),
        pc.v_pools.reshape((g, n_self) + pc.v_pools.shape[1:]))
    x1, pools_out = jax.lax.scan(
        group_body, x1,
        (params["self_layers"], params["cross_layers"],
         pools_grouped[0], pools_grouped[1], cache.cross_k, cache.cross_v))

    kp = pools_out[0].reshape((g * n_self,) + pc.k_pools.shape[1:])
    vp = pools_out[1].reshape((g * n_self,) + pc.v_pools.shape[1:])
    new_cache = cache._replace(self_attn=pc._replace(
        k_pools=kp, v_pools=vp, seq_lens=pc.seq_lens + 1))

    x1 = layers.rms_norm(x1[:, None], params["final_norm"],
                         cfg.norm_eps)[:, 0]
    logits = layers.unembed(params["embedding"], cfg, x1)
    return logits, new_cache
