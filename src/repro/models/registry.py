"""Uniform model API over the 10 assigned architectures.

Every family exposes the same five entry points; the training loop, serving
engine, dry-run and benchmarks are family-agnostic:

    specs(cfg)                              parameter spec tree
    train_loss(params, cfg, batch)          -> (loss, metrics)
    prefill(params, cfg, batch)             -> (last logits, kv, extra)
    decode_step(params, cfg, tok, pos, cache, backend) -> (logits, cache')
    init_cache(cfg, dpc, batch, max_pages)  decode cache pytree
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, DPCConfig
from repro.models import cache as cache_lib
from repro.models import hybrid as hybrid_mod
from repro.models import lm as lm_mod
from repro.models import vlm as vlm_mod
from repro.models.cache import (HybridCache, MLAPagedCache, PagedKVCache,
                                RWKVCache, VLMCache)


class ModelAPI(NamedTuple):
    family: str
    specs: Callable[[ArchConfig], Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


# ---------------------------------------------------------------------------
# cache factories
# ---------------------------------------------------------------------------


def _init_cache_lm(cfg: ArchConfig, dpc: DPCConfig, batch: int,
                   max_pages: int, *, pool_pages=None, abstract=False):
    if cfg.block_kind == "rwkv6":
        return cache_lib.alloc_rwkv(cfg, batch, abstract=abstract)
    return cache_lib.alloc_paged(cfg, dpc, batch, max_pages,
                                 pool_pages=pool_pages, abstract=abstract)


def _init_cache_hybrid(cfg: ArchConfig, dpc: DPCConfig, batch: int,
                       max_pages: int, *, pool_pages=None, abstract=False):
    n_inv = hybrid_mod.n_attn_invocations(cfg)
    return HybridCache(
        ssm=cache_lib.alloc_ssm(cfg, batch, abstract=abstract),
        attn=cache_lib.alloc_paged(cfg, dpc, batch, max_pages,
                                   num_layers=n_inv, pool_pages=pool_pages,
                                   abstract=abstract))


def _init_cache_vlm(cfg: ArchConfig, dpc: DPCConfig, batch: int,
                    max_pages: int, *, pool_pages=None, abstract=False):
    g, n_self = vlm_mod.vlm_groups(cfg)
    t = cfg.vision.num_image_tokens
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(dpc.kv_dtype)
    mk = (jax.ShapeDtypeStruct if abstract else jnp.zeros)
    return VLMCache(
        self_attn=cache_lib.alloc_paged(cfg, dpc, batch, max_pages,
                                        num_layers=g * n_self,
                                        pool_pages=pool_pages,
                                        abstract=abstract),
        cross_k=mk((g, batch, t, hkv, hd), dt),
        cross_v=mk((g, batch, t, hkv, hd), dt))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _lm_api(family: str) -> ModelAPI:
    return ModelAPI(family, lm_mod.lm_specs, lm_mod.train_loss,
                    lm_mod.prefill, lm_mod.decode_step, _init_cache_lm)


_API: Dict[str, ModelAPI] = {
    "dense": _lm_api("dense"),
    "moe": _lm_api("moe"),
    "audio": _lm_api("audio"),
    "ssm": _lm_api("ssm"),
    "vlm": ModelAPI("vlm", vlm_mod.vlm_specs, vlm_mod.train_loss,
                    vlm_mod.prefill, vlm_mod.decode_step, _init_cache_vlm),
    "hybrid": ModelAPI("hybrid", hybrid_mod.hybrid_specs,
                       hybrid_mod.train_loss, hybrid_mod.prefill,
                       hybrid_mod.decode_step, _init_cache_hybrid),
}


def get_model(cfg: ArchConfig) -> ModelAPI:
    return _API[cfg.family]


# ---------------------------------------------------------------------------
# batch construction (concrete + abstract "input_specs" for the dry-run)
# ---------------------------------------------------------------------------


def train_batch_spec(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    def tok(shape):
        return jax.ShapeDtypeStruct(shape, jnp.int32)
    if cfg.family == "audio" and cfg.audio is not None:
        k = cfg.audio.num_codebooks
        return {"tokens": tok((batch, k, seq)), "labels": tok((batch, k, seq))}
    spec = {"tokens": tok((batch, seq)), "labels": tok((batch, seq))}
    if cfg.family == "vlm":
        spec["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision.num_image_tokens, cfg.d_model),
            jnp.dtype(cfg.activation_dtype))
    return spec


def prefill_batch_spec(cfg: ArchConfig, batch: int, seq: int):
    spec = train_batch_spec(cfg, batch, seq)
    del spec["labels"]
    return spec


def decode_token_spec(cfg: ArchConfig, batch: int):
    if cfg.family == "audio" and cfg.audio is not None:
        return jax.ShapeDtypeStruct((batch, cfg.audio.num_codebooks),
                                    jnp.int32)
    return jax.ShapeDtypeStruct((batch,), jnp.int32)


def make_train_batch(cfg: ArchConfig, batch: int, seq: int,
                     key: jax.Array) -> Dict[str, Any]:
    """Concrete random batch matching train_batch_spec (smoke tests)."""
    spec = train_batch_spec(cfg, batch, seq)
    out = {}
    for name, s in spec.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            vocab = (cfg.audio.codebook_size if cfg.family == "audio"
                     and cfg.audio else cfg.vocab_size)
            out[name] = jax.random.randint(sub, s.shape, 0, vocab, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(
                s.dtype)
    return out


def greedy_sample(logits: jax.Array) -> jax.Array:
    """[B, V] -> [B]; audio [B, K, V] -> [B, K]."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
