"""Decode-time caches + the local attention backend.

Caches are NamedTuple pytrees with per-layer leaves stacked on dim 0, so the
layer scan feeds each layer its slice as scan xs and collects the updated
slice as scan ys.  Attention caches are *paged*: physical pools indexed
through page tables — the structure DPC's directory governs.  ``page_table``
holds page ids in the pool's own id space: local slot ids in single-node
mode, global ``node * P + slot`` ids under DPC (the distributed backend in
``core/ship_compute.py`` resolves ownership per shard).

``append_slot`` is the *local* slot of each request's currently-filling page
(new tokens always land in pages the request's home node owns — ACC_MISS_ALLOC
grants E locally, exactly the paper's preallocated DMA target).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, DPCConfig
from repro.kernels import dispatch


class PagedKVCache(NamedTuple):
    k_pools: jax.Array      # [L, P, page, Hkv, D]
    v_pools: jax.Array      # [L, P, page, Hkv, D]
    page_table: jax.Array   # [B, N] int32 page ids (-1 invalid)
    seq_lens: jax.Array     # [B] int32 tokens already cached
    append_slot: jax.Array  # [B] int32 local slot of the filling page

    @property
    def page_size(self) -> int:
        return self.k_pools.shape[2]


class MLAPagedCache(NamedTuple):
    latent_pools: jax.Array  # [L, P, page, R+Dr]
    page_table: jax.Array
    seq_lens: jax.Array
    append_slot: jax.Array

    @property
    def page_size(self) -> int:
        return self.latent_pools.shape[2]


class SSMCache(NamedTuple):
    """Mamba2 per-layer recurrent state."""
    conv: jax.Array    # [L, B, K-1, Dconv]
    state: jax.Array   # [L, B, H, P, N]


class RWKVCache(NamedTuple):
    tm_shift: jax.Array  # [L, B, D] last token entering time-mix
    cm_shift: jax.Array  # [L, B, D] last token entering channel-mix
    wkv: jax.Array       # [L, B, H, N, V]


class HybridCache(NamedTuple):
    """zamba2: mamba states for every layer + paged KV per shared-attn call."""
    ssm: SSMCache
    attn: PagedKVCache   # leaves stacked over the n_invocations dim


class VLMCache(NamedTuple):
    """llama-vision: paged self-attn KV + static per-request image KV."""
    self_attn: PagedKVCache       # [L_self, ...]
    cross_k: jax.Array            # [G, B, T_img, Hkv, D]
    cross_v: jax.Array


# ---------------------------------------------------------------------------
# allocation
# ---------------------------------------------------------------------------


def alloc_paged(cfg: ArchConfig, dpc: DPCConfig, batch: int, max_pages: int,
                num_layers: Optional[int] = None, pool_pages: Optional[int] = None,
                dtype=None, abstract: bool = False):
    """Paged KV (or MLA latent) cache for ``batch`` requests."""
    L = num_layers if num_layers is not None else cfg.num_attn_layers
    P = pool_pages if pool_pages is not None else dpc.pool_pages_per_shard
    page = dpc.page_size
    dt = jnp.dtype(dtype or dpc.kv_dtype)
    mk = (jax.ShapeDtypeStruct if abstract
          else lambda s, d: jnp.zeros(s, d))
    pt = (jax.ShapeDtypeStruct((batch, max_pages), jnp.int32) if abstract
          else jnp.full((batch, max_pages), -1, jnp.int32))
    common = dict(
        page_table=pt,
        seq_lens=mk((batch,), jnp.int32),
        append_slot=mk((batch,), jnp.int32),
    )
    if cfg.mla is not None:
        rd = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return MLAPagedCache(
            latent_pools=mk((L, P, page, rd), dt), **common)
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return PagedKVCache(
        k_pools=mk((L, P, page, hkv, hd), dt),
        v_pools=mk((L, P, page, hkv, hd), dt), **common)


def alloc_ssm(cfg: ArchConfig, batch: int, num_layers: Optional[int] = None,
              abstract: bool = False):
    s = cfg.ssm
    L = num_layers if num_layers is not None else cfg.num_layers
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    d_conv = d_in + 2 * s.state_dim
    mk = (jax.ShapeDtypeStruct if abstract else jnp.zeros)
    return SSMCache(
        conv=mk((L, batch, s.conv_kernel - 1, d_conv), jnp.float32),
        state=mk((L, batch, h, s.head_dim, s.state_dim), jnp.float32),
    )


def alloc_rwkv(cfg: ArchConfig, batch: int, abstract: bool = False):
    s = cfg.ssm
    L, d = cfg.num_layers, cfg.d_model
    h = d // s.head_dim
    mk = (jax.ShapeDtypeStruct if abstract else jnp.zeros)
    return RWKVCache(
        tm_shift=mk((L, batch, d), jnp.float32),
        cm_shift=mk((L, batch, d), jnp.float32),
        wkv=mk((L, batch, h, s.state_dim, s.head_dim), jnp.float32),
    )


# ---------------------------------------------------------------------------
# local (single-shard) decode backend
# ---------------------------------------------------------------------------


class LocalBackend:
    """Append + paged attention entirely against local pools.

    Used by smoke tests and single-replica serving; the DPC distributed
    backend (core/ship_compute.py) implements the same two methods over the
    sharded pool with cross-shard LSE combination.
    """

    def __init__(self, page_table, seq_lens, append_slot, *, impl="auto"):
        self.page_table = page_table
        self.seq_lens = seq_lens
        self.append_slot = append_slot
        self.impl = impl

    def attend(self, q, k_new, v_new, k_pool, v_pool
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """q: [B, Hq, D]; k_new/v_new: [B, Hkv, D]; pools: [P, page, Hkv, D].
        Appends the new token then attends over seq_lens+1 tokens.
        Negative append slots are dropped (inactive/padding requests)."""
        page = k_pool.shape[1]
        off = self.seq_lens % page
        slot = jnp.where(self.append_slot >= 0, self.append_slot,
                         k_pool.shape[0])
        k_pool = k_pool.at[slot, off].set(
            k_new.astype(k_pool.dtype), mode="drop")
        v_pool = v_pool.at[slot, off].set(
            v_new.astype(v_pool.dtype), mode="drop")
        out = dispatch.paged_attention(q, k_pool, v_pool, self.page_table,
                                       self.seq_lens + 1, impl=self.impl)
        return out, k_pool, v_pool

    def attend_mla(self, q_latent, q_rope, latent_new, latent_pool, *,
                   sm_scale=None):
        """latent_new: [B, R+Dr]; latent_pool: [P, page, R+Dr]."""
        page = latent_pool.shape[1]
        off = self.seq_lens % page
        slot = jnp.where(self.append_slot >= 0, self.append_slot,
                         latent_pool.shape[0])
        latent_pool = latent_pool.at[slot, off].set(
            latent_new.astype(latent_pool.dtype), mode="drop")
        out = dispatch.mla_paged_attention(
            q_latent, q_rope, latent_pool, self.page_table,
            self.seq_lens + 1, impl=self.impl, sm_scale=sm_scale)
        return out, latent_pool


class LocalPageWriter:
    """Installs prefill KV pages into local pool slots inside the layer scan.

    ``targets``: [B, n_pages] local slot ids (-1 = skip; engine provides the
    directory-granted slots).  The same writer object serves GQA pools
    ((k_pool, v_pool) + kv stacked [2, B, S, Hkv, hd]) and MLA latent pools
    (pool + latents [B, S, RD]).
    """

    def __init__(self, targets: jax.Array, page_size: int):
        self.targets = targets
        self.page_size = page_size

    def _pack(self, kv: jax.Array):
        """[B, S, ...] -> [B * n_pages, page, ...] (padded to page multiple)."""
        b, s = kv.shape[:2]
        page = self.page_size
        n_pages = self.targets.shape[1]
        sp = n_pages * page
        if sp != s:
            pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (kv.ndim - 2)
            kv = jnp.pad(kv, pad)
        return kv.reshape((b * n_pages, page) + kv.shape[2:])

    def _write(self, pool, pages):
        flat_t = self.targets.reshape(-1)
        slot = jnp.where(flat_t >= 0, flat_t, pool.shape[0])
        return pool.at[slot].set(pages.astype(pool.dtype), mode="drop")

    def write(self, pools, kv):
        if isinstance(pools, tuple):              # GQA (k_pool, v_pool)
            k_pool, v_pool = pools
            k_pool = self._write(k_pool, self._pack(kv[0]))
            v_pool = self._write(v_pool, self._pack(kv[1]))
            return (k_pool, v_pool)
        return self._write(pools, self._pack(kv))  # MLA latent pool


class DPCPageWriter:
    """Distributed prefill install: each node writes the granted pages it
    owns (global target ids; single-copy — exactly one writer per page).

    KV content arrives replicated across the model axis (kv projections are
    replicated in the DPC serve scheme), sharded over batch rows; the write
    itself is node-local, so installs cost no fabric traffic beyond the
    row-local replication already present.
    """

    def __init__(self, mesh, targets: jax.Array, page_size: int,
                 pool_pages: int, batch_axes=("pod", "data"),
                 head_axis="model"):
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.ship_compute import _my_node

        self.targets = targets
        self.page_size = page_size
        dpc_axes = tuple(ax for ax in (*batch_axes, head_axis)
                         if ax in mesh.axis_names)
        b_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)
        batch_p = (b_axes if len(b_axes) > 1
                   else (b_axes[0] if b_axes else None))
        dpc_p = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]
        page = page_size

        def write_one(pool, pages, targets):
            # pages: [B_loc * n_pages, page, ...]; targets: [B_loc, n_pages]
            me = _my_node(dpc_axes)
            flat_t = targets.reshape(-1)
            mine = (flat_t >= 0) & (flat_t // pool_pages == me)
            slot = jnp.where(mine, flat_t % pool_pages, pool.shape[0])
            return pool.at[slot].set(pages.astype(pool.dtype), mode="drop")

        def make(nd_pool, nd_pages):
            return shard_map(
                write_one, mesh=mesh,
                in_specs=(P(dpc_p, *([None] * (nd_pool - 1))),
                          P(batch_p, *([None] * (nd_pages - 1))),
                          P(batch_p, None)),
                out_specs=P(dpc_p, *([None] * (nd_pool - 1))),
                check_rep=False)

        self._write3 = make(3, 3)   # MLA latent pool [P, page, RD]
        self._write4 = make(4, 4)   # GQA pools [P, page, H, hd]

    def _pack(self, kv: jax.Array):
        b, s = kv.shape[:2]
        page = self.page_size
        n_pages = self.targets.shape[1]
        sp = n_pages * page
        if sp != s:
            pad = [(0, 0), (0, sp - s)] + [(0, 0)] * (kv.ndim - 2)
            kv = jnp.pad(kv, pad)
        return kv.reshape((b * n_pages, page) + kv.shape[2:])

    def write(self, pools, kv):
        if isinstance(pools, tuple):
            k_pool, v_pool = pools
            k_pool = self._write4(k_pool, self._pack(kv[0]), self.targets)
            v_pool = self._write4(v_pool, self._pack(kv[1]), self.targets)
            return (k_pool, v_pool)
        return self._write3(pools, self._pack(kv), self.targets)


def host_assign_pages(page_table, seq_lens, append_slot, page_size,
                      new_slots):
    """Host-side helper: when a request's filling page is full, bind a fresh
    slot (engine got it from the directory/pool) into the table.

    All arrays are numpy; returns updated (page_table, append_slot).
    """
    import numpy as np
    pt = np.asarray(page_table).copy()
    sl = np.asarray(seq_lens)
    ap = np.asarray(append_slot).copy()
    for b in range(pt.shape[0]):
        if sl[b] % page_size == 0:  # filling page is exactly full
            idx = sl[b] // page_size
            if idx < pt.shape[1] and new_slots[b] >= 0:
                pt[b, idx] = new_slots[b]
                ap[b] = new_slots[b]
    return pt, ap
