"""Generic decoder-only LM: dense / MoE / MLA / audio / RWKV stacks.

One scan-over-layers per homogeneous segment (e.g. DeepSeek = 1 dense-FFN
layer + 26 MoE layers = two segments), with per-layer params stacked on a
leading ``layers`` dim.  Prefill emits the KV page content per attention
layer (k/v or MLA latents) as scan outputs; decode threads paged pools
through the scan as xs/ys and calls the attention backend per layer.

The audio family (MusicGen) embeds the sum of K codebook tokens and predicts
K vocab heads; its frontend (EnCodec) is stubbed per the brief.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models import layers, mla, moe, rwkv6
from repro.models.cache import LocalBackend, MLAPagedCache, PagedKVCache, RWKVCache
from repro.models.spec import ParamSpec, is_spec_leaf, pad_to_multiple

# ---------------------------------------------------------------------------
# segments & specs
# ---------------------------------------------------------------------------


class Segment(NamedTuple):
    kind: str       # attn_dense | attn_moe | rwkv
    count: int


def lm_segments(cfg: ArchConfig) -> List[Segment]:
    if cfg.block_kind == "rwkv6":
        return [Segment("rwkv", cfg.num_layers)]
    assert cfg.block_kind == "attn"
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        return [Segment("attn_dense", cfg.moe.first_dense_layers),
                Segment("attn_moe",
                        cfg.num_layers - cfg.moe.first_dense_layers)]
    if cfg.moe is not None:
        return [Segment("attn_moe", cfg.num_layers)]
    return [Segment("attn_dense", cfg.num_layers)]


def stack_specs(per_layer, count: int):
    return jax.tree.map(
        lambda s: ParamSpec((count,) + s.shape, ("layers",) + s.logical_axes,
                            s.dtype, s.init, s.fan_in),
        per_layer, is_leaf=is_spec_leaf)


def _attn_specs(cfg: ArchConfig) -> Dict[str, Any]:
    if cfg.mla is not None:
        return mla.mla_specs(cfg)
    return layers.gqa_specs(cfg)


def _layer_specs(cfg: ArchConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    if kind == "rwkv":
        return {
            "ln1": layers.rms_norm_spec(d),
            "ln2": layers.rms_norm_spec(d),
            "tm": rwkv6.rwkv6_timemix_specs(cfg),
            "cm": rwkv6.rwkv6_channelmix_specs(cfg),
        }
    specs = {
        "ln1": layers.rms_norm_spec(d),
        "ln2": layers.rms_norm_spec(d),
        "attn": _attn_specs(cfg),
    }
    if kind == "attn_moe":
        specs["moe"] = moe.moe_specs(cfg)
    else:
        ffn = (cfg.moe.dense_ffn if cfg.moe is not None and cfg.moe.dense_ffn
               else cfg.d_ff)
        specs["mlp"] = layers.mlp_specs(d, ffn, cfg.mlp_variant,
                                        cfg.param_dtype)
    return specs


def _embedding_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    if cfg.family == "audio" and cfg.audio is not None:
        a = cfg.audio
        v = pad_to_multiple(a.codebook_size, 128)
        return {
            "code_embed": ParamSpec((a.num_codebooks, v, cfg.d_model),
                                    ("codebooks", "vocab", "embed"),
                                    cfg.param_dtype, fan_in=cfg.d_model),
            "code_unembed": ParamSpec((a.num_codebooks, cfg.d_model, v),
                                      ("codebooks", "embed", "vocab"),
                                      cfg.param_dtype),
        }
    return layers.embedding_specs(cfg)


def lm_specs(cfg: ArchConfig) -> Dict[str, Any]:
    segs = lm_segments(cfg)
    return {
        "embedding": _embedding_specs(cfg),
        "segments": [stack_specs(_layer_specs(cfg, s.kind), s.count)
                     for s in segs],
        "final_norm": layers.rms_norm_spec(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def embed(params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    """tokens: [B, S] (LM) or [B, K, S] (audio codes)."""
    emb = params["embedding"]
    if cfg.family == "audio" and cfg.audio is not None:
        # sum of codebook embeddings
        k = cfg.audio.num_codebooks
        parts = [emb["code_embed"][i][tokens[:, i]] for i in range(k)]
        return functools.reduce(jnp.add, parts)
    return layers.embed_tokens(emb, tokens)


def logits_head(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: [..., D] -> [..., V] (LM) or [..., K, V] (audio)."""
    emb = params["embedding"]
    if cfg.family == "audio" and cfg.audio is not None:
        return jnp.einsum("...d,kdv->...kv", x, emb["code_unembed"])
    return layers.unembed(emb, cfg, x)


def lm_loss(params, cfg: ArchConfig, hidden: jax.Array, labels: jax.Array
            ) -> jax.Array:
    if cfg.family == "audio" and cfg.audio is not None:
        logits = logits_head(params, cfg, hidden)        # [B,S,K,V]
        lf = logits.astype(jnp.float32)
        v = lf.shape[-1]
        if v > cfg.audio.codebook_size:
            lf = jnp.where(jnp.arange(v) >= cfg.audio.codebook_size,
                           -1e30, lf)
        lse = jax.nn.logsumexp(lf, axis=-1)
        lab = labels.transpose(0, 2, 1)                  # [B,S,K]
        picked = jnp.take_along_axis(lf, lab[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - picked)
    return layers.chunked_lm_loss(hidden, labels, params["embedding"], cfg)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------


def _attn_layer_fwd(lp, cfg: ArchConfig, x, positions, kind: str):
    """Returns (x', kv_pages, aux).

    Norm outputs are pinned seq-unsharded (Megatron-SP boundary): the norm
    runs on the seq-sharded residual, the gather moves bf16 activations, and
    the projection weights stay sharded (§Perf iteration B3)."""
    h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
    h = sharding.act(h, ("batch", None, None))
    if cfg.mla is not None:
        attn_out, latent = mla.mla_prefill_attention(lp["attn"], cfg, h,
                                                     positions)
        kv = latent                                          # [B,S,R+Dr]
    else:
        attn_out, (k, v) = layers.self_attention_block(lp["attn"], cfg, h,
                                                       positions)
        kv = jnp.stack([k, v])                               # [2,B,S,Hkv,hd]
    x = x + attn_out
    h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
    h = sharding.act(h, ("batch", None, None))
    if kind == "attn_moe":
        ffn_out, aux = moe.moe_apply(lp["moe"], cfg, h)
    else:
        ffn_out = layers.mlp_apply(lp["mlp"], h, cfg.mlp_variant)
        aux = jnp.zeros((), jnp.float32)
    out = sharding.act(x + ffn_out, ("batch", "seq", None))
    return out, kv, aux


def _rwkv_layer_fwd(lp, cfg: ArchConfig, x):
    h = layers.rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + rwkv6.rwkv6_timemix(lp["tm"], cfg, h)
    h = layers.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + rwkv6.rwkv6_channelmix(lp["cm"], h)
    return sharding.act(x, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_hidden(params, cfg: ArchConfig, embeds: jax.Array,
                   positions: jax.Array, *, collect_kv: bool = False,
                   remat: bool = True, pools=None, writer=None):
    """embeds: [B, S, D] -> (hidden [B, S, D], kv_or_pools, aux_sum).

    Without pools: kv_pages [L_attn, 2, B, S, Hkv, hd] (GQA) /
    [L, B, S, R+Dr] (MLA) / None (rwkv).
    With (pools, writer): each layer's KV is *streamed into the page pools*
    inside the scan (never materialized across layers) and the updated pools
    come back in kv's place — the prefill install path.
    """
    segs = lm_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    kv_all = []
    pools_all = []
    x = embeds
    is_mla = cfg.mla is not None
    ofs = 0

    for seg, seg_params in zip(segs, params["segments"]):
        if seg.kind == "rwkv":
            def rwkv_body(x, lp):
                return _rwkv_layer_fwd(lp, cfg, x), None
            body = jax.checkpoint(rwkv_body) if remat else rwkv_body
            x, _ = jax.lax.scan(body, x, seg_params)
            continue

        if pools is not None:
            sl = slice(ofs, ofs + seg.count)
            pools_seg = (pools[sl] if is_mla
                         else (pools[0][sl], pools[1][sl]))

            def attn_install_body(carry, xs, kind=seg.kind):
                x, aux = carry
                lp, pool_l = xs
                x, kv, a = _attn_layer_fwd(lp, cfg, x, positions, kind)
                pool_l = writer.write(pool_l, kv)
                return (x, aux + a), pool_l

            (x, aux_total), pools_out = jax.lax.scan(
                attn_install_body, (x, aux_total), (seg_params, pools_seg))
            pools_all.append(pools_out)
            ofs += seg.count
            continue

        def attn_body(carry, lp, kind=seg.kind):
            x, aux = carry
            x, kv, a = _attn_layer_fwd(lp, cfg, x, positions, kind)
            return (x, aux + a), kv if collect_kv else None

        body = jax.checkpoint(attn_body) if remat else attn_body
        (x, aux_total), kv_seg = jax.lax.scan(body, (x, aux_total),
                                              seg_params)
        if collect_kv:
            kv_all.append(kv_seg)
        ofs += seg.count

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if pools is not None and pools_all:
        if is_mla:
            out_pools = jnp.concatenate(pools_all, axis=0)
        else:
            out_pools = (jnp.concatenate([p[0] for p in pools_all], axis=0),
                         jnp.concatenate([p[1] for p in pools_all], axis=0))
        return x, out_pools, aux_total
    kv = jnp.concatenate(kv_all, axis=0) if kv_all else None
    return x, kv, aux_total


def train_loss(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
               *, remat: bool = True) -> Tuple[jax.Array, Dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    b = tokens.shape[0]
    s = tokens.shape[-1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = sharding.act(embed(params, cfg, tokens), ("batch", "seq", None))
    hidden, _, aux = forward_hidden(params, cfg, x, positions, remat=remat)
    loss = lm_loss(params, cfg, hidden, labels)
    return loss + aux, {"ce": loss, "aux": aux}


def prefill(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            *, remat: bool = True, pools=None, writer=None):
    """Returns (last-token logits, kv pages — or the updated pools when an
    install writer is provided)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    s = tokens.shape[-1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = sharding.act(embed(params, cfg, tokens), ("batch", "seq", None))
    hidden, kv, _ = forward_hidden(params, cfg, x, positions,
                                   collect_kv=cfg.block_kind == "attn",
                                   remat=remat, pools=pools, writer=writer)
    logits = logits_head(params, cfg, hidden[:, -1])
    return logits, kv


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _attn_layer_decode(lp, cfg: ArchConfig, x1, positions, kind: str,
                       backend, pools):
    """x1: [B, D].  pools: per-layer cache slice.  Returns (x1', pools')."""
    h = layers.rms_norm(x1[:, None], lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        latent_pool = pools
        ql, qr = mla.mla_decode_q(lp["attn"], cfg, h[:, 0], positions)
        latent_new = mla.latent_from_x(lp["attn"], cfg, h,
                                       positions[:, None])[:, 0]
        o_lat, latent_pool = backend.attend_mla(
            ql, qr, latent_new, latent_pool, sm_scale=mla.mla_sm_scale(cfg))
        attn_out = mla.mla_decode_out(lp["attn"], o_lat)
        pools = latent_pool
    else:
        k_pool, v_pool = pools
        q, k, v = layers.gqa_project_qkv(lp["attn"], cfg, h,
                                         positions[:, None])
        out, k_pool, v_pool = backend.attend(q[:, 0], k[:, 0], v[:, 0],
                                             k_pool, v_pool)
        attn_out = layers.gqa_output(lp["attn"], out[:, None])[:, 0]
        pools = (k_pool, v_pool)
    x1 = x1 + attn_out
    h = layers.rms_norm(x1[:, None], lp["ln2"], cfg.norm_eps)
    if kind == "attn_moe":
        ffn_out, _ = moe.moe_apply(lp["moe"], cfg, h)
    else:
        ffn_out = layers.mlp_apply(lp["mlp"], h, cfg.mlp_variant)
    return x1 + ffn_out[:, 0], pools


def _rwkv_layer_decode(lp, cfg: ArchConfig, x1, state):
    tm_shift, cm_shift, wkv = state
    h = layers.rms_norm(x1[:, None], lp["ln1"], cfg.norm_eps)[:, 0]
    o, tm_shift, wkv = rwkv6.rwkv6_timemix_decode(lp["tm"], cfg, h,
                                                  tm_shift, wkv)
    x1 = x1 + o
    h = layers.rms_norm(x1[:, None], lp["ln2"], cfg.norm_eps)[:, 0]
    o, cm_shift = rwkv6.rwkv6_channelmix_decode(lp["cm"], h, cm_shift)
    return x1 + o, (tm_shift, cm_shift, wkv)


def decode_step(params, cfg: ArchConfig, tokens: jax.Array,
                positions: jax.Array, cache, backend=None):
    """One decode token.

    tokens: [B] (LM) or [B, K] (audio); positions: [B].
    cache: PagedKVCache / MLAPagedCache / RWKVCache.
    Returns (logits, cache').
    """
    if backend is None and not isinstance(cache, RWKVCache):
        backend = LocalBackend(cache.page_table, cache.seq_lens,
                               cache.append_slot)
    segs = lm_segments(cfg)
    if cfg.family == "audio" and cfg.audio is not None:
        x1 = embed(params, cfg, tokens[..., None])[:, 0]
    else:
        x1 = embed(params, cfg, tokens[:, None])[:, 0]

    if cfg.block_kind == "rwkv6":
        def body(x1, xs):
            lp, st = xs
            x1, st = _rwkv_layer_decode(lp, cfg, x1, st)
            return x1, st
        x1, (tm, cm, wkv) = jax.lax.scan(
            body, x1, (params["segments"][0],
                       (cache.tm_shift, cache.cm_shift, cache.wkv)))
        new_cache = RWKVCache(tm, cm, wkv)
    else:
        is_mla = cfg.mla is not None
        layer_ofs = 0
        new_pools = []
        for seg, seg_params in zip(segs, params["segments"]):
            sl = slice(layer_ofs, layer_ofs + seg.count)
            if is_mla:
                pools_seg = cache.latent_pools[sl]
            else:
                pools_seg = (cache.k_pools[sl], cache.v_pools[sl])

            def body(x1, xs, kind=seg.kind):
                lp, pools = xs
                x1, pools = _attn_layer_decode(lp, cfg, x1, positions, kind,
                                               backend, pools)
                return x1, pools

            x1, pools_out = jax.lax.scan(body, x1, (seg_params, pools_seg))
            new_pools.append(pools_out)
            layer_ofs += seg.count

        if is_mla:
            lat = jnp.concatenate(new_pools, axis=0)
            new_cache = cache._replace(latent_pools=lat,
                                       seq_lens=cache.seq_lens + 1)
        else:
            kp = jnp.concatenate([p[0] for p in new_pools], axis=0)
            vp = jnp.concatenate([p[1] for p in new_pools], axis=0)
            new_cache = cache._replace(k_pools=kp, v_pools=vp,
                                       seq_lens=cache.seq_lens + 1)

    x1 = layers.rms_norm(x1[:, None], params["final_norm"],
                         cfg.norm_eps)[:, 0]
    logits = logits_head(params, cfg, x1)
    return logits, new_cache
