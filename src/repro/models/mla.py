"""DeepSeek-style Multi-head Latent Attention (paper's own DeepSeek workload).

The KV cache entry per token is the *compressed latent* [R + Dr] — 4–8x
smaller than GQA KV — which is exactly what makes MLA the best-case DPC
architecture: remote page fetches ship the latent, and the absorbed decode
attends directly in latent space (w_uk folded into q, w_uv applied after).

Prefill caches pages of latents; decode uses the absorbed form so remote
pages are consumed without expansion.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models import layers
from repro.models.spec import ParamSpec


def mla_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    c = cfg.mla
    d, h, dt = cfg.d_model, cfg.num_heads, cfg.param_dtype
    qd = c.qk_nope_head_dim + c.qk_rope_head_dim
    specs = {
        "w_dkv": ParamSpec((d, c.kv_lora_rank + c.qk_rope_head_dim),
                           ("embed", "kv_lora"), dt),
        "latent_norm": ParamSpec((c.kv_lora_rank,), (None,), "float32",
                                 init="ones"),
        "w_uk": ParamSpec((c.kv_lora_rank, h, c.qk_nope_head_dim),
                          ("kv_lora", "heads", None), dt),
        "w_uv": ParamSpec((c.kv_lora_rank, h, c.v_head_dim),
                          ("kv_lora", "heads", None), dt),
        "w_o": ParamSpec((h, c.v_head_dim, d), ("heads", None, "embed"), dt,
                         fan_in=h * c.v_head_dim),
    }
    if c.q_lora_rank:
        specs["w_dq"] = ParamSpec((d, c.q_lora_rank), ("embed", "q_lora"), dt)
        specs["q_norm"] = ParamSpec((c.q_lora_rank,), (None,), "float32",
                                    init="ones")
        specs["w_uq"] = ParamSpec((c.q_lora_rank, h, qd),
                                  ("q_lora", "heads", None), dt)
    else:
        specs["w_q"] = ParamSpec((d, h, qd), ("embed", "heads", None), dt)
    return specs


def _project_q(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> q [B, S, H, nope+rope]."""
    c = cfg.mla
    if c.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
        cq = layers.rms_norm(cq, params["q_norm"], cfg.norm_eps)
        return jnp.einsum("bsr,rhq->bshq", cq, params["w_uq"])
    return jnp.einsum("bsd,dhq->bshq", x, params["w_q"])


def mla_sm_scale(cfg: ArchConfig) -> float:
    c = cfg.mla
    return float((c.qk_nope_head_dim + c.qk_rope_head_dim) ** -0.5)


def latent_from_x(params, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """The cacheable per-token latent: [B, S, R+Dr] (normed latent ‖ roped k)."""
    c = cfg.mla
    ckv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    lat = layers.rms_norm(ckv[..., :c.kv_lora_rank], params["latent_norm"],
                          cfg.norm_eps)
    k_rope = ckv[..., None, c.kv_lora_rank:]                     # [B,S,1,Dr]
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([lat, k_rope], axis=-1)


def mla_prefill_attention(params, cfg: ArchConfig, x: jax.Array,
                          positions: jax.Array
                          ) -> Tuple[jax.Array, jax.Array]:
    """Full (non-absorbed) MLA attention for train/prefill.

    Returns (out [B, S, D], latent pages [B, S, R+Dr] for the cache).
    """
    c = cfg.mla
    b, s, _ = x.shape
    q = _project_q(params, cfg, x)                               # [B,S,H,qd]
    q_nope = q[..., :c.qk_nope_head_dim]
    q_rope = layers.apply_rope(q[..., c.qk_nope_head_dim:], positions,
                               cfg.rope_theta)

    latent = latent_from_x(params, cfg, x, positions)            # [B,S,R+Dr]
    lat, k_rope = (latent[..., :c.kv_lora_rank],
                   latent[..., c.kv_lora_rank:])
    k_nope = jnp.einsum("bsr,rhn->bshn", lat, params["w_uk"])
    v = jnp.einsum("bsr,rhv->bshv", lat, params["w_uv"])

    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (b, s, cfg.num_heads, c.qk_rope_head_dim))
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    kfull = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # seq-unsharded at the attention boundary (see layers.gqa_project_qkv)
    from repro import sharding as shardlib
    qfull = shardlib.act(qfull, ("batch", None, "heads", None))
    kfull = shardlib.act(kfull, ("batch", None, "heads", None))
    v = shardlib.act(v, ("batch", None, "heads", None))
    attn = dispatch.flash_attention(qfull, kfull, v, causal=True)
    out = jnp.einsum("bshv,hvd->bsd", attn, params["w_o"])
    return out, latent


def mla_decode_q(params, cfg: ArchConfig, x1: jax.Array, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Absorbed decode queries.  x1: [B, D] -> (q_latent [B,H,R], q_rope)."""
    c = cfg.mla
    q = _project_q(params, cfg, x1[:, None])                     # [B,1,H,qd]
    q_nope = q[..., :c.qk_nope_head_dim]
    q_rope = layers.apply_rope(q[..., c.qk_nope_head_dim:],
                               positions[:, None], cfg.rope_theta)
    q_latent = jnp.einsum("bshn,rhn->bshr", q_nope, params["w_uk"])
    return q_latent[:, 0], q_rope[:, 0]


def mla_decode_out(params, o_latent: jax.Array) -> jax.Array:
    """o_latent: [B, H, R] -> [B, D]."""
    o = jnp.einsum("bhr,rhv->bhv", o_latent, params["w_uv"])
    return jnp.einsum("bhv,hvd->bd", o, params["w_o"])
