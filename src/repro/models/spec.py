"""Parameter specification trees.

Every model declares its parameters as a pytree of ``ParamSpec`` (shape, dtype,
logical axes, initializer).  From one spec tree we derive:

  * materialized params       (``init_params`` — smoke tests, real training)
  * abstract params           (``abstract_params`` — dry-run, no allocation)
  * NamedShardings            (``specs_to_shardings`` via repro.sharding rules)

Logical axis names (resolved by ``repro.sharding.logical_to_pspec``):
  embed, vocab, heads, kv_heads, q_lora, kv_lora, mlp, experts, layers,
  groups, ssm_inner, ssm_state, conv, codebooks, stack
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    dtype: str = "bfloat16"
    init: str = "normal"        # normal | zeros | ones
    # fan_in override for scaled init; 0 = use shape[-2] (or shape[-1] for 1D)
    fan_in: int = 0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"spec rank mismatch: {self.shape} vs {self.logical_axes}")


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.fan_in
    if not fan_in:
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def is_spec_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    """Materialize a spec tree into a params pytree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec_leaf)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs):
    """ShapeDtypeStruct tree — zero allocation, for .lower()."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=is_spec_leaf)


def param_bytes(specs) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec_leaf):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total


def param_count(specs) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec_leaf):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
