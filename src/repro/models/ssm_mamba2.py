"""Mamba2 block (SSD chunked scan) — zamba2's backbone.

Training/prefill use the chunked SSD decomposition: intra-chunk attention-like
term + inter-chunk state recurrence (a scan over chunk states), so HLO size is
O(1) in sequence length and peak memory is O(chunk).  Decode is the O(1)
recurrent update.

All decay exponents are differences of an inclusive cumsum of negative
``dt*A`` terms with j <= t, so every exp() argument is <= 0 — numerically safe
without log-space gymnastics.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers
from repro.models.spec import ParamSpec


def mamba2_dims(cfg: ArchConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    d_conv = d_in + 2 * s.state_dim
    return d_in, n_heads, s.head_dim, s.state_dim, d_conv


def mamba2_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, dt = cfg.d_model, cfg.param_dtype
    d_in, h, p, n, d_conv = mamba2_dims(cfg)
    k = cfg.ssm.conv_kernel
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * n + h), ("embed", "ssm_inner"),
                             dt),
        "conv_w": ParamSpec((k, d_conv), ("conv", None), dt, fan_in=k),
        "conv_b": ParamSpec((d_conv,), (None,), "float32", init="zeros"),
        "a_log": ParamSpec((h,), (None,), "float32", init="zeros"),
        "d_skip": ParamSpec((h,), (None,), "float32", init="ones"),
        "dt_bias": ParamSpec((h,), (None,), "float32", init="zeros"),
        "gn_scale": ParamSpec((d_in,), ("ssm_inner",), "float32", init="ones"),
        "out_proj": ParamSpec((d_in, d), ("ssm_inner", "embed"), dt),
    }


def _split_zxbcdt(cfg: ArchConfig, zxbcdt: jax.Array):
    d_in, h, p, n, d_conv = mamba2_dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_conv]
    dt = zxbcdt[..., d_in + d_conv:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, conv_w: jax.Array, conv_b: jax.Array,
                 init: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  xbc: [B, T, C]; conv_w: [K, C].
    Returns (out [B, T, C], final K-1 raw inputs for decode handoff)."""
    k = conv_w.shape[0]
    b, t, c = xbc.shape
    if init is None:
        init = jnp.zeros((b, k - 1, c), xbc.dtype)
    padded = jnp.concatenate([init.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + padded[:, i:i + t].astype(jnp.float32) * \
            conv_w[i].astype(jnp.float32)
    out = out + conv_b
    return out.astype(xbc.dtype), padded[:, -(k - 1):] if k > 1 else \
        jnp.zeros((b, 0, c), xbc.dtype)


def mamba2_forward(params, cfg: ArchConfig, x: jax.Array, *,
                   conv_init: Optional[jax.Array] = None,
                   state_init: Optional[jax.Array] = None,
                   return_state: bool = False):
    """x: [B, T, D] -> y [B, T, D] (+ (conv_state, ssd_state) if requested)."""
    s = cfg.ssm
    b, t, d = x.shape
    d_in, h, p, n, d_conv = mamba2_dims(cfg)

    zxbcdt = jnp.einsum("btd,de->bte", x, params["in_proj"])
    z, xbc, dt = _split_zxbcdt(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                   conv_init)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_in]
    bc = xbc[..., d_in:d_in + n].astype(jnp.float32)          # [B,T,N]
    cc = xbc[..., d_in + n:].astype(jnp.float32)              # [B,T,N]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,T,H]
    a = -jnp.exp(params["a_log"])                              # [H] negative
    xh = xs.reshape(b, t, h, p).astype(jnp.float32)
    da = dt * a                                                # [B,T,H] <= 0

    # pad to chunk multiple
    q = min(s.chunk_size, t)
    tp = (t + q - 1) // q * q
    if tp != t:
        pad = ((0, 0), (0, tp - t))
        xh = jnp.pad(xh, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        da = jnp.pad(da, pad + ((0, 0),))
        bc = jnp.pad(bc, pad + ((0, 0),))
        cc = jnp.pad(cc, pad + ((0, 0),))
    nc = tp // q

    def to_chunks(arr):
        return arr.reshape((b, nc, q) + arr.shape[2:]).swapaxes(0, 1)

    xs_c, dt_c, da_c, b_c, c_c = map(to_chunks, (xh, dt, da, bc, cc))
    mask = jnp.tril(jnp.ones((q, q), jnp.float32))

    def chunk_step(state, inp):
        xq, dtq, daq, bq, cq = inp           # [B,Q,H,P] [B,Q,H] [B,Q,N] ...
        cum = jnp.cumsum(daq, axis=1)        # [B,Q,H] inclusive
        # inter-chunk: y_t += C_t . (exp(cum_t) * state_in)
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", cq, jnp.exp(cum), state)
        # intra-chunk
        sc = jnp.einsum("bqn,bjn->bqj", cq, bq)               # [B,Q,Q]
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,Q,Q,H]
        w = sc[..., None] * dec * mask[None, :, :, None]
        y_intra = jnp.einsum("bqjh,bjh,bjhp->bqhp", w, dtq, xq)
        # state update
        dec_last = jnp.exp(cum[:, -1:, :] - cum)              # [B,Q,H]
        state = state * jnp.exp(cum[:, -1])[:, :, None, None]
        state = state + jnp.einsum("bqh,bqh,bqhp,bqn->bhpn",
                                   dec_last, dtq, xq, bq)
        return state, y_inter + y_intra

    state0 = (state_init if state_init is not None
              else jnp.zeros((b, h, p, n), jnp.float32))
    state, y = jax.lax.scan(chunk_step, state0, (xs_c, dt_c, da_c, b_c, c_c))
    y = y.swapaxes(0, 1).reshape(b, tp, h, p)[:, :t]
    y = y + params["d_skip"][None, None, :, None] * xh[:, :t]
    y = y.reshape(b, t, d_in)

    gated = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(gated.astype(x.dtype), params["gn_scale"],
                        cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"])
    if return_state:
        return out, (conv_state, state)
    return out


def mamba2_decode(params, cfg: ArchConfig, x1: jax.Array,
                  conv_state: jax.Array, state: jax.Array):
    """One-token recurrent step.

    x1: [B, D]; conv_state: [B, K-1, Dconv]; state: [B, H, P, N] float32.
    Returns (y [B, D], conv_state', state').
    """
    d_in, h, p, n, d_conv = mamba2_dims(cfg)
    k = cfg.ssm.conv_kernel

    zxbcdt = jnp.einsum("bd,de->be", x1, params["in_proj"])
    z, xbc_t, dt = _split_zxbcdt(cfg, zxbcdt)

    window = jnp.concatenate(
        [conv_state.astype(x1.dtype), xbc_t[:, None]], axis=1)  # [B,K,C]
    xbc = (jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
           + params["conv_b"])
    xbc = jax.nn.silu(xbc)
    conv_state_new = window[:, 1:]

    xs = xbc[..., :d_in]
    bc = xbc[..., d_in:d_in + n]
    cc = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = -jnp.exp(params["a_log"])
    xh = xs.reshape(-1, h, p)

    decay = jnp.exp(dt * a)                                   # [B,H]
    state = state * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bc)
    y = jnp.einsum("bn,bhpn->bhp", cc, state)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(-1, d_in)

    gated = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(gated.astype(x1.dtype), params["gn_scale"],
                        cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])
    return out, conv_state_new, state


def mamba2_recurrent_oracle(params, cfg: ArchConfig, x: jax.Array):
    """Token-by-token decode loop — the oracle chunked forward must match."""
    b, t, d = x.shape
    d_in, h, p, n, d_conv = mamba2_dims(cfg)
    k = cfg.ssm.conv_kernel
    conv = jnp.zeros((b, k - 1, d_conv), x.dtype)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    outs = []
    for i in range(t):
        y, conv, state = mamba2_decode(params, cfg, x[:, i], conv, state)
        outs.append(y)
    return jnp.stack(outs, axis=1), (conv, state)
