"""Mixture-of-experts FFN block (capacity-based top-k, scatter dispatch).

Expert-parallel layout: expert weights are stacked [E, ...] with the expert
dim sharded over the ``model`` mesh axis (EP folded into TP); activations are
replicated across ``model``, so dispatch needs *no* token all_to_all — each
model shard computes the experts it owns and the per-token combine is summed
by the out-projection reduction like a TP MLP.

Dispatch is index-based (scatter into [E, cap, D] buffers), not the one_hot
einsum (whose [T, E, cap] dispatch tensor is quadratically larger).
Overflowing tokens beyond expert capacity are dropped (standard).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import layers
from repro.models.spec import ParamSpec, pad_to_multiple


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    e, f = m.num_experts, m.expert_ffn
    specs = {
        "w_router": ParamSpec((d, e), ("embed", None), "float32"),
        "we_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp"), dt),
        "we_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"), dt),
        "we_down": ParamSpec((e, f, d), ("experts", "mlp", "embed"), dt,
                             fan_in=f),
    }
    if m.num_shared_experts:
        fs = m.shared_expert_ffn * m.num_shared_experts
        specs.update({
            "ws_gate": ParamSpec((d, fs), ("embed", "mlp"), dt),
            "ws_up": ParamSpec((d, fs), ("embed", "mlp"), dt),
            "ws_down": ParamSpec((fs, d), ("mlp", "embed"), dt),
        })
    return specs


def expert_capacity(m: MoEConfig, n_tokens: int) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return pad_to_multiple(max(cap, 4), 4)


def moe_apply(params, cfg: ArchConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [..., D] -> (out [..., D], aux_loss scalar)."""
    m = cfg.moe
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    e, k = m.num_experts, m.top_k
    cap = expert_capacity(m, t)

    # --- routing (float32 router, softmax over experts, renormalized top-k)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    top_p, top_i = jax.lax.top_k(probs, k)                      # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (switch-style)
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32),
                       axis=0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * prob_mean) * m.router_aux_loss

    # --- dispatch: position of each (token, k) in its expert's queue
    flat_e = top_i.reshape(-1)                                  # [T*K]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # [T*K, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # pos before me
    pos = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]    # [T*K]
    keep = (pos < cap).reshape(t, k)
    slot_e = jnp.where(keep, top_i, e)                          # overflow slot
    slot_c = jnp.where(keep, pos.reshape(t, k), 0)

    # scatter tokens per routing slot WITHOUT materializing x repeated K
    # times ([T*K, D] at 32k tokens is GBs); K static scatters instead
    buf = jnp.zeros((e + 1, cap, d), x.dtype)
    for i in range(k):
        buf = buf.at[slot_e[:, i], slot_c[:, i]].add(xf)
    buf = buf[:e]                                               # [E, cap, D]

    # --- expert FFN (swiglu), expert dim sharded over `model`
    g = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_down"])  # [E, cap, D]

    # --- combine: gather each (token, k) result, weight by router prob;
    # again one [T, D] gather per k instead of a [T*K, D] buffer
    y = jnp.zeros((t, d), x.dtype)
    for i in range(k):
        w_i = (keep[:, i].astype(x.dtype)
               * top_p[:, i].astype(x.dtype))[:, None]
        y = y + out_buf[jnp.minimum(slot_e[:, i], e - 1),
                        slot_c[:, i]] * w_i

    # --- shared experts (dense, always-on)
    if m.num_shared_experts:
        gs = jnp.einsum("td,df->tf", xf, params["ws_gate"])
        us = jnp.einsum("td,df->tf", xf, params["ws_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + jnp.einsum("tf,fd->td", hs, params["ws_down"])

    return y.reshape(*lead, d), aux


def moe_apply_dense_oracle(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """No-capacity oracle (every token sees its full top-k): test reference."""
    m = cfg.moe
    lead, d = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    def one_expert(eid):
        g = xf @ params["we_gate"][eid]
        u = xf @ params["we_up"][eid]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return h @ params["we_down"][eid]

    all_out = jax.vmap(one_expert)(jnp.arange(m.num_experts))   # [E, T, D]
    sel = jnp.take_along_axis(
        all_out.transpose(1, 0, 2), top_i[..., None], axis=1)   # [T, K, D]
    y = (sel * top_p[..., None].astype(x.dtype)).sum(axis=1)
    if m.num_shared_experts:
        gs = xf @ params["ws_gate"]
        us = xf @ params["ws_up"]
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + hs @ params["ws_down"]
    return y.reshape(*lead, d)
