"""Gradient compression for the DP all-reduce (distributed-optimization trick).

``compressed_allreduce_mean`` implements an int8 reduce-scatter + all-gather:
each shard owns 1/n of every gradient, peers ship their int8-quantized chunk
(+ one f32 scale) to the owner, the owner reduces in f32, re-quantizes, and
all-gathers the result — wire bytes are ~1/4 of a bf16 ring all-reduce and
~1/8 of f32.  Per-leaf error feedback (Karimireddy et al.) keeps the
compression unbiased over time: the quantization residual is added back into
the next step's gradient.

Used by training/train_step.py when ``grad_compression="int8"`` (a shard_map
stage over the data axes, between accumulation and the optimizer).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

INT8_MAX = 127.0


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, ef: jax.Array) -> Tuple[jax.Array, jax.Array,
                                                      jax.Array]:
    """Error-feedback compression of one tensor.
    Returns (q, scale, new_ef)."""
    target = g.astype(jnp.float32) + ef.astype(jnp.float32)
    q, scale = quantize_int8(target)
    recon = dequantize_int8(q, scale)
    return q, scale, (target - recon).astype(ef.dtype)


def _flat_size(x):
    n = 1
    for d in x.shape:
        n *= d
    return n


def make_compressed_allreduce(mesh: Mesh, axes=("pod", "data")):
    """Returns mean_fn(flat_vec [N] f32) -> [N] f32 averaged over ``axes``
    with int8 wire format (reduce-scatter + all-gather shape)."""
    axes = tuple(ax for ax in axes if ax in mesh.axis_names)
    import numpy as np
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))

    def rs_ag(vec):
        n = vec.shape[0]
        chunk = n // n_shards
        x = vec.reshape(n_shards, chunk)
        q, scale = quantize_int8(x)          # per-row scales? one scale/tensor
        # ship int8 chunks to their owners (reduce-scatter data movement)
        parts_q = q
        parts_s = jnp.broadcast_to(scale, (n_shards,))
        for ax in axes:
            na = jax.lax.psum(1, ax)
            parts_q = parts_q.reshape((na, parts_q.shape[0] // na)
                                      + parts_q.shape[1:])
            parts_q = jax.lax.all_to_all(parts_q, ax, 0, 0, tiled=False)
            parts_q = parts_q.reshape((-1,) + parts_q.shape[2:])
            parts_s = parts_s.reshape(na, -1)
            parts_s = jax.lax.all_to_all(parts_s, ax, 0, 0, tiled=False)
            parts_s = parts_s.reshape(-1)
        # wait: after the exchange each shard holds every peer's copy of *its*
        # chunk: [n_shards, chunk] int8 + [n_shards] scales
        mine = jnp.sum(parts_q.astype(jnp.float32).reshape(n_shards, chunk)
                       * parts_s[:, None], axis=0) / n_shards
        # re-quantize the reduced chunk and all-gather it back
        q2, s2 = quantize_int8(mine)
        out_q, out_s = q2, s2[None]
        for ax in reversed(axes):
            out_q = jax.lax.all_gather(out_q, ax, axis=0, tiled=False)
            out_q = out_q.reshape((-1,) + out_q.shape[2:]) \
                if out_q.ndim > 2 else out_q
            out_s = jax.lax.all_gather(out_s, ax, axis=0, tiled=True)
        out_q = out_q.reshape(n_shards, chunk)
        return (out_q.astype(jnp.float32) * out_s[:, None]).reshape(n)

    ax_spec = axes if len(axes) > 1 else axes[0]
    return shard_map(rs_ag, mesh=mesh, in_specs=P(),
                     out_specs=P(), check_rep=False)


def compress_tree_with_ef(grads, ef_tree):
    """Pointwise error-feedback int8 round-trip on every leaf (models the
    wire quantization when no mesh is available, e.g. unit tests).

    Returns (compressed grads (f32-reconstructed), new ef tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_tree)
    outs = []
    new_ef = []
    for g, e in zip(flat_g, flat_e):
        q, s, e2 = ef_compress(g, e)
        outs.append(dequantize_int8(q, s).astype(g.dtype))
        new_ef.append(e2)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_ef))


def init_ef(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
