"""AdamW + LR schedules, functional, pytree-generic.

Moments can be stored in bfloat16 (``moment_dtype``) — at 340B params the
f32->bf16 moment change alone frees ~2.7 GB/chip on the 256-chip mesh, which
is what lets the largest assigned archs train in 16 GiB HBM (see
training/presets.py).  Update math always runs in float32.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    schedule: str = "cosine"            # cosine | linear | constant


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def abstract_state(params_abstract, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=jax.tree.map(zeros, params_abstract),
                      nu=jax.tree.map(zeros, params_abstract))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - 0.9 * frac
    else:  # cosine to 10%
        frac = jnp.clip((s - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(functools.reduce(jnp.add, leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def one(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_f = mu.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        nu_f = nu.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
        upd = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, mu_f.astype(mdt), nu_f.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [one(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
