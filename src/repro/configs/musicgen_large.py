"""musicgen-large — 48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.
Decoder-only over EnCodec tokens (4 codebooks, delay pattern).
[arXiv:2306.05284; hf]

Per the brief the EnCodec frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (sum of the 4 codebook embeddings, already at
d_model); the backbone predicts 4 codebook heads of 2048 each.
"""

from repro.configs.base import ArchConfig, AudioConfig

ARCH_ID = "musicgen-large"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        mlp_variant="gelu",              # musicgen uses GELU MLPs
        audio=AudioConfig(num_codebooks=4, codebook_size=2048),
        source="arXiv:2306.05284; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        mlp_variant="gelu",
        audio=AudioConfig(num_codebooks=4, codebook_size=64),
        source="smoke",
    )
