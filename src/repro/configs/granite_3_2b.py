"""granite-3-2b — 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
GQA, SwiGLU, tied embeddings.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.base import ArchConfig

ARCH_ID = "granite-3-2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49155,
        tie_embeddings=True,
        rope_theta=10000.0,
        source="hf:ibm-granite/granite-3.0-2b-base; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        tie_embeddings=True,
        source="smoke",
    )
