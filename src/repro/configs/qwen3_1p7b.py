"""qwen3-1.7b — 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
qk_norm, GQA, head_dim=128, tied embeddings.  [hf:Qwen/Qwen3 family; hf]"""

from repro.configs.base import ArchConfig

ARCH_ID = "qwen3-1.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen3-8B; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
        tie_embeddings=True,
        source="smoke",
    )
