"""rwkv6-3b (Finch) — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Data-dependent decay time-mix + channel-mix.  [arXiv:2404.05892; hf]

Attention-free: no KV cache grows with context, so the DPC KV-page technique
is inapplicable to this arch (DESIGN.md §4); decode state is O(1) per layer.
"""

from repro.configs.base import ArchConfig, SSMConfig

ARCH_ID = "rwkv6-3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=0,                     # attention-free
        num_kv_heads=0,
        d_ff=8960,
        vocab_size=65536,
        block_kind="rwkv6",
        ssm=SSMConfig(state_dim=64, head_dim=64, chunk_size=128),
        source="arXiv:2404.05892; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=128,
        vocab_size=256,
        block_kind="rwkv6",
        ssm=SSMConfig(state_dim=16, head_dim=16, chunk_size=32),
        source="smoke",
    )
