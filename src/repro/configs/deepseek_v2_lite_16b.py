"""deepseek-v2-lite-16b — 27L d_model=2048 16H d_ff=1408, vocab=102400.
MLA kv_lora=512, MoE: 2 shared + 64 routed experts, top-6; first layer dense.
[arXiv:2405.04434; hf]

The assignment line reads "MoE 64e top-6 — 2 shared+160 routed top-6"; 160
routed is the full DeepSeek-V2 — the Lite model (this entry) has 64 routed
experts, so we take 64 routed + 2 shared, top-6, matching the HF config.
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,                 # MLA: kv heads == q heads post up-proj
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        rope_theta=10000.0,
        mla=MLAConfig(
            kv_lora_rank=512,
            q_lora_rank=0,               # v2-lite: no q compression
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            expert_ffn=1408,
            num_shared_experts=2,
            shared_expert_ffn=1408,
            first_dense_layers=1,
            dense_ffn=10944,
        ),
        source="arXiv:2405.04434; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, expert_ffn=96,
                      num_shared_experts=1, shared_expert_ffn=96,
                      first_dense_layers=1, dense_ffn=128),
        source="smoke",
    )
