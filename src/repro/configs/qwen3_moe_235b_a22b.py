"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936,
MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]
Qwen3 uses qk_norm and head_dim=128 (decoupled from d_model)."""

from repro.configs.base import ArchConfig, MoEConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,                       # per-expert intermediate
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            expert_ffn=1536,
        ),
        source="hf:Qwen/Qwen3-30B-A3B; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        qk_norm=True,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ffn=96),
        source="smoke",
    )
