"""minitron-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Pruned nemotron: GQA + squared-ReLU.  [arXiv:2407.14679; hf]"""

from repro.configs.base import ArchConfig

ARCH_ID = "minitron-8b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=256000,
        mlp_variant="squared_relu",
        rope_theta=10000.0,
        source="arXiv:2407.14679; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        mlp_variant="squared_relu",
        source="smoke",
    )
