"""nemotron-4-340b — 96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
GQA + squared-ReLU MLP (no gating).  [arXiv:2402.16819; unverified]"""

from repro.configs.base import ArchConfig

ARCH_ID = "nemotron-4-340b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        mlp_variant="squared_relu",
        rope_theta=10000.0,
        source="arXiv:2402.16819; unverified",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=384,
        vocab_size=256,
        mlp_variant="squared_relu",
        source="smoke",
    )
