"""llama-3.2-vision-90b — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; cross-attn image layers every 5 layers.
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]

The modality frontend is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings (already projected to d_model); the backbone's
cross-attention layers consume them as static KV.
"""

from repro.configs.base import ArchConfig, VisionConfig

ARCH_ID = "llama-3.2-vision-90b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        rope_theta=500000.0,
        vision=VisionConfig(num_image_tokens=1601, cross_attn_every=5),
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=4,                    # one cross-attn group (3 self + 1 cross)
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        vision=VisionConfig(num_image_tokens=17, cross_attn_every=4),
        source="smoke",
    )
