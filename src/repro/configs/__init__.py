"""Architecture registry: ``--arch <id>`` resolves through here."""

from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.configs.base import (  # noqa: F401 (re-exported)
    ArchConfig,
    AudioConfig,
    DPCConfig,
    MLAConfig,
    MeshConfig,
    MoEConfig,
    MULTI_POD_MESH,
    RunConfig,
    SINGLE_POD_MESH,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    ShardingConfig,
    SSMConfig,
    VisionConfig,
    resolve_pages_per_seq,
    shape_applicable,
)

_ARCH_MODULES: Dict[str, str] = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "qwen3-1.7b": "repro.configs.qwen3_1p7b",
    "minitron-8b": "repro.configs.minitron_8b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "musicgen-large": "repro.configs.musicgen_large",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def _loader(arch_id: str, fn: str) -> Callable[[], ArchConfig]:
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return getattr(mod, fn)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _loader(arch_id, "config")()


def get_smoke_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _loader(arch_id, "smoke_config")()


def get_shape(shape_name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[shape_name]
