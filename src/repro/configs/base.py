"""Architecture / run configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every benchmark shape is
a ``ShapeConfig``.  ``(ArchConfig, ShapeConfig, MeshConfig, DPCConfig)`` fully
determines a lowered program — the dry-run, roofline, trainers and the serving
engine all consume these and nothing else.

Configs are plain frozen dataclasses (hashable → usable as jit static args and
cache keys).  ``src/repro/configs/<arch>.py`` exposes ``config()`` (the exact
published config) and ``smoke_config()`` (same family, tiny) for CPU tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_ffn: int                     # per-expert intermediate size
    num_shared_experts: int = 0
    shared_expert_ffn: int = 0
    router_dtype: str = "float32"
    # layers [0, first_dense_layers) use a dense FFN instead of MoE
    first_dense_layers: int = 0
    dense_ffn: int = 0                  # ffn width for those dense layers
    capacity_factor: float = 1.25       # train-time expert capacity
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0                # 0 = full-rank q projection (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / RWKV6 state-space parameters."""

    state_dim: int = 64                 # N (per-head state size)
    head_dim: int = 64                  # P (mamba2 head dim) / rwkv head size
    num_heads: int = 0                  # 0 = derive from d_model // head_dim
    conv_kernel: int = 4                # mamba2 short conv
    expand: int = 2                     # mamba2 inner expansion
    chunk_size: int = 128               # chunked-scan block length


@dataclass(frozen=True)
class VisionConfig:
    """Stub modality frontend: precomputed patch/frame embeddings."""

    num_image_tokens: int = 1601        # llama-3.2-vision: (448/14)^2+1 per tile
    cross_attn_every: int = 5           # a cross-attn layer every N layers
    embed_dim: int = 0                  # 0 = d_model (pre-projected stub)


@dataclass(frozen=True)
class AudioConfig:
    """MusicGen-style EnCodec token decoder (frontend stubbed)."""

    num_codebooks: int = 4
    codebook_size: int = 2048
    text_cond_tokens: int = 0           # 0 = unconditional backbone


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.  Field semantics follow the brief's table."""

    name: str
    family: str                         # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int                      # query heads (0 for attn-free)
    num_kv_heads: int                   # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 = d_model // num_heads
    # --- block variants ---
    mlp_variant: str = "swiglu"         # swiglu | squared_relu | gelu
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # --- optional sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionConfig] = None
    audio: Optional[AudioConfig] = None
    # hybrid (zamba2): indices of layers that append the shared attention block
    hybrid_attn_every: int = 0          # 0 = pure; else shared attn after every N ssm blocks
    # which block type the main scan uses
    block_kind: str = "attn"            # attn | mamba2 | rwkv6
    # --- numerics ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # source tag from the assignment table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def kv_dim_per_token(self) -> int:
        """bf16 elements of KV state appended per token per attention layer."""
        if self.attention_free:
            return 0
        if self.mla is not None:
            return self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
        return 2 * self.num_kv_heads * self.resolved_head_dim

    @property
    def num_attn_layers(self) -> int:
        """How many layers actually maintain a growing KV cache."""
        if self.attention_free:
            return 0
        if self.block_kind == "mamba2" and self.hybrid_attn_every:
            return self.num_layers // self.hybrid_attn_every
        if self.vision is not None:
            # cross-attn layers hold static image KV, not growing KV
            n_cross = self.num_layers // self.vision.cross_attn_every
            return self.num_layers - n_cross
        return self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        embed = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio" and self.audio:
            embed = self.audio.num_codebooks * self.audio.codebook_size * d \
                + self.audio.num_codebooks * self.audio.codebook_size * d
        total = embed
        for layer in range(L):
            total += self._layer_params(layer, d, hd)
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        m = self.moe
        for layer in range(L):
            total += self._attn_params(d, hd) + 2 * d
            if layer < m.first_dense_layers:
                total += 3 * d * m.dense_ffn
            else:
                total += m.top_k * 3 * d * m.expert_ffn
                total += m.num_shared_experts * 3 * d * m.shared_expert_ffn
                total += d * m.num_experts  # router
        return total + d

    # -- helpers ------------------------------------------------------------

    def _attn_params(self, d: int, hd: int) -> int:
        if self.attention_free:
            return 0
        if self.mla is not None:
            c = self.mla
            qd = (c.qk_nope_head_dim + c.qk_rope_head_dim) * self.num_heads
            down = d * (c.kv_lora_rank + c.qk_rope_head_dim)
            up = c.kv_lora_rank * self.num_heads * (c.qk_nope_head_dim + c.v_head_dim)
            q = d * qd if not c.q_lora_rank else d * c.q_lora_rank + c.q_lora_rank * qd
            o = self.num_heads * c.v_head_dim * d
            return q + down + up + o
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _mlp_params(self, d: int, ffn: int) -> int:
        mats = 3 if self.mlp_variant == "swiglu" else 2
        return mats * d * ffn

    def _layer_params(self, layer: int, d: int, hd: int) -> int:
        total = 2 * d  # norms
        if self.block_kind == "attn":
            total += self._attn_params(d, hd)
            if self.moe is not None:
                m = self.moe
                if layer < m.first_dense_layers:
                    total += self._mlp_params(d, m.dense_ffn)
                else:
                    total += m.num_experts * 3 * d * m.expert_ffn
                    total += m.num_shared_experts * 3 * d * m.shared_expert_ffn
                    total += d * m.num_experts
            else:
                total += self._mlp_params(d, self.d_ff)
            if self.vision is not None and (layer + 1) % self.vision.cross_attn_every == 0:
                total += self._attn_params(d, hd)  # extra cross-attn block
        elif self.block_kind == "mamba2":
            s = self.ssm
            d_in = s.expand * d
            total += 2 * d * d_in + d_in * d  # in_proj(x,z), out_proj
            total += d_in * s.conv_kernel + 3 * d_in  # conv + dt/A/D params (approx)
            total += self._mlp_params(d, self.d_ff)
            if self.hybrid_attn_every and (layer + 1) % self.hybrid_attn_every == 0:
                pass  # shared block params counted once below by caller family
        elif self.block_kind == "rwkv6":
            # time-mix: r,k,v,g,o + decay lora; channel-mix: k,v,r
            total += 5 * d * d + 2 * d * 64
            total += 2 * d * self.d_ff + d * d
        return total


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic context handling (SSM/hybrid only)."""
    if shape.name == "long_500k" and arch.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{arch.name} is pure full-attention — skipped per brief, see DESIGN.md §4"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / parallelism config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def data_shards(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("data", "pod"):
                n *= s
        return n

    @property
    def model_shards(self) -> int:
        for ax, s in zip(self.axes, self.shape):
            if ax == "model":
                return s
        return 1


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis → mesh-axis rules (MaxText-style)."""

    # weights
    fsdp: bool = True                   # shard weights over data axis too
    # activations
    shard_batch: Tuple[str, ...] = ("pod", "data")
    shard_heads: str = "model"
    shard_ffn: str = "model"
    shard_vocab: str = "model"
    shard_experts: str = "model"        # EP folded into model axis
    # sequence parallelism for very long prefill
    sequence_parallel: bool = False
    # remat policy: none | minimal | full
    remat: str = "full"


# ---------------------------------------------------------------------------
# DPC config — the paper's technique as a first-class feature
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DPCConfig:
    """Distributed page cache over the KV pool.

    ``mode``:
      dpc          relaxed coherence (paper's DPC) — default
      dpc_sc       strong coherence (two-step write: LOOKUP_LOCK/UNLOCK)
      replicated   per-replica caching, no sharing (NFS/per-node baseline)
      local_only   no cross-replica cache at all (Virtiofs baseline: every
                   remote-miss refetches from "storage" = prefill recompute)

    ``datapath``:
      ship_data     paper-faithful CXL analog — fetch owner pages over ICI
      ship_compute  beyond-paper — send q to owners, combine partials by LSE
    """

    mode: str = "dpc"
    datapath: str = "ship_compute"
    page_size: int = 64                 # tokens per KV page
    pool_pages_per_shard: int = 4096    # physical pages per data shard
    directory_capacity: int = 1 << 16   # hash slots (power of two)
    inv_batch_threshold: int = 32       # paper §4.3 batch size
    max_pages_per_seq: int = 0          # 0 = derive from shape
    kv_dtype: str = "bfloat16"          # int8 enables quantized pool
    # directory placement: sharded (hash-partitioned) | central (shard 0)
    directory_placement: str = "sharded"
    # --- per-node mapping cache (software TLB, core/tlb.py) ---
    # established grants are cached node-side so steady-state re-reads pay
    # zero directory ops and zero device round trips; teardowns shoot the
    # cached entries down before they complete (protocol.py)
    tlb_enabled: bool = True
    tlb_slots: int = 1024               # per-node entries (power of two)
    tlb_max_probe: int = 8              # open-addressing probe bound
    # write grants: MODE_M entries let mark_dirty/write_prepare complete with
    # zero directory ops; dirty bits buffer per node and flush in one batched
    # op per engine step (and always before a teardown can observe the page)
    tlb_write_grants: bool = True
    # deliver TLB shootdowns as piggybacked descriptor lanes on the next
    # opcode batch routed to the sharer (False = legacy synchronous draining;
    # kept for the piggyback==sync equivalence property tests)
    tlb_shootdown_piggyback: bool = True
    # async data plane: migration KV copies and writeback flushes ride
    # COPY/FLUSH descriptor lanes on routed opcode batches, the engine
    # double-buffers page allocation (step N overlaps the fetches for
    # step N+1 behind a generation check), drains evacuate in overlapped
    # MIGRATE rounds, and _routed pipelines its per-shard device transfers.
    # False = legacy synchronous stepping, kept as the reference mode for
    # the async==sync equivalence property tests (tests/test_async_data_plane)
    async_data_plane: bool = True
    # --- cluster prefix tree + predictive prefetch (serving/prefix_tree) ---
    # tree nodes are keyed exactly like file pages (chain hash, page idx) and
    # partitioned by the same dir_shard_of placement, so any node's prefill
    # is visible to any other node's match; a match promotes the matched
    # tail pages (sharer-bit + TLB install, no alloc on miss) during the
    # decode overlap window and credits the migration ledger
    prefix_tree_enabled: bool = True
    prefix_tree_capacity: int = 4096    # max tree nodes before cold pruning
    prefix_predict_weight: int = 2      # ledger credit per predicted access
    # False = per-node prefix index ablation: page keys are salted with the
    # node id, so no request ever resolves to another node's prefill (the
    # pre-cluster-tree behavior, kept as the app_serving ablation row)
    prefix_cluster: bool = True
    # --- ownership migration (core/migration.py; 0 threshold disables) ---
    migrate_threshold: int = 4          # decayed remote accesses that promote
    migrate_batch: int = 32             # max MIGRATEs per round
    migrate_interval_steps: int = 8     # engine steps between rounds
    migrate_decay_every: int = 4        # rounds between hotness halvings
    migrate_cooldown: int = 2           # rounds a migrated page is immune
    # --- durable backing store + async writeback (repro/storage) ---
    storage_backend: str = "none"       # none | memory | file
    storage_dir: str = ""               # file-backend root ("" = temp dir)
    storage_extent_pages: int = 8       # pages per npy extent file
    writeback_batch: int = 32           # flush obligations per store sync
    writeback_interval_s: float = 0.002  # async flusher wake period
    writeback_async: bool = True        # background thread; False = pumped
    # run the refimpl directory in lockstep and assert dirty-bit agreement
    # on every completed invalidation/migration (tests/debug)
    shadow_oracle: bool = False
    # --- observability (repro/obs) ---
    # off      plain-dict stats, seed-identical data-path cost
    # counters always-on metrics registry (typed counters + gauges + log2
    #          histograms keyed (node, subsystem, name); gated <1.1x vs off
    #          by the bench.obs_overhead row)
    # full     counters plus the ring-buffered protocol event tracer
    #          (Chrome trace_event export, repro.obs.audit replay checks)
    obs_level: str = "counters"
    obs_trace_events: int = 32768       # tracer ring capacity (power of two)

    @property
    def enabled(self) -> bool:
        return self.mode in ("dpc", "dpc_sc")

    @property
    def storage_enabled(self) -> bool:
        return self.storage_backend not in ("", "none")

    @property
    def migration_enabled(self) -> bool:
        return self.enabled and self.migrate_threshold > 0 \
            and self.migrate_interval_steps > 0


# ---------------------------------------------------------------------------
# Top-level run config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    arch: ArchConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD_MESH
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    dpc: DPCConfig = field(default_factory=DPCConfig)
    # optimizer
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    grad_clip: float = 1.0
    # fault tolerance
    checkpoint_every: int = 200
    heartbeat_interval_s: float = 5.0
    straggler_timeout_s: float = 30.0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def resolve_pages_per_seq(cfg: RunConfig) -> int:
    if cfg.dpc.max_pages_per_seq:
        return cfg.dpc.max_pages_per_seq
    return max(1, (cfg.shape.seq_len + cfg.dpc.page_size - 1) // cfg.dpc.page_size)
