"""zamba2-1.2b — 38L d_model=2048, Mamba2 backbone + shared attention block
(32H kv=32 = MHA) d_ff=8192 vocab=32000, ssm_state=64.  [arXiv:2411.15242; hf]

Zamba2 interleaves Mamba2 blocks with a single *shared* transformer block
(attention + MLP, parameters reused at every application).  We apply the
shared block after every ``hybrid_attn_every`` Mamba2 blocks.
"""

from repro.configs.base import ArchConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,                 # shared block is MHA
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        block_kind="mamba2",
        hybrid_attn_every=6,             # shared attn block every 6 mamba blocks
        ssm=SSMConfig(state_dim=64, head_dim=64, conv_kernel=4, expand=2,
                      chunk_size=128),
        source="arXiv:2411.15242; hf",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_kind="mamba2",
        hybrid_attn_every=2,
        ssm=SSMConfig(state_dim=16, head_dim=16, conv_kernel=4, expand=2,
                      chunk_size=32),
        source="smoke",
    )
