"""Token data pipeline with a host-tier DPC shard cache.

Synthetic-but-deterministic corpus (seeded per shard), sharded across data
ranks.  The *host tier* reuses the DPC protocol at file granularity: dataset
shards are pages, the refimpl directory coordinates which rank holds the
single cached copy, and ranks that miss "fetch" from a peer (memcpy) instead
of regenerating from "storage" (the synthetic generator stands in for the
object store; its cost is made explicit so cache hits are observable).

The iterator is checkpointable: ``state_dict()/load_state_dict`` capture the
exact cursor, so restore resumes mid-epoch without sample loss or repeats.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import refimpl
from repro.storage import MemoryBackingStore


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_shards: int = 16          # dataset shards ("files")
    shard_tokens: int = 1 << 16   # tokens per shard
    seed: int = 0
    storage_latency_s: float = 0.0   # simulated object-store latency


class ShardStore(MemoryBackingStore):
    """The "backing storage" of the host tier, as a ``BackingStore``.

    Shards are pages of stream 0: ``read`` serves any shard ever written
    (the durable/staged tiers of ``MemoryBackingStore``) and falls back to
    deterministic synthesis — the seeded generator stands in for an
    infinite, read-only object store, with its cost made explicit so cache
    hits are observable.  The host tier (``HostShardCache``) and the page
    tier (``core/protocol.py``) now speak the same storage interface.
    """

    STREAM = 0  # all shards live on one storage stream ("the corpus file")

    def __init__(self, cfg: DataConfig):
        super().__init__()
        self.cfg = cfg
        self.fetches = 0

    def read(self, stream: int, page: int) -> np.ndarray:
        data = super().read(stream, page)   # counts the hit, or the miss
        if data is not None:
            return data
        if self.cfg.storage_latency_s:
            time.sleep(self.cfg.storage_latency_s)
        # stream folds into the seed (stream 0 — the host tier's only
        # stream — keeps the corpus identical to the pre-refactor bytes)
        rng = np.random.RandomState(self.cfg.seed * 9973
                                    + stream * 31337 + page)
        return rng.randint(0, self.cfg.vocab_size,
                           size=self.cfg.shard_tokens).astype(np.int32)

    def contains(self, stream: int, page: int) -> bool:
        # honest caveat for generic BackingStore callers: this store can
        # synthesize *every* key, so "contains" means "readable", not
        # "previously written" — missing-page conditions do not exist here
        return True

    def fetch(self, shard_id: int) -> np.ndarray:
        """Host-tier convenience: fetch one shard ("file") from storage."""
        self.fetches += 1
        return self.read(self.STREAM, shard_id)


class HostShardCache:
    """DPC at shard granularity across data ranks (refimpl directory)."""

    def __init__(self, cfg: DataConfig, num_ranks: int,
                 capacity_per_rank: int = 4):
        self.store = ShardStore(cfg)
        self.dir = refimpl.RefDirectory(capacity=cfg.num_shards * 2,
                                        num_nodes=num_ranks)
        self.capacity = capacity_per_rank
        self.resident: Dict[int, Dict[int, np.ndarray]] = {
            r: {} for r in range(num_ranks)}
        self.hits_local = 0
        self.hits_remote = 0
        self.misses = 0

    def get(self, shard_id: int, rank: int) -> np.ndarray:
        st, owner, _ = self.dir.lookup_and_install(0, shard_id, rank)
        from repro.core import descriptors as D
        if st == D.ST_HIT_OWNER:
            self.hits_local += 1
            return self.resident[rank][shard_id]
        if st in (D.ST_MAP_S, D.ST_HIT_SHARER):
            self.hits_remote += 1
            return self.resident[owner][shard_id]  # remote read (memcpy)
        if st == D.ST_GRANT_E:
            self.misses += 1
            self._evict_if_needed(rank)
            data = self.store.fetch(shard_id)
            self.resident[rank][shard_id] = data
            self.dir.commit(0, shard_id, rank, shard_id)
            return data
        # BLOCKED/FULL: bypass the cache (direct fetch, no install)
        self.misses += 1
        return self.store.fetch(shard_id)

    def _evict_if_needed(self, rank: int) -> None:
        while len(self.resident[rank]) >= self.capacity:
            victim = next(iter(self.resident[rank]))
            st, sharers = self.dir.begin_invalidate(0, victim, rank)
            if st == refimpl.D.ST_OK:
                for s in sharers:
                    self.dir.ack_invalidate(0, victim, s, False)
                self.dir.complete_invalidate(0, victim, rank)
            del self.resident[rank][victim]


class TokenPipeline:
    """Per-rank batched LM token iterator over the cached shards."""

    def __init__(self, cfg: DataConfig, rank: int, num_ranks: int,
                 cache: Optional[HostShardCache] = None):
        self.cfg = cfg
        self.rank = rank
        self.num_ranks = num_ranks
        self.cache = cache or HostShardCache(cfg, num_ranks)
        self.cursor = 0               # global sample index for this rank
        self.batch_per_rank = cfg.global_batch // num_ranks

    def _sample(self, idx: int) -> Tuple[np.ndarray, np.ndarray]:
        tokens_per_sample = self.cfg.seq_len + 1
        samples_per_shard = self.cfg.shard_tokens // tokens_per_sample
        shard_id = (idx // samples_per_shard) % self.cfg.num_shards
        offset = (idx % samples_per_shard) * tokens_per_sample
        shard = self.cache.get(shard_id, self.rank)
        chunk = shard[offset:offset + tokens_per_sample]
        return chunk[:-1], chunk[1:]

    def next_batch(self) -> Dict[str, np.ndarray]:
        toks, labs = [], []
        for i in range(self.batch_per_rank):
            # rank-strided global order so ranks see disjoint streams
            idx = self.cursor * self.num_ranks + self.rank \
                + i * 7919 * self.num_ranks
            t, l = self._sample(idx)
            toks.append(t)
            labs.append(l)
        self.cursor += 1
        return {"tokens": np.stack(toks), "labels": np.stack(labs)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- checkpointable cursor --------------------------------------------

    def state_dict(self) -> Dict:
        return {"cursor": self.cursor, "rank": self.rank,
                "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict) -> None:
        assert state["rank"] == self.rank and state["seed"] == self.cfg.seed
        self.cursor = state["cursor"]


def for_arch(arch: ArchConfig, seq_len: int, global_batch: int,
             **kw) -> DataConfig:
    vocab = (arch.audio.codebook_size if arch.family == "audio" and arch.audio
             else arch.vocab_size)
    return DataConfig(vocab_size=vocab, seq_len=seq_len,
                      global_batch=global_batch, **kw)
