"""Async checkpoint/restore for TrainState + data cursor.

Checkpoint layout (one dir per step):
    ckpt_dir/step_000100/
        manifest.json        step, leaf paths, shapes/dtypes, extra state
        leaf_00000.npy ...   one file per pytree leaf

Writes happen on a background thread (training never blocks on I/O); a
``.complete`` marker commits the checkpoint atomically so a crash mid-write
is never restored from.  ``restore_latest`` finds the newest complete step —
the restart path node failures funnel into (runtime/liveness.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.saves = 0

    # ------------------------------------------------------------------

    def save(self, step: int, state, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write to disk async."""
        flat, _ = _flatten_with_paths(state)
        host_leaves = [np.asarray(x) for x in flat]   # device -> host now
        # numpy .npy cannot round-trip bf16 (ml_dtypes) — store a uint16 view
        # and record the logical dtype in the manifest
        dtypes = [str(a.dtype) for a in host_leaves]
        host_leaves = [a.view(np.uint16) if a.dtype.str == "<V2" or
                       "bfloat16" in str(a.dtype) else a
                       for a in host_leaves]
        extra = dict(extra or {})

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "extra": extra,
                        "leaves": [{"shape": list(a.shape), "dtype": dt}
                                   for a, dt in zip(host_leaves, dtypes)]}
            for i, a in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            open(os.path.join(path, ".complete"), "w").close()
            self._gc()
            self.saves += 1

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------

    def _complete_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, ".complete")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, step: int, state_like) -> Tuple[Any, Dict]:
        """Restore into the structure (and shardings) of ``state_like``."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, treedef = _flatten_with_paths(state_like)
        assert manifest["n_leaves"] == len(flat), "state structure changed"
        leaves = []
        for i, like in enumerate(flat):
            a = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            want = manifest["leaves"][i]["dtype"]
            if "bfloat16" in want and a.dtype == np.uint16:
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            if hasattr(like, "sharding"):
                leaves.append(jax.device_put(a, like.sharding))
            else:
                leaves.append(jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]

    def restore_latest(self, state_like) -> Optional[Tuple[Any, Dict, int]]:
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, state_like)
        return state, extra, step
