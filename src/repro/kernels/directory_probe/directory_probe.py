"""Pallas TPU batched directory probe.

The whole key table lives in VMEM (a 2^16-slot directory is 512 KiB — well
inside the ~16 MiB v5e VMEM budget, exactly the "tag store" framing of the
paper), and each grid step resolves a block of queries with an in-register
linear probe.  The hash matches ``descriptors.hash_key`` bit-for-bit so the
kernel, the jnp oracle, and the Python refimpl agree on slot placement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.directory import EMPTY, TOMB


def _hash(stream, page):
    h = stream.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = h ^ (page.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 13)
    return h


def _probe_kernel(keys_ref, q_ref, o_ref, *, max_probe: int, block_n: int):
    cap = keys_ref.shape[0]

    def probe_one(i, _):
        stream = q_ref[i, 0]
        page = q_ref[i, 1]
        h0 = (_hash(stream, page) & jnp.uint32(cap - 1)).astype(jnp.int32)

        def cond(c):
            _, steps, _, _, done = c
            return jnp.logical_and(~done, steps < max_probe)

        def body(c):
            slot, steps, found, insert, _ = c
            row = keys_ref[pl.dslice(slot, 1), :]
            s = row[0, 0]
            match = jnp.logical_and(s == stream, row[0, 1] == page)
            is_empty = s == EMPTY
            is_tomb = s == TOMB
            found = jnp.where(match, slot, found)
            insert = jnp.where(
                jnp.logical_and(insert < 0, is_empty | is_tomb), slot, insert)
            done = match | is_empty
            return ((slot + 1) & (cap - 1), steps + 1, found, insert, done)

        init = (h0, jnp.int32(0), jnp.int32(-1), jnp.int32(-1),
                jnp.bool_(False))
        _, _, found, insert, _ = jax.lax.while_loop(cond, body, init)
        o_ref[i, 0] = found
        o_ref[i, 1] = insert
        return 0

    jax.lax.fori_loop(0, block_n, probe_one, 0)


@functools.partial(jax.jit, static_argnames=("max_probe", "block_n",
                                             "interpret"))
def probe_batch(keys, queries, *, max_probe: int = 128, block_n: int = 128,
                interpret: bool = False):
    """keys: [C, 2] int32 (C power of two); queries: [N, 2] int32.
    Returns [N, 2] int32 (found_slot, insert_slot)."""
    n = queries.shape[0]
    block_n = min(block_n, n)
    n_pad = pl.cdiv(n, block_n) * block_n
    if n_pad != n:
        queries = jnp.pad(queries, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_probe_kernel, max_probe=max_probe,
                          block_n=block_n),
        out_shape=jax.ShapeDtypeStruct((n_pad, 2), jnp.int32),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec(keys.shape, lambda i: (0, 0)),     # whole table
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
        interpret=interpret,
    )(keys, queries)
    return out[:n]
