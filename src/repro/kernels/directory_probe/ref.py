"""Pure-jnp oracle for the batched directory hash probe (read-only path).

Given the directory key table and a batch of (stream, page) queries, return
per query the matching slot (or -1) and the first insertable slot seen
(EMPTY or TOMB, or -1).  This is the hot lookup half of
``directory.lookup_and_install`` — the mutation half stays in the serialized
fori_loop, but a read-mostly workload (CH-R rehits) resolves through probes
alone.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import descriptors as D
from repro.core.directory import EMPTY, TOMB, probe


@functools.partial(jax.jit, static_argnames=("max_probe",))
def probe_batch(keys: jax.Array, queries: jax.Array, *, max_probe: int = 128):
    """keys: [C, 2] int32; queries: [N, 2] -> [N, 2] (found, insert)."""

    def one(q):
        found, insert = probe(keys, q[0], q[1], max_probe)
        return jnp.stack([found, insert])

    return jax.vmap(one)(queries)
