"""jit'd wrapper for the batched directory probe."""

from __future__ import annotations

import functools

import jax

from repro.kernels.directory_probe import directory_probe as _dp
from repro.kernels.directory_probe import ref as _ref


@functools.partial(jax.jit, static_argnames=("max_probe", "interpret"))
def probe_batch(keys, queries, *, max_probe: int = 128,
                interpret: bool = False):
    return _dp.probe_batch(keys, queries, max_probe=max_probe,
                           interpret=interpret)


probe_batch_ref = _ref.probe_batch
