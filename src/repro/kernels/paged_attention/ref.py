"""Pure-jnp oracle for paged decode attention over the DPC page pool.

The KV pool is the *physical* side of the distributed page cache: pages are
owned by exactly one pool slot cluster-wide (single-copy invariant); the page
table maps each request's logical pages to physical slots.  Invalid entries
(page id < 0) are masked — they correspond to pages still in E/TBI state or
beyond seq_len.

q:          [B, Hq, D]            one new token per request
k_pool:     [P, page, Hkv, D]     physical key pages (this shard's slice)
v_pool:     [P, page, Hkv, D]
page_table: [B, N] int32          physical slot per logical page (-1 invalid)
seq_lens:   [B] int32             tokens currently valid per request
Returns     [B, Hq, D]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@functools.partial(jax.jit, static_argnames=("pages_per_step",))
def paged_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                    pages_per_step: int = 8):
    """GQA-grouped online softmax over pool pages.

    kv heads are NEVER replicated/materialized (the Pallas kernel broadcasts
    them in registers; here the grouped einsum keeps pool tiles in their
    storage dtype and produces f32 scores directly via
    preferred_element_type) — this keeps both HBM traffic and peak memory at
    1x the pool bytes instead of n_rep x in f32.
    """
    b, hq, d = q.shape
    p_phys, page, hkv, _ = k_pool.shape
    n_pages = page_table.shape[1]
    n_rep = hq // hkv
    scale = 1.0 / np.sqrt(d)

    g = min(pages_per_step, n_pages)
    npad = (n_pages + g - 1) // g * g
    pt = jnp.pad(page_table, ((0, 0), (0, npad - n_pages)), constant_values=-1)
    pt = pt.reshape(b, npad // g, g)

    qg = q.reshape(b, hkv, n_rep, d).astype(jnp.float32)

    def step(carry, ids_and_base):
        o, m, l = carry                                # o: [B,Hkv,R,D]
        ids, base = ids_and_base                       # ids: [B, G]
        safe = jnp.maximum(ids, 0)
        kt = k_pool[safe]                              # [B, G, page, Hkv, D]
        vt = v_pool[safe]
        sc = jnp.einsum("bhrd,bgphd->bhrgp", qg, kt,
                        preferred_element_type=jnp.float32) * scale
        # token position of (g, p) = (base + g_local) * page + p
        pos = (base + jnp.arange(g))[None, :, None] * page + \
            jnp.arange(page)[None, None, :]
        ok = (ids[:, :, None] >= 0) & (pos < seq_lens[:, None, None])
        sc = jnp.where(ok[:, None, None], sc, NEG_INF)  # [B,Hkv,R,G,page]

        sc_flat = sc.reshape(b, hkv, n_rep, g * page)
        m_new = jnp.maximum(m, sc_flat.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(sc - m_new[..., None, None])
        l_new = l * alpha + p_.reshape(b, hkv, n_rep, -1).sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhrgp,bgphd->bhrd", p_, vt,
            preferred_element_type=jnp.float32)
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((b, hkv, n_rep, d), jnp.float32)
    m0 = jnp.full((b, hkv, n_rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, n_rep), jnp.float32)
    bases = jnp.arange(npad // g) * g
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), (pt.swapaxes(0, 1), bases))
    out = o / jnp.maximum(l[..., None], 1e-20)
    return (out.reshape(b, hq, d).astype(q.dtype),
            (m.reshape(b, hq), l.reshape(b, hq)))


def paged_attention_nocache(q, k_pool, v_pool, page_table, seq_lens, **kw):
    out, _ = paged_attention(q, k_pool, v_pool, page_table, seq_lens, **kw)
    return out


@functools.partial(jax.jit, static_argnames=("pages_per_step", "sm_scale"))
def mla_paged_attention(q_latent, q_rope, latent_pool, page_table, seq_lens, *,
                        pages_per_step: int = 8, sm_scale=None):
    """Absorbed MLA decode attention over a latent page pool.

    q_latent:    [B, H, R]        q projected into the kv-lora space (absorbed W_uk)
    q_rope:      [B, H, Dr]       decoupled rope part
    latent_pool: [P, page, R+Dr]  compressed latent + shared rope key
    Returns      [B, H, R]        attention output still in latent space
    """
    b, h, r = q_latent.shape
    dr = q_rope.shape[-1]
    p_phys, page, rd = latent_pool.shape
    assert rd == r + dr
    n_pages = page_table.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(r + dr)

    g = min(pages_per_step, n_pages)
    npad = (n_pages + g - 1) // g * g
    pt = jnp.pad(page_table, ((0, 0), (0, npad - n_pages)), constant_values=-1)
    pt = pt.reshape(b, npad // g, g)

    qlf = q_latent.astype(jnp.float32)
    qrf = q_rope.astype(jnp.float32)

    def step(carry, ids_and_base):
        o, m, l = carry
        ids, base = ids_and_base
        safe = jnp.maximum(ids, 0)
        lat = latent_pool[safe].astype(jnp.float32)      # [B, G, page, R+Dr]
        kl, kr = lat[..., :r], lat[..., r:]
        sc = (jnp.einsum("bhr,bgpr->bhgp", qlf, kl)
              + jnp.einsum("bhr,bgpr->bhgp", qrf, kr)) * scale
        pos = (base + jnp.arange(g))[None, :, None] * page + jnp.arange(page)[None, None, :]
        ok = (ids[:, :, None] >= 0) & (pos < seq_lens[:, None, None])
        sc = jnp.where(ok[:, None], sc, NEG_INF)

        sc_flat = sc.reshape(b, h, g * page)
        m_new = jnp.maximum(m, sc_flat.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(sc - m_new[..., None, None])
        l_new = l * alpha + p_.reshape(b, h, -1).sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum("bhgp,bgpr->bhr", p_, kl)
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((b, h, r), jnp.float32)
    m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    bases = jnp.arange(npad // g) * g
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), (pt.swapaxes(0, 1), bases))
    out = o / jnp.maximum(l[..., None], 1e-20)
    return out.astype(q_latent.dtype), (m, l)


def combine_partials(outs, ms, ls):
    """LSE-combine per-shard partial attention results (ship_compute datapath).

    outs: [S, B, H, D] unnormalized o×l? — here: outs are *normalized* per-shard
    outputs with their (m, l) stats; we recombine exactly:
        o_full = sum_s o_s * l_s * exp(m_s - m*) / l*
    """
    m_star = jnp.max(ms, axis=0)
    w = ls * jnp.exp(ms - m_star[None])
    l_star = jnp.sum(w, axis=0)
    o = jnp.sum(outs * w[..., None], axis=0) / jnp.maximum(l_star[..., None], 1e-20)
    return o, (m_star, l_star)
