"""Pallas TPU paged decode attention over the DPC page pool.

The page table is a *scalar-prefetch* operand: BlockSpec index maps read it
to steer the pool-page DMA for each grid step — the hardware-level analog of
"insert the remote frame into the page table and let loads hit it".  Invalid
entries (< 0: pages in E/TBI, or beyond seq_len) clamp the DMA to slot 0 and
are masked out of the softmax, so in-teardown pages are I/O-blocked exactly
like the paper's reclaim path.

Grid (batch, kv_head, page): one pool page per step per kv head; online
softmax state in VMEM scratch; output emitted on the final page.  Returns the
(m, l) stats needed by the ship_compute LSE combine.

The MLA variant attends over compressed latent pages [P, page, R+Dr] with
absorbed queries — the page is both K and V (out stays in latent space).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA paged attention
# ---------------------------------------------------------------------------


def _paged_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref, m_o, l_o,
                  m_s, l_s, acc_s, *, page: int, scale: float):
    b = pl.program_id(0)
    n = pl.program_id(2)
    nn = pl.num_programs(2)

    @pl.when(n == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    valid = pt_ref[b, n] >= 0

    @pl.when(valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [n_rep, D]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)            # [page, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = n * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < sl_ref[b], s, NEG_INF)        # [n_rep, page]

        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(n == nn - 1)
    def _emit():
        l = jnp.maximum(l_s[...], 1e-20)
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)
        m_o[0, 0] = m_s[...][:, 0]
        l_o[0, 0] = l_s[...][:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                    interpret: bool = False):
    """q: [B, Hq, D]; pools: [P, page, Hkv, D]; page_table: [B, N] int32;
    seq_lens: [B].  Returns ([B, Hq, D], (m [B, Hq], l [B, Hq]))."""
    b, hq, d = q.shape
    p_phys, page, hkv, _ = k_pool.shape
    n_pages = page_table.shape[1]
    n_rep = hq // hkv
    scale = d ** -0.5

    qr = q.reshape(b, hkv, n_rep, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, n_rep, d),
                         lambda b_, h, n, pt, sl: (b_, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, n, pt, sl:
                         (jnp.maximum(pt[b_, n], 0), 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, n, pt, sl:
                         (jnp.maximum(pt[b_, n], 0), 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n_rep, d),
                         lambda b_, h, n, pt, sl: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, n_rep), lambda b_, h, n, pt, sl: (b_, h, 0)),
            pl.BlockSpec((1, 1, n_rep), lambda b_, h, n, pt, sl: (b_, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, 1), jnp.float32),
            pltpu.VMEM((n_rep, d), jnp.float32),
        ],
    )

    out, m, l = pl.pallas_call(
        functools.partial(_paged_kernel, page=page, scale=scale),
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, n_rep, d), q.dtype),
            jax.ShapeDtypeStruct((b, hkv, n_rep), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, n_rep), jnp.float32),
        ],
        grid_spec=grid_spec,
        interpret=interpret,
    )(page_table, seq_lens, qr, k_pool, v_pool)
    return (out.reshape(b, hq, d),
            (m.reshape(b, hq), l.reshape(b, hq)))


# ---------------------------------------------------------------------------
# MLA paged attention (absorbed latent space)
# ---------------------------------------------------------------------------


def _mla_kernel(pt_ref, sl_ref, q_ref, lat_ref, o_ref, m_o, l_o,
                m_s, l_s, acc_s, *, page: int, r: int, scale: float):
    b = pl.program_id(0)
    n = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(n == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    valid = pt_ref[b, n] >= 0

    @pl.when(valid)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # [H, R+Dr]
        lat = lat_ref[0].astype(jnp.float32)              # [page, R+Dr]
        s = jax.lax.dot_general(q, lat, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = n * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < sl_ref[b], s, NEG_INF)        # [H, page]

        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, lat[:, :r], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(n == nn - 1)
    def _emit():
        l = jnp.maximum(l_s[...], 1e-20)
        o_ref[0] = (acc_s[...] / l).astype(o_ref.dtype)
        m_o[0] = m_s[...][:, 0]
        l_o[0] = l_s[...][:, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "sm_scale"))
def mla_paged_attention(q_latent, q_rope, latent_pool, page_table, seq_lens,
                        *, interpret: bool = False, sm_scale=None):
    """q_latent: [B, H, R]; q_rope: [B, H, Dr]; latent_pool: [P, page, R+Dr].
    Returns ([B, H, R] latent-space out, (m, l))."""
    b, h, r = q_latent.shape
    dr = q_rope.shape[-1]
    p_phys, page, rd = latent_pool.shape
    assert rd == r + dr
    n_pages = page_table.shape[1]
    scale = sm_scale if sm_scale is not None else (r + dr) ** -0.5

    q_cat = jnp.concatenate([q_latent, q_rope], axis=-1)  # [B, H, R+Dr]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, rd), lambda b_, n, pt, sl: (b_, 0, 0)),
            pl.BlockSpec((1, page, rd),
                         lambda b_, n, pt, sl: (jnp.maximum(pt[b_, n], 0), 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, r), lambda b_, n, pt, sl: (b_, 0, 0)),
            pl.BlockSpec((1, h), lambda b_, n, pt, sl: (b_, 0)),
            pl.BlockSpec((1, h), lambda b_, n, pt, sl: (b_, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, r), jnp.float32),
        ],
    )

    out, m, l = pl.pallas_call(
        functools.partial(_mla_kernel, page=page, r=r, scale=scale),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, r), q_latent.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        grid_spec=grid_spec,
        interpret=interpret,
    )(page_table, seq_lens, q_cat, latent_pool)
    return out, (m, l)
