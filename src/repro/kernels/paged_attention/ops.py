"""jit'd public wrappers for the Pallas paged attention kernels."""

from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import (  # noqa: F401
    mla_paged_attention as _mla_pallas,
    paged_attention as _paged_pallas,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                    interpret: bool = False):
    return _paged_pallas(q, k_pool, v_pool, page_table, seq_lens,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "sm_scale"))
def mla_paged_attention(q_latent, q_rope, latent_pool, page_table, seq_lens,
                        *, interpret: bool = False, sm_scale=None):
    return _mla_pallas(q_latent, q_rope, latent_pool, page_table, seq_lens,
                       interpret=interpret, sm_scale=sm_scale)
