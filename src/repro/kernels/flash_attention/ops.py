"""jit'd public wrapper for the Pallas flash attention kernel.

Models use seq-major [B, S, H, D] activations; the kernel wants head-major
tiles.  The transpose pair is fused by XLA into the surrounding layout
assignment on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_hmajor


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "sm_scale", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, sm_scale=None,
                    interpret: bool = False):
    """q: [B, Sq, Hq, Dk]; k/v: [B, Sk, Hkv, D*].  Returns [B, Sq, Hq, Dv]."""
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = flash_attention_hmajor(qh, kh, vh, causal=causal, block_q=block_q,
                                 block_k=block_k, sm_scale=sm_scale,
                                 interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
