"""Pure-jnp oracle for flash attention (also the CPU/dry-run lowering path).

``tiled_causal_attention`` processes exactly the lower-triangular tiles via a
single ``lax.scan`` over a static (i, j) tile list, so

  * HLO size is O(1) in sequence length (one scan body),
  * peak memory is O(tile), and
  * cost_analysis FLOPs count only the causally-needed work (no 2x masked
    waste) — important because the roofline tables read HLO_FLOPs directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, T, Hkv, D] -> [B, T, Hkv*n_rep, D] (GQA head replication)."""
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)).reshape(
        b, t, h * n_rep, d)


def reference_attention(q, k, v, *, causal: bool = True, scale=None):
    """Naive O(S^2)-memory oracle used by the kernel unit tests."""
    b, s, hq, d = q.shape
    _, t, hkv, _ = k.shape
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "causal"))
def tiled_causal_attention(q, k, v, *, chunk: int = 512, causal: bool = True):
    """Memory-efficient exact attention.

    q: [B, S, Hq, D];  k, v: [B, T, Hkv, D] with T == S for causal self-attn.
    Returns [B, S, Hq, D].
    """
    b, s, hq, d = q.shape
    _, t, hkv, _ = k.shape
    dv = v.shape[-1]
    n_rep = hq // hkv
    scale = 1.0 / np.sqrt(d)

    chunk = min(chunk, s, t)
    # pad S and T to chunk multiples
    sp = (s + chunk - 1) // chunk * chunk
    tp = (t + chunk - 1) // chunk * chunk
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    nq, nk = sp // chunk, tp // chunk

    # static tile list: causal keeps j <= i (+ diagonal offset for T > S)
    off = (tp - sp) // chunk
    tiles = [(i, j) for i in range(nq) for j in range(nk)
             if (not causal) or j <= i + off]
    tile_idx = jnp.asarray(tiles, jnp.int32)  # [n_tiles, 2]

    qp = qp.reshape(b, nq, chunk, hq, d)
    kp = kp.reshape(b, nk, chunk, hkv, d)
    vp = vp.reshape(b, nk, chunk, hkv, dv)

    o0 = jnp.zeros((b, nq, chunk, hq, dv), jnp.float32)
    m0 = jnp.full((b, nq, chunk, hq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, chunk, hq), jnp.float32)

    pos_q = jnp.arange(chunk)
    pos_k = jnp.arange(chunk)

    def step(carry, ij):
        o, m, l = carry
        i, j = ij[0], ij[1]
        qt = jax.lax.dynamic_index_in_dim(qp, i, 1, keepdims=False)   # [B,C,Hq,D]
        kt = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)   # [B,C,Hkv,D]
        vt = jax.lax.dynamic_index_in_dim(vp, j, 1, keepdims=False)
        if n_rep > 1:
            kt = _repeat_kv(kt, n_rep)
            vt = _repeat_kv(vt, n_rep)
        sc = jnp.einsum("bqhd,bkhd->bqhk", qt.astype(jnp.float32),
                        kt.astype(jnp.float32)) * scale                # [B,C,Hq,C]
        # causal mask on the diagonal tile + padded-key mask
        q_abs = i * chunk + pos_q                                      # [C]
        k_abs = j * chunk + pos_k                                      # [C]
        ok = k_abs[None, :] < t
        if causal:
            ok = ok & (k_abs[None, :] <= q_abs[:, None] + (t - s))
        sc = jnp.where(ok[None, :, None, :], sc, NEG_INF)

        mt = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        lt = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ot = jax.lax.dynamic_index_in_dim(o, i, 1, keepdims=False)

        m_new = jnp.maximum(mt, sc.max(axis=-1))
        alpha = jnp.exp(mt - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = lt * alpha + p.sum(axis=-1)
        o_new = ot * alpha[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p, vt.astype(jnp.float32))

        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), tile_idx)
    out = o / jnp.maximum(l[..., None], 1e-20)
    out = out.reshape(b, sp, hq, dv)[:, :s]
    return out.astype(q.dtype)


def cross_attention(q, k, v, *, chunk: int = 512):
    """Non-causal cross attention (e.g. text->image); kv is short & static."""
    return tiled_causal_attention(q, k, v, chunk=chunk, causal=False)
