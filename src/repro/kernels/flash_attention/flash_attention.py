"""Pallas TPU flash attention (prefill/train path).

Tiling: grid (batch, q_head, q_blocks, kv_blocks) with the kv axis innermost;
per-(b, h, i) the online-softmax state (m, l, acc) lives in VMEM scratch and
the output tile is emitted on the final kv block of that row.  Causal rows
skip kv blocks strictly above the diagonal via ``pl.when`` — skipped blocks
cost no MXU work, matching the exact-FLOP ref oracle.

GQA is handled in the k/v BlockSpec index maps (kv head = q head // n_rep),
so kv tiles are never materialized per q-head in HBM.

Supports Dk != Dv (MLA prefill: qk dim 192, v dim 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  q_len: int, k_len: int):
    i = pl.program_id(2)           # q block
    j = pl.program_id(3)           # kv block
    nk = pl.num_programs(3)

    # last kv block this q row touches (diagonal block for causal)
    off = k_len - q_len
    if causal:
        j_max = jnp.minimum((i * block_q + block_q - 1 + off) // block_k,
                            nk - 1)
    else:
        j_max = nk - 1

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(j <= j_max)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, Dk]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, Dk]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                  # [bq, bk]

        q_abs = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_abs = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_abs < k_len                              # padded keys
        if causal:
            mask = jnp.logical_and(mask, k_abs <= q_abs + off)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]                                 # [bq, 1]
        l_prev = l_s[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # [bq, bk]
        l_s[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_s[...] = acc_s[...] * alpha + pv

    @pl.when(j == j_max)
    def _emit():
        l = jnp.maximum(l_s[...], 1e-20)
        o_ref[0, 0] = (acc_s[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "sm_scale", "interpret"))
def flash_attention_hmajor(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           sm_scale: float | None = None,
                           interpret: bool = False):
    """Head-major flash attention.

    q: [B, Hq, Sq, Dk];  k: [B, Hkv, Sk, Dk];  v: [B, Hkv, Sk, Dv].
    Returns [B, Hq, Sq, Dv].
    """
    b, hq, sq, dk = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]
    n_rep = hq // hkv
    scale = sm_scale if sm_scale is not None else dk ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_p = pl.cdiv(sq, block_q) * block_q
    sk_p = pl.cdiv(sk, block_k) * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    nq, nk = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, q_len=sq, k_len=sk)

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, dv), q.dtype),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dk),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, dk),
                         lambda b_, h, i, j, n_rep=n_rep: (b_, h // n_rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, i, j, n_rep=n_rep: (b_, h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dv),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, dv), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]
