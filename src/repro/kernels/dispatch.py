"""Kernel implementation dispatch.

Models call through here.  ``impl``:
  auto    -> pallas on TPU backends, ref elsewhere (CPU dry-run / tests)
  pallas  -> force the Pallas kernel (interpret=True off-TPU)
  ref     -> force the pure-jnp oracle

The ref path is not a toy: it is scan-tiled, exact-FLOP, bounded-memory JAX
(see flash_attention/ref.py) and is what the CPU dry-run lowers, so the
roofline's cost_analysis reflects the same math the TPU kernels perform.
"""

from __future__ import annotations

import functools

import jax

_FORCED_IMPL = None  # test hook


def set_default_impl(impl):
    global _FORCED_IMPL
    _FORCED_IMPL = impl


@functools.lru_cache(maxsize=1)
def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def resolve_impl(impl: str = "auto") -> str:
    if _FORCED_IMPL is not None:
        return _FORCED_IMPL
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def flash_attention(q, k, v, *, causal=True, chunk=512, impl="auto"):
    from repro.kernels.flash_attention import ops, ref
    if resolve_impl(impl) == "pallas":
        return ops.flash_attention(q, k, v, causal=causal,
                                   interpret=not _on_tpu())
    return ref.tiled_causal_attention(q, k, v, chunk=chunk, causal=causal)


def paged_attention(q, k_pool, v_pool, page_table, seq_lens, *,
                    pages_per_step=8, impl="auto", with_stats=False):
    from repro.kernels.paged_attention import ops, ref
    if resolve_impl(impl) == "pallas":
        out, stats = ops.paged_attention(q, k_pool, v_pool, page_table,
                                         seq_lens, interpret=not _on_tpu())
    else:
        out, stats = ref.paged_attention(q, k_pool, v_pool, page_table,
                                         seq_lens, pages_per_step=pages_per_step)
    return (out, stats) if with_stats else out


def mla_paged_attention(q_latent, q_rope, latent_pool, page_table, seq_lens, *,
                        pages_per_step=8, impl="auto", with_stats=False,
                        sm_scale=None):
    from repro.kernels.paged_attention import ops, ref
    if resolve_impl(impl) == "pallas":
        out, stats = ops.mla_paged_attention(q_latent, q_rope, latent_pool,
                                             page_table, seq_lens,
                                             interpret=not _on_tpu(),
                                             sm_scale=sm_scale)
    else:
        out, stats = ref.mla_paged_attention(q_latent, q_rope, latent_pool,
                                             page_table, seq_lens,
                                             pages_per_step=pages_per_step,
                                             sm_scale=sm_scale)
    return (out, stats) if with_stats else out


def directory_probe(keys, queries, *, max_probe=128, impl="auto"):
    from repro.kernels.directory_probe import ops
    if resolve_impl(impl) == "pallas":
        return ops.probe_batch(keys, queries, max_probe=max_probe,
                               interpret=not _on_tpu())
    return ops.probe_batch_ref(keys, queries, max_probe=max_probe)


def page_gather(pool, page_ids, *, impl="auto"):
    from repro.kernels.page_gather import ops, ref
    if resolve_impl(impl) == "pallas":
        return ops.page_gather(pool, page_ids, interpret=not _on_tpu())
    return ref.page_gather(pool, page_ids)


def page_scatter(pool, page_ids, pages, *, impl="auto"):
    from repro.kernels.page_gather import ops, ref
    if resolve_impl(impl) == "pallas":
        return ops.page_scatter(pool, page_ids, pages, interpret=not _on_tpu())
    return ref.page_scatter(pool, page_ids, pages)
