"""Pure-jnp oracle for DPC page gather/scatter.

This is the data-plane of the paper's remote read: fetching whole KV pages
from the (remote) owner's pool slice into a local staging buffer — the TPU
analog of a CXL.mem read of a mapped page — and installing newly committed
pages (E -> O) into pool slots.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def page_gather(pool: jax.Array, page_ids: jax.Array) -> jax.Array:
    """pool: [P, page, ...], page_ids: [N] int32 (-1 => zero page).

    Returns [N, page, ...].
    """
    safe = jnp.maximum(page_ids, 0)
    out = pool[safe]
    mask = (page_ids >= 0).reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, jnp.zeros_like(out))


def page_scatter(pool: jax.Array, page_ids: jax.Array,
                 pages: jax.Array) -> jax.Array:
    """Install pages at slots ``page_ids`` (-1 entries are dropped).

    pool: [P, page, ...]; page_ids: [N]; pages: [N, page, ...].
    Returns updated pool.
    """
    valid = page_ids >= 0
    # route invalid writes to a scratch slot past the end, then slice off
    p = pool.shape[0]
    ids = jnp.where(valid, page_ids, p)
    padded = jnp.concatenate([pool, jnp.zeros_like(pool[:1])], axis=0)
    padded = padded.at[ids].set(pages.astype(pool.dtype))
    return padded[:p]
