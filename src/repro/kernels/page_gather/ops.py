"""jit'd wrappers: flatten arbitrary page feature dims for the Pallas kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.page_gather import page_gather as _pk


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(pool, page_ids, *, interpret: bool = False):
    """pool: [P, page, ...]; ids: [N] -> [N, page, ...]."""
    p, page = pool.shape[:2]
    feat = pool.shape[2:]
    f = 1
    for d in feat:
        f *= d
    out = _pk.page_gather(pool.reshape(p, page, f), page_ids,
                          interpret=interpret)
    return out.reshape((page_ids.shape[0], page) + feat)


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_scatter(pool, page_ids, pages, *, interpret: bool = False):
    """pool: [P, page, ...]; ids: [N]; pages: [N, page, ...]."""
    p, page = pool.shape[:2]
    feat = pool.shape[2:]
    f = 1
    for d in feat:
        f *= d
    n = page_ids.shape[0]
    out = _pk.page_scatter(pool.reshape(p, page, f), page_ids,
                           pages.reshape(n, page, f), interpret=interpret)
    return out.reshape((p, page) + feat)
