"""Pallas TPU page gather/scatter — the DPC data plane.

``page_gather`` is the ship_data remote read: DMA whole pool pages selected
by a scalar-prefetched id vector into a staging buffer (the "CXL.mem read of
a mapped page").  ``page_scatter`` installs committed pages (E -> O) into
pool slots in place via input/output aliasing.  Invalid ids (< 0) gather a
zero page / scatter into a sacrificial scratch slot appended by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(ids_ref, pool_ref, o_ref):
    n = pl.program_id(0)
    valid = ids_ref[n] >= 0
    page = pool_ref[0]
    o_ref[0] = jnp.where(valid, page, jnp.zeros_like(page))


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(pool, page_ids, *, interpret: bool = False):
    """pool: [P, page, F] (wrapper-flattened features); ids: [N] int32.
    Returns [N, page, F]."""
    p, page, f = pool.shape
    n = page_ids.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n, page, f), pool.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec(
                (1, page, f),
                lambda i, ids: (jnp.maximum(ids[i], 0), 0, 0))],
            out_specs=pl.BlockSpec((1, page, f), lambda i, ids: (i, 0, 0)),
        ),
        interpret=interpret,
    )(page_ids, pool)


def _scatter_kernel(ids_ref, pages_ref, pool_in_ref, pool_ref):
    del ids_ref, pool_in_ref
    pool_ref[0] = pages_ref[0].astype(pool_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "donate"))
def page_scatter(pool, page_ids, pages, *, interpret: bool = False,
                 donate: bool = True):
    """Install pages [N, page, F] at slots ``page_ids`` (-1 dropped).

    The pool is extended by one sacrificial slot that absorbs invalid writes,
    then sliced back — the kernel itself writes unconditionally through the
    aliased output so valid slots update in place.
    """
    del donate
    p, page, f = pool.shape
    n = page_ids.shape[0]
    padded = jnp.concatenate([pool, jnp.zeros_like(pool[:1])], axis=0)
    safe_ids = jnp.where(page_ids >= 0, page_ids, p)

    out = pl.pallas_call(
        _scatter_kernel,
        out_shape=jax.ShapeDtypeStruct((p + 1, page, f), pool.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, page, f), lambda i, ids: (i, 0, 0)),
                pl.BlockSpec((1, page, f), lambda i, ids: (ids[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, page, f), lambda i, ids: (ids[i], 0, 0)),
        ),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(safe_ids, pages, padded)
    return out[:p]
