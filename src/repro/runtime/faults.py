"""Deterministic seed-driven fault injection for the DPC protocol.

Every existing invariant (single-copy, flush-before-free,
shootdown-before-remap, zero lost committed dirty bytes) has only ever
been asserted on *clean* executions.  :class:`FaultPlan` threads a
seeded stream of message-layer faults through the protocol's routed
opcode batches, its SHOOTDOWN/COPY/FLUSH descriptor lanes, and the
writeback queue, so the same assertions run under loss, reordering,
duplication, crashes, and clock skew — deterministically: one seed, one
schedule, one replayable execution.

Fault semantics are chosen to preserve the protocol's *interface*
contracts while stressing its *ordering* machinery:

* **drop** — a routed batch send fails transiently; the transport
  retries with bounded exponential backoff (accounted, never slept) and
  delivers within ``max_retries`` attempts.  Callers need answers (the
  directory is RPC-shaped), so reliable-delivery-with-retries is the
  real-world model; exceeding the budget counts a ``send_timeouts``.
* **delay** — a node's pending descriptor lanes (shootdowns, COPY,
  FLUSH) sit out the next ``delay_batches`` routed batches before
  delivery.  The protocol's fences (``TLBGroup.fence``,
  ``fence_data_lanes``) must force-settle them before any completion
  can observe stale state — exactly the machinery under test.
* **duplicate** — a node's lane delivery is serviced twice; receiver
  idempotence (metadata pop-once) must make the second a no-op.
* **crash** — :class:`NodeCrash` raises at a *named crash point* (a
  clean state boundary: ``pre_migrate_finish``, ``post_flush_register``,
  ``mid_drain_chunk``, ``pre_reclaim_finish``, ``post_commit``); the
  harness catches it and drives the ordinary failover path.
* **clock skew** — a node's liveness clock runs offset, so heartbeat
  expiry (false suspicion) paths fire under test control.
* **sync failure** — the backing store's sync fails transiently; the
  writeback pipeline must re-drive the batch without dropping or
  reordering obligations.

All accounting lands in the obs registry under ``(node, "faults", ...)``
so soaks and traces can report exactly which faults a run absorbed.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = ["FaultConfig", "FaultPlan", "NodeCrash", "InjectedSyncError",
           "CRASH_POINTS", "FAULT_COUNTERS"]

# named crash points — each is a clean state boundary in the protocol
CRASH_POINTS = ("pre_migrate_finish", "post_flush_register",
                "mid_drain_chunk", "pre_reclaim_finish", "post_commit")

FAULT_COUNTERS = ("drops_injected", "retries", "backoff_us",
                  "send_timeouts", "lanes_delayed", "lanes_duplicated",
                  "crashes_fired", "sync_fails_injected", "skew_applied")


class NodeCrash(RuntimeError):
    """A node crashed at a named crash point.  The harness catches this
    and drives the ordinary failover path (``Membership.evict``)."""

    def __init__(self, node: int, point: str):
        super().__init__(f"node {node} crashed at {point!r}")
        self.node = node
        self.point = point


class InjectedSyncError(RuntimeError):
    """Fault-injected transient backing-store sync failure — retried by
    the writeback pipeline, never surfaced to callers."""


@dataclasses.dataclass
class FaultConfig:
    """Knobs for one deterministic fault schedule."""
    seed: int = 0
    drop_p: float = 0.0          # transient send failure per routed op
    delay_p: float = 0.0         # per (node, batch): defer its lanes
    delay_batches: int = 2       # how many batches a delayed lane sits out
    dup_p: float = 0.0           # per (node, batch): deliver lanes twice
    sync_fail_p: float = 0.0     # per writeback batch: transient sync fail
    max_retries: int = 3
    backoff_base_us: int = 50    # exponential: base * 2^attempt (accounted)
    # (crash_point, node) -> fire on the Nth hit of that point for that node
    crashes: Dict[Tuple[str, int], int] = dataclasses.field(
        default_factory=dict)
    clock_skew_s: Dict[int, float] = dataclasses.field(default_factory=dict)


class FaultPlan:
    """One seeded, replayable fault schedule threaded through a cluster.

    All randomness comes from one ``np.random.default_rng(seed)`` drawn
    in deterministic call order, so a (seed, workload) pair is exactly
    reproducible — the property tier leans on that to shrink failures.
    """

    def __init__(self, cfg: FaultConfig, obs=None):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.obs = obs
        self._views: Dict[int, dict] = {}
        # lane delay state: node -> batch index before which its lanes
        # stay queued; one global batch counter orders the delays
        self._batch = 0
        self._delay_until: Dict[int, int] = {}
        self._crash_hits: Dict[Tuple[str, int], int] = {}
        self._fired: Set[Tuple[str, int]] = set()
        # crash points disarm while the recovery path itself runs (the
        # failover for one crash must not trip another mid-cleanup)
        self._disarmed = 0

    # -- accounting -----------------------------------------------------

    def _stats(self, node: int) -> dict:
        view = self._views.get(node)
        if view is None:
            if self.obs is not None:
                view = self.obs.view(node, "faults", FAULT_COUNTERS)
            else:
                view = {n: 0 for n in FAULT_COUNTERS}
            self._views[node] = view
        return view

    def counters(self, node: int) -> dict:
        """Read-side view of one node's fault counters."""
        return dict(self._stats(node))

    # -- routed-batch transport faults ----------------------------------

    def routed_send(self, nodes: Sequence[int]) -> None:
        """Model the send of one routed opcode batch on behalf of
        ``nodes``: injected transient failures retry with bounded
        exponential backoff (accounted in µs, never slept — the soak
        measures protocol work, not injected sleep)."""
        self._batch += 1
        if self.cfg.drop_p <= 0.0:
            return
        for nd in nodes:
            attempts = 0
            while attempts < self.cfg.max_retries \
                    and self.rng.random() < self.cfg.drop_p:
                attempts += 1
            if attempts:
                st = self._stats(int(nd))
                st["drops_injected"] += attempts
                st["retries"] += attempts
                st["backoff_us"] += sum(
                    self.cfg.backoff_base_us << a for a in range(attempts))
                if attempts >= self.cfg.max_retries:
                    # budget exhausted: the op still delivers (bounded
                    # retry is the transport contract) but the overrun
                    # is visible as a timeout
                    st["send_timeouts"] += 1

    def lane_delayed(self, node: int) -> bool:
        """Should ``node``'s pending descriptor lanes sit this batch
        out?  Once a delay arms, the node's lanes stay queued for
        ``delay_batches`` routed batches (reorder-by-N) — fences still
        force-settle them, which is exactly the invariant under test."""
        node = int(node)
        until = self._delay_until.get(node)
        if until is not None:
            if self._batch < until:
                return True
            del self._delay_until[node]
            return False
        if self.cfg.delay_p > 0.0 and self.rng.random() < self.cfg.delay_p:
            self._delay_until[node] = self._batch + self.cfg.delay_batches
            self._stats(node)["lanes_delayed"] += 1
            return True
        return False

    def lane_duplicated(self, node: int) -> bool:
        """Should ``node``'s lane delivery be serviced twice?"""
        if self.cfg.dup_p > 0.0 and self.rng.random() < self.cfg.dup_p:
            self._stats(int(node))["lanes_duplicated"] += 1
            return True
        return False

    # -- crash points ---------------------------------------------------

    def check_crash(self, point: str, node: int) -> None:
        """Raise :class:`NodeCrash` when the plan armed a crash at this
        (point, node) and its hit count is reached.  Each armed crash
        fires at most once."""
        if not self.cfg.crashes or self._disarmed:
            return
        key = (point, int(node))
        want = self.cfg.crashes.get(key)
        if want is None or key in self._fired:
            return
        hits = self._crash_hits.get(key, 0) + 1
        self._crash_hits[key] = hits
        if hits >= want:
            self._fired.add(key)
            self._stats(int(node))["crashes_fired"] += 1
            raise NodeCrash(int(node), point)

    def disarm(self) -> None:
        """Suspend crash points (recovery paths call this so cleanup for
        one crash cannot trip another)."""
        self._disarmed += 1

    def rearm(self) -> None:
        self._disarmed = max(0, self._disarmed - 1)

    # -- clock skew -----------------------------------------------------

    def skewed_clock(self, node: int,
                     base: Callable[[], float]) -> Callable[[], float]:
        """Wrap a liveness clock with this node's configured skew."""
        skew = self.cfg.clock_skew_s.get(int(node), 0.0)
        if not skew:
            return base
        self._stats(int(node))["skew_applied"] += 1
        return lambda: base() + skew

    # -- storage sync faults --------------------------------------------

    def sync_fails(self) -> bool:
        """Should this writeback batch's sync fail transiently?"""
        if self.cfg.sync_fail_p > 0.0 \
                and self.rng.random() < self.cfg.sync_fail_p:
            self._stats(-1)["sync_fails_injected"] += 1
            return True
        return False


def random_plan(seed: int, num_nodes: int, *, obs=None,
                intensity: float = 1.0,
                crash_candidates: Sequence[int] = ()) -> FaultPlan:
    """Draw one randomized :class:`FaultConfig` from ``seed`` — the soak
    harness's schedule generator.  ``intensity`` scales all fault
    probabilities; ``crash_candidates`` are nodes the schedule may crash
    (the harness excludes nodes whose loss the workload can't absorb)."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    crashes: Dict[Tuple[str, int], int] = {}
    if len(crash_candidates) and rng.random() < 0.6:
        point = CRASH_POINTS[int(rng.integers(len(CRASH_POINTS)))]
        node = int(crash_candidates[
            int(rng.integers(len(crash_candidates)))])
        crashes[(point, node)] = int(rng.integers(1, 4))
    skew = {}
    if num_nodes and rng.random() < 0.4:
        skew[int(rng.integers(num_nodes))] = float(rng.uniform(-5.0, 5.0))
    cfg = FaultConfig(
        seed=seed,
        drop_p=float(rng.uniform(0.0, 0.15)) * intensity,
        delay_p=float(rng.uniform(0.0, 0.25)) * intensity,
        delay_batches=int(rng.integers(1, 5)),
        dup_p=float(rng.uniform(0.0, 0.25)) * intensity,
        sync_fail_p=float(rng.uniform(0.0, 0.2)) * intensity,
        crashes=crashes,
        clock_skew_s=skew,
    )
    return FaultPlan(cfg, obs=obs)
