"""Lease-based replicated epoch log — the membership control plane.

The single-copy invariant is only as strong as the membership view that
backs it: if a split cluster can run two independent views, two nodes
can each believe they own a page.  This module makes every membership
transition a *proposed log entry* that commits only with acknowledgments
from a quorum — a majority of all participants (voters plus optional
witness nodes).  :class:`~repro.runtime.liveness.Membership` is a view
over the committed log: its epoch is the committed log length, and every
protocol-visible epoch bump carries the **fencing token** (the commit
index) so a stale-epoch node's routed batches can be rejected by a
single integer compare.

Lease model (the DAXFS shape from PAPERS.md): a participant's lease is a
word on CXL shared memory.  A *crashed* node's lease is still readable —
its expiry is witness-attested — so node death never blocks a quorum;
the quorum denominator stays the full participant set (which is exactly
what prevents split-brain: both sides of a partition count against the
same denominator, and at most one side can reach a majority).  The only
thing that blocks acknowledgments is a **partition**: participants on
the other side of the split are unreachable, their leases can't be
attested, and a proposer on the minority side raises
:class:`QuorumLostError` — it must stop serving ownership transitions
(degrade to local-only, like ``DirectoryClientGuard``) until the
partition heals and it rejoins through the committed log.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Sequence, Set

__all__ = ["EpochLog", "LogEntry", "QuorumLostError"]


class QuorumLostError(RuntimeError):
    """A proposal could not gather a quorum of acknowledgments — the
    proposer is on the minority side of a partition and must degrade to
    local-only serving instead of committing membership transitions."""

    def __init__(self, kind: str, node: int, acks: int, quorum: int):
        super().__init__(
            f"membership proposal ({kind!r}, node {node}) reached only "
            f"{acks}/{quorum} acknowledgments — minority partition, "
            "degrade to local-only")
        self.kind = kind
        self.node = node
        self.acks = acks
        self.quorum = quorum


@dataclasses.dataclass(frozen=True)
class LogEntry:
    """One committed membership transition.

    ``index`` is the 1-based commit index — the cluster epoch after this
    entry applies, and the fencing token any protocol-visible bump for
    this transition carries."""
    index: int
    kind: str                  # join | drain | fail | fence | heal | ...
    node: int
    acks: FrozenSet[int]       # participants that acknowledged
    term: int                  # partition generation at commit time


class EpochLog:
    """Quorum-committed membership log with a partition model.

    Participants are the voter set (the founding nodes, grown by
    ``add_voter`` on join) plus ``witnesses`` ack-only members (ids -1,
    -2, ... — they hold no pages, they only attest leases, which lets a
    two-node cluster survive one node's death without split-brain).
    """

    def __init__(self, num_nodes: int, witnesses: int = 0):
        self.voters: Set[int] = set(range(num_nodes))
        self.witnesses: Set[int] = {-(i + 1) for i in range(witnesses)}
        self.entries: List[LogEntry] = []
        # the minority side of the current partition (empty = healthy).
        # Witnesses always land majority-side: they model shared-memory
        # lease words reachable from the surviving fabric.
        self.minority: Set[int] = set()
        self.term = 0              # bumps on every partition() / heal()

    # -- views ----------------------------------------------------------

    @property
    def participants(self) -> Set[int]:
        return self.voters | self.witnesses

    @property
    def quorum(self) -> int:
        """Majority of ALL participants — the denominator never shrinks
        on death (dead leases still attest), only grows on join."""
        return len(self.participants) // 2 + 1

    @property
    def epoch(self) -> int:
        """Committed log length == current cluster epoch."""
        return len(self.entries)

    @property
    def fence_token(self) -> int:
        """The token a protocol-visible bump for the latest commit
        carries; monotone non-decreasing by construction."""
        return len(self.entries)

    def reachable_from(self, proposer: Optional[int]) -> Set[int]:
        """Participants whose ack (live response or witness-attested
        lease word) the proposer can collect.  ``None`` proposes from
        the majority side (the common case: the in-process control
        plane *is* the surviving fabric)."""
        if proposer is not None and proposer in self.minority:
            return set(self.minority)
        return self.participants - self.minority

    # -- mutation -------------------------------------------------------

    def add_voter(self, node: int) -> None:
        """A brand-new node joins the voter set (the quorum denominator
        grows).  Departed voters are *not* removed: their leases persist
        on CXL shared memory, keeping the denominator fixed so a later
        partition cannot split-brain against a shrunken quorum."""
        self.voters.add(int(node))

    def propose(self, kind: str, node: int,
                proposer: Optional[int] = None) -> LogEntry:
        """Propose one membership transition; commit iff a quorum acks.

        Raises :class:`QuorumLostError` when the proposer's side cannot
        reach a majority — the caller must degrade, not retry."""
        acks = self.reachable_from(proposer)
        if len(acks) < self.quorum:
            raise QuorumLostError(kind, node, len(acks), self.quorum)
        entry = LogEntry(index=len(self.entries) + 1, kind=kind,
                         node=int(node), acks=frozenset(acks),
                         term=self.term)
        self.entries.append(entry)
        return entry

    def partition(self, minority: Sequence[int]) -> Set[int]:
        """Split the cluster: ``minority`` becomes unreachable from the
        rest.  Refuses a split that would leave *no* side with a quorum
        alive is allowed (both sides then degrade); refuses nothing —
        the quorum math itself decides who may still commit."""
        self.minority = set(int(n) for n in minority) & self.voters
        self.term += 1
        return set(self.minority)

    def heal(self) -> Set[int]:
        """The partition heals: everyone is reachable again.  Returns
        the previously-fenced minority (the caller drives their
        re-probe/rejoin)."""
        healed, self.minority = set(self.minority), set()
        self.term += 1
        return healed

    def has_quorum(self, proposer: Optional[int] = None) -> bool:
        return len(self.reachable_from(proposer)) >= self.quorum
