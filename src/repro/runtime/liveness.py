"""Liveness, membership epochs, elastic re-meshing, straggler mitigation.

Paper §5 at cluster scale, plus the training-side fault-tolerance features:

  * Heartbeats + failure detection: a node missing ``timeout`` of heartbeats
    is declared failed; the directory drops it (DistributedKVCache.fail_node)
    and any invalidation waiting on its ACK completes — eviction liveness.
  * Membership epochs: each change bumps the epoch; step functions are
    re-lowered per epoch mesh (elastic data-parallel width).
  * Symmetric directory failure: clients that lose the directory fall back
    to local-only caching (paper's client-side timeout).
  * Straggler watchdog: per-step durations feed an EWMA; steps slower than
    ``straggler_factor``× the EWMA mark the slowest node suspect, and after
    ``strikes`` consecutive marks the policy (report | evict) fires.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.obs import CLUSTER

# membership event kind -> counter row in the (CLUSTER, "membership") group
_KIND_COUNTERS = {"join": "joins", "drain": "drains", "fail": "fails",
                  "evict_straggler": "stragglers_evicted",
                  "dir_lost": "dir_lost"}


@dataclasses.dataclass
class MembershipEvent:
    epoch: int
    kind: str          # join | drain | fail | evict_straggler | dir_lost
    node: int
    t: float


class Membership:
    """Heartbeat-driven membership with epochs."""

    def __init__(self, num_nodes: int, timeout_s: float = 15.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout_s = timeout_s
        self.epoch = 0
        self.last_seen: Dict[int, float] = {
            n: clock() for n in range(num_nodes)}
        self.alive: Set[int] = set(range(num_nodes))
        self.events: List[MembershipEvent] = []
        self._listeners: List[Callable[[MembershipEvent], None]] = []

    def on_change(self, fn: Callable[[MembershipEvent], None]) -> None:
        self._listeners.append(fn)

    def attach_obs(self, obs) -> None:
        """Report membership transitions into the observability hub: one
        counter per event kind plus the current epoch, recorded *before*
        the reacting listeners run so the protocol's own incarnation fold
        (rejoin) can never zero the event that caused it."""
        stats = obs.view(CLUSTER, "membership",
                         tuple(_KIND_COUNTERS.values()) + ("epoch",))

        def _record(ev: MembershipEvent) -> None:
            stats["epoch"] = ev.epoch
            name = _KIND_COUNTERS.get(ev.kind)
            if name is not None:
                stats[name] += 1

        self._listeners.insert(0, _record)

    def heartbeat(self, node: int) -> None:
        if node in self.alive:
            self.last_seen[node] = self.clock()

    def _emit(self, kind: str, node: int) -> None:
        self.epoch += 1
        ev = MembershipEvent(self.epoch, kind, node, self.clock())
        self.events.append(ev)
        for fn in self._listeners:
            fn(ev)

    def check(self) -> List[int]:
        """Declare nodes failed whose heartbeat lapsed.  Returns new
        failures."""
        now = self.clock()
        failed = [n for n in self.alive
                  if now - self.last_seen[n] > self.timeout_s]
        for n in failed:
            self.alive.discard(n)
            self._emit("fail", n)
        return failed

    def evict(self, node: int, kind: str = "evict_straggler") -> None:
        if node in self.alive:
            self.alive.discard(node)
            self._emit(kind, node)

    def drain(self, node: int) -> None:
        """Planned departure: the event fires while the node is still listed
        alive, so listeners can evacuate through it (the protocol drain
        needs a live peer to MIGRATE against) before it drops out."""
        if node not in self.alive:
            return
        self._emit("drain", node)
        self.alive.discard(node)

    def join(self, node: int) -> None:
        self.alive.add(node)
        self.last_seen[node] = self.clock()
        self._emit("join", node)


def elastic_mesh_shape(alive_nodes: int, model_parallel: int,
                       pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest (pod, data, model) mesh runnable on the surviving chips.

    Chips per node group = model_parallel; data width shrinks to the largest
    value the survivors support.  Returns None when nothing runnable
    remains."""
    groups = alive_nodes // model_parallel
    if groups < 1:
        return None
    data = groups // pods
    if data < 1:
        pods, data = 1, groups
    return (pods, data, model_parallel) if pods > 1 else \
        (data, model_parallel)


class StragglerWatchdog:
    def __init__(self, factor: float = 2.0, strikes: int = 3,
                 ewma: float = 0.9):
        self.factor = factor
        self.strikes_needed = strikes
        self.ewma_coef = ewma
        self.ewma: Optional[float] = None
        self.strikes: Dict[int, int] = {}
        self.flagged: List[Tuple[int, float]] = []

    def observe(self, step_time_s: float,
                slowest_node: Optional[int] = None) -> Optional[int]:
        """Feed one step duration; returns a node id when the policy fires."""
        if self.ewma is None:
            self.ewma = step_time_s
            return None
        is_slow = step_time_s > self.factor * self.ewma
        # only non-straggler steps update the baseline
        if not is_slow:
            self.ewma = self.ewma_coef * self.ewma + \
                (1 - self.ewma_coef) * step_time_s
        if is_slow and slowest_node is not None:
            c = self.strikes.get(slowest_node, 0) + 1
            self.strikes[slowest_node] = c
            if c >= self.strikes_needed:
                self.flagged.append((slowest_node, step_time_s))
                self.strikes[slowest_node] = 0
                return slowest_node
        elif slowest_node is not None:
            self.strikes[slowest_node] = 0
        return None


class DirectoryClientGuard:
    """Client-side symmetric timeout (paper §5): if the directory stops
    responding, disconnect from DPC, drop remote mappings, and fall back to
    the purely local page-cache policy."""

    def __init__(self, timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_response = clock()
        self.mode = "dpc"

    def response_received(self) -> None:
        self.last_response = self.clock()

    def check(self) -> str:
        if self.mode == "dpc" and \
                self.clock() - self.last_response > self.timeout_s:
            self.mode = "local_only"
        return self.mode
