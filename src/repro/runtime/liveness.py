"""Liveness, membership epochs, elastic re-meshing, straggler mitigation.

Paper §5 at cluster scale, plus the training-side fault-tolerance features:

  * Heartbeats + failure detection: a node missing ``timeout`` of heartbeats
    is declared failed; the directory drops it (DistributedKVCache.fail_node)
    and any invalidation waiting on its ACK completes — eviction liveness.
  * Membership epochs over a quorum-committed log
    (:class:`~repro.runtime.epoch_log.EpochLog`): each change is a proposed
    entry that commits only with acknowledgments from a majority of
    voters + witnesses; the epoch is the committed log length and doubles
    as the fencing token protocol-visible bumps carry.
  * Partition fencing: ``partition(minority)`` commits "fence" events on
    the majority side — the minority stops serving ownership transitions
    (its routed batches are rejected by fence-token compare) and degrades
    to local-only, the server-side dual of the client guard below.
    ``heal()`` commits "heal" events; fenced nodes rejoin through the
    guard's re-probe hysteresis.
  * Symmetric directory failure: clients that lose the directory fall back
    to local-only caching (paper's client-side timeout), and re-probe
    their way back after ``reprobe_successes`` consecutive responses.
  * Straggler watchdog: per-step durations feed an EWMA seeded from a
    warm-up window (a slow *first* step must not poison the baseline);
    steps slower than ``straggler_factor``× the EWMA mark the slowest
    node suspect, and after ``strikes`` consecutive marks the policy
    (report | evict) fires.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.obs import CLUSTER
from repro.runtime.epoch_log import EpochLog, QuorumLostError

# membership event kind -> counter row in the (CLUSTER, "membership") group
_KIND_COUNTERS = {"join": "joins", "drain": "drains", "fail": "fails",
                  "evict_straggler": "stragglers_evicted",
                  "dir_lost": "dir_lost",
                  "fence": "fences", "heal": "heals"}


@dataclasses.dataclass
class MembershipEvent:
    epoch: int
    kind: str      # join | drain | fail | fence | heal | evict_* | dir_lost
    node: int
    t: float
    fence: int = 0  # fencing token (the committing log entry's index)


class Membership:
    """Heartbeat-driven membership, a view over the committed epoch log.

    Construction is backward-compatible: by default the log has the full
    node set as voters and no partition, so every proposal commits (a
    healthy fully-connected cluster always has quorum)."""

    def __init__(self, num_nodes: int, timeout_s: float = 15.0,
                 clock: Callable[[], float] = time.monotonic,
                 log: Optional[EpochLog] = None, witnesses: int = 0):
        self.clock = clock
        self.timeout_s = timeout_s
        self.log = log if log is not None else EpochLog(
            num_nodes, witnesses=witnesses)
        self.last_seen: Dict[int, float] = {
            n: clock() for n in range(num_nodes)}
        self.alive: Set[int] = set(range(num_nodes))
        self.fenced: Set[int] = set()
        self.events: List[MembershipEvent] = []
        self._listeners: List[Callable[[MembershipEvent], None]] = []
        self._lat_stats = None    # set by attach_obs

    @property
    def epoch(self) -> int:
        """Committed log length — bumps exactly once per committed
        membership transition."""
        return self.log.epoch

    @property
    def fence_token(self) -> int:
        return self.log.fence_token

    def on_change(self, fn: Callable[[MembershipEvent], None]) -> None:
        self._listeners.append(fn)

    def attach_obs(self, obs) -> None:
        """Report membership transitions into the observability hub: one
        counter per event kind plus the current epoch, recorded *before*
        the reacting listeners run so the protocol's own incarnation fold
        (rejoin) can never zero the event that caused it."""
        stats = obs.view(CLUSTER, "membership",
                         tuple(_KIND_COUNTERS.values()) +
                         ("epoch", "fence_token", "quorum_lost"))
        self._lat_stats = stats

        def _record(ev: MembershipEvent) -> None:
            stats["epoch"] = ev.epoch
            stats["fence_token"] = ev.fence
            name = _KIND_COUNTERS.get(ev.kind)
            if name is not None:
                stats[name] += 1

        self._listeners.insert(0, _record)

    def heartbeat(self, node: int) -> None:
        if node in self.alive:
            self.last_seen[node] = self.clock()

    def _emit(self, kind: str, node: int) -> None:
        """Commit the transition to the log, then run the listeners.
        Raises :class:`QuorumLostError` (uncommitted, no event) when the
        proposing side lacks quorum."""
        entry = self.log.propose(kind, node)
        ev = MembershipEvent(entry.index, kind, node, self.clock(),
                             fence=entry.index)
        self.events.append(ev)
        for fn in self._listeners:
            fn(ev)

    def check(self) -> List[int]:
        """Declare nodes failed whose heartbeat lapsed.  Returns new
        failures."""
        now = self.clock()
        failed = [n for n in self.alive
                  if now - self.last_seen[n] > self.timeout_s]
        for n in failed:
            self.alive.discard(n)
            self._emit("fail", n)
        return failed

    def evict(self, node: int, kind: str = "evict_straggler") -> None:
        if node in self.alive:
            self.alive.discard(node)
            self._emit(kind, node)

    def drain(self, node: int) -> None:
        """Planned departure: the event fires while the node is still listed
        alive, so listeners can evacuate through it (the protocol drain
        needs a live peer to MIGRATE against) before it drops out."""
        if node not in self.alive:
            return
        self._emit("drain", node)
        self.alive.discard(node)

    def join(self, node: int) -> None:
        self.log.add_voter(node)
        self.alive.add(node)
        self.fenced.discard(node)
        self.last_seen[node] = self.clock()
        self._emit("join", node)

    # -- partition fencing ------------------------------------------------

    def partition(self, minority: List[int]) -> List[int]:
        """Split the cluster: ``minority`` lands on the losing side of
        the partition.  The majority side (this object) still has quorum
        and commits one "fence" event per minority node — listeners
        reject the fenced nodes' batches and re-home their pages.  The
        fenced side, were it to propose, would raise
        :class:`QuorumLostError` (see :meth:`assert_no_quorum`)."""
        cut = sorted(self.log.partition(minority) & self.alive)
        for n in cut:
            self.alive.discard(n)
            self.fenced.add(n)
            self._emit("fence", n)
        return cut

    def heal(self) -> List[int]:
        """The partition heals: commit one "heal" event per fenced node.
        Healing does NOT rejoin them — a healed node re-probes through
        the :class:`DirectoryClientGuard` hysteresis and only then calls
        :meth:`join` (the rejoin path), so one flapping link cannot
        thrash the directory."""
        healed = sorted(self.log.heal() & self.fenced)
        for n in healed:
            self._emit("heal", n)
        return healed

    def has_quorum(self, proposer: Optional[int] = None) -> bool:
        return self.log.has_quorum(proposer)

    def assert_no_quorum(self, node: int) -> None:
        """The minority side's self-check: a fenced node proposing any
        transition must observe quorum loss (and degrade) — this drives
        that proposal and expects the raise."""
        try:
            self.log.propose("noop", node, proposer=node)
        except QuorumLostError:
            if self._lat_stats is not None:
                self._lat_stats["quorum_lost"] += 1
            return
        raise AssertionError(
            f"node {node} proposed from the minority side and committed — "
            "split-brain: both partition sides reached quorum")


def elastic_mesh_shape(alive_nodes: int, model_parallel: int,
                       pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest (pod, data, model) mesh runnable on the surviving chips.

    Chips per node group = model_parallel; data width shrinks to the largest
    value the survivors support.  Returns None when nothing runnable
    remains."""
    groups = alive_nodes // model_parallel
    if groups < 1:
        return None
    data = groups // pods
    if data < 1:
        pods, data = 1, groups
    return (pods, data, model_parallel) if pods > 1 else \
        (data, model_parallel)


class StragglerWatchdog:
    def __init__(self, factor: float = 2.0, strikes: int = 3,
                 ewma: float = 0.9, warmup: int = 2):
        self.factor = factor
        self.strikes_needed = strikes
        self.ewma_coef = ewma
        self.warmup = max(1, warmup)
        self._warm: List[float] = []
        self.ewma: Optional[float] = None
        self.strikes: Dict[int, int] = {}
        self.flagged: List[Tuple[int, float]] = []

    def observe(self, step_time_s: float,
                slowest_node: Optional[int] = None) -> Optional[int]:
        """Feed one step duration; returns a node id when the policy fires.

        The EWMA seeds from the *median* of a ``warmup``-step window, not
        from the first step alone — a straggler on step 0 must not poison
        the baseline (every later step would compare against the outlier
        and nothing would ever flag)."""
        if self.ewma is None:
            self._warm.append(step_time_s)
            if len(self._warm) >= self.warmup:
                warm = sorted(self._warm)
                mid = len(warm) // 2
                self.ewma = (warm[mid] if len(warm) % 2
                             else 0.5 * (warm[mid - 1] + warm[mid]))
            return None
        is_slow = step_time_s > self.factor * self.ewma
        # only non-straggler steps update the baseline
        if not is_slow:
            self.ewma = self.ewma_coef * self.ewma + \
                (1 - self.ewma_coef) * step_time_s
        if is_slow and slowest_node is not None:
            c = self.strikes.get(slowest_node, 0) + 1
            self.strikes[slowest_node] = c
            if c >= self.strikes_needed:
                self.flagged.append((slowest_node, step_time_s))
                self.strikes[slowest_node] = 0
                return slowest_node
        elif slowest_node is not None:
            self.strikes[slowest_node] = 0
        return None


class DirectoryClientGuard:
    """Client-side symmetric timeout (paper §5): if the directory stops
    responding, disconnect from DPC, drop remote mappings, and fall back to
    the purely local page-cache policy.

    Degradation is no longer one-way: once in ``local_only`` the guard
    keeps probing, and after ``reprobe_successes`` *consecutive*
    responses it returns to ``dpc`` (hysteresis — one lucky packet on a
    flapping link must not bounce the client straight back).  Partition
    heal reuses this: a fenced node's rejoin rides the same streak."""

    def __init__(self, timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 reprobe_successes: int = 3):
        self.timeout_s = timeout_s
        self.clock = clock
        self.reprobe_successes = max(1, reprobe_successes)
        self.last_response = clock()
        self.mode = "dpc"
        self._streak = 0

    def response_received(self) -> None:
        self.last_response = self.clock()
        if self.mode == "local_only":
            self._streak += 1
            if self._streak >= self.reprobe_successes:
                self.mode = "dpc"
                self._streak = 0

    def probe_failed(self) -> None:
        """A re-probe went unanswered: the streak resets (hysteresis)."""
        self._streak = 0

    def trip(self) -> None:
        """Force local-only (server-side fencing trips the client guard
        directly instead of waiting out the timeout)."""
        self.mode = "local_only"
        self._streak = 0

    def check(self) -> str:
        if self.mode == "dpc" and \
                self.clock() - self.last_response > self.timeout_s:
            self.mode = "local_only"
            self._streak = 0
        return self.mode
