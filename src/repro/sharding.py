"""Logical-axis → mesh-axis sharding rules (MaxText-style).

A ``ParamSpec``/activation carries logical axis names; ``logical_to_pspec``
resolves them to a ``PartitionSpec`` under the current ``ShardingConfig`` and
mesh, dropping any rule whose dimension does not divide the assigned mesh axes
(replicate instead of crash — e.g. kv_heads=4 on model=16).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ShardingConfig
from repro.models.spec import ParamSpec, is_spec_leaf


def _rules(sharding: ShardingConfig, mesh_axes: Sequence[str]):
    """logical name -> tuple of mesh axes (in priority order)."""
    fsdp_axes: Tuple[str, ...] = tuple(
        ax for ax in ("pod", "data") if ax in mesh_axes) if sharding.fsdp else ()
    batch_axes: Tuple[str, ...] = tuple(
        ax for ax in sharding.shard_batch if ax in mesh_axes)
    model = (sharding.shard_heads,) if "model" in mesh_axes else ()
    return {
        "embed": fsdp_axes,              # FSDP shards the embed dim of weights
        "vocab": model,
        "heads": model,
        "kv_heads": model,
        "q_lora": model,
        "kv_lora": (),                   # MLA latent: replicated (small)
        "mlp": model,
        "experts": model,                # EP folded into the model axis
        "batch": batch_axes,
        # sequence parallelism: stashed activations (and norms) keep the seq
        # dim sharded over `model`; XLA turns the TP all_reduce into
        # reduce_scatter + all_gather pairs around the matmuls (same bytes)
        # while dividing remat stash memory by the TP degree.
        "seq": ((sharding.shard_heads,) if sharding.sequence_parallel
                and "model" in mesh_axes else ()),
        "layers": (),
        "groups": (),
        "stack": (),
        "ssm_inner": model,
        "ssm_state": (),
        "conv": (),
        "codebooks": (),
        None: (),
    }


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    return n


def logical_to_pspec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    sharding: ShardingConfig,
) -> P:
    rules = _rules(sharding, mesh.axis_names)
    used = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        axes = rules.get(name, ())
        axes = tuple(a for a in axes if a not in used)
        if axes and dim % _axis_size(mesh, axes) == 0:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def specs_to_shardings(specs, mesh: Mesh, sharding: ShardingConfig):
    """Spec tree -> NamedSharding tree (for in_shardings / constraints)."""
    def one(s: ParamSpec):
        return NamedSharding(mesh, logical_to_pspec(s.logical_axes, s.shape, mesh, sharding))
    return jax.tree.map(one, specs, is_leaf=is_spec_leaf)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]],
              mesh: Mesh, sharding: ShardingConfig) -> jax.Array:
    """with_sharding_constraint by logical names (no-op outside a mesh ctx)."""
    spec = logical_to_pspec(logical_axes, x.shape, mesh, sharding)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_pspec(mesh: Mesh, sharding: ShardingConfig, ndim: int,
                batch_dim: int = 0) -> P:
    axes = tuple(ax for ax in sharding.shard_batch if ax in mesh.axis_names)
    parts: list = [None] * ndim
    if axes:
        parts[batch_dim] = axes if len(axes) > 1 else axes[0]
    return P(*parts)


def make_mesh_from_config(cfg: MeshConfig) -> Mesh:
    return jax.make_mesh(cfg.shape, cfg.axes)


# ---------------------------------------------------------------------------
# activation sharding context (models call ``act``; a no-op unless a trainer
# or the dry-run installs a sharder around tracing)
# ---------------------------------------------------------------------------

_ACT_SHARDER = None
_TP_REDUCE_BF16 = False


def tp_dot_dtype():
    """Accumulation dtype for TP-reduced projections (o-proj / down-proj).

    Inside an ``activation_sharding`` context this is bfloat16: the partial
    products that immediately cross the TP all-reduce are kept in bf16, so
    the collective moves half the bytes (Megatron reduces grads/activations
    in bf16 too).  Outside distributed tracing (unit tests, CPU smoke) the
    default f32 accumulation is kept.  §Perf iteration B4.
    """
    import jax.numpy as jnp
    return jnp.bfloat16 if _TP_REDUCE_BF16 else None


class activation_sharding:
    """Context manager installing a logical-axis activation sharder.

    Usage (at trace time):
        with activation_sharding(mesh, sharding_cfg):
            lowered = jax.jit(step).lower(...)
    """

    def __init__(self, mesh: Mesh, sharding: ShardingConfig):
        self.sharder = lambda x, names: constrain(x, names, mesh, sharding)
        self.tp_bf16 = getattr(sharding, "tp_reduce_bf16", False)

    def __enter__(self):
        global _ACT_SHARDER, _TP_REDUCE_BF16
        self._prev = _ACT_SHARDER
        self._prev_tp = _TP_REDUCE_BF16
        _ACT_SHARDER = self.sharder
        _TP_REDUCE_BF16 = self.tp_bf16
        return self

    def __exit__(self, *exc):
        global _ACT_SHARDER, _TP_REDUCE_BF16
        _ACT_SHARDER = self._prev
        _TP_REDUCE_BF16 = self._prev_tp
        return False


def act(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Constrain an activation by logical axis names (no-op by default)."""
    if _ACT_SHARDER is None:
        return x
    return _ACT_SHARDER(x, names)
