"""Post-compile HLO analysis: collective link-byte accounting + roofline.

cost_analysis() gives per-device FLOPs and HBM bytes but no collective
traffic; we parse the optimized HLO (whose operand shapes are elided — only
*result* shapes R are printed) and charge each collective op its per-device
*link* bytes under ring schedules:

    all-gather          result R, group g ->  R * (g-1)/g   (recv others')
    all-reduce          result R          ->  2 * R * (g-1)/g
    reduce-scatter      result R          ->  R * (g-1)     (operand = R*g)
    all-to-all          result R          ->  R * (g-1)/g
    collective-permute  result R          ->  R

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per the brief).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * (1 << 30)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result segment may contain tuple-index comments ("/*index=5*/") — match
# lazily across anything between "= " and the opcode keyword
_OP_RE = re.compile(
    r"=\s+(.*?)\s"
    r"(all-gather-start|all-gather-done|all-gather|"
    r"all-reduce-start|all-reduce-done|all-reduce|"
    r"reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute)"
    r"\(([^)]*)\)(.*)$")
_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(tail: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(tail)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(tail)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


# computation headers sit at column 0: "%name (args...) -> type {"
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"=\s+.*?\bwhile\(.*?body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_COND_RE = re.compile(
    r"=\s+.*?\bconditional\(.*?branch_computations=\{([^}]*)\}")
_CALL_RE = re.compile(r"=\s+.*?\bcall\(.*?to_apply=%([\w.\-]+)")


def _one_collective(line: str, num_devices: int):
    """Returns (op, result_bytes, link_bytes) or None."""
    m = _OP_RE.search(line)
    if m is None:
        return None
    opname = m.group(2)
    if opname.endswith("-done"):
        return None  # counted at the matching -start
    op = opname.replace("-start", "")
    shapes = _SHAPE_RE.findall(m.group(1))
    if not shapes:
        return None
    if opname.endswith("-start"):
        # -start results are tuples (operand, result): take the last shape
        dt, dims = shapes[-1]
        s = shape_bytes(dt, dims)
    else:
        # variadic collectives (tuple results) move every element
        s = sum(shape_bytes(dt, dims) for dt, dims in shapes)
    g = _group_size(m.group(4), num_devices)
    if g <= 1:
        return None
    if op == "all-gather":
        link = int(s * (g - 1) / g)
    elif op == "all-reduce":
        link = int(2 * s * (g - 1) / g)
    elif op == "reduce-scatter":
        link = s * (g - 1)
    elif op == "all-to-all":
        link = int(s * (g - 1) / g)
    else:  # collective-permute
        link = s
    return op, s, link


def split_computations(hlo_text: str) -> Dict[str, list]:
    """Computation name -> its instruction lines."""
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if line[:1] not in (" ", "\t", ""):
            m = _COMP_HEADER_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def collective_bytes(hlo_text: str, num_devices: int) -> Dict[str, Dict]:
    """Trip-count-aware per-collective-kind accounting (per device).

    XLA's cost_analysis visits while bodies once; real executions run them
    ``known_trip_count`` times (layer scans, microbatch scans, attention tile
    scans).  We walk the computation graph multiplying by trip counts, so the
    reported link bytes are per *executed step*.
    """
    comps = split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break

    def walk(name: str, seen) -> Dict[str, Dict]:
        acc = {k: {"count": 0, "operand_bytes": 0, "link_bytes": 0}
               for k in _COLLECTIVES}
        if name not in comps or name in seen:
            return acc
        seen = seen | {name}
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                trips = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                sub = walk(wm.group(1), seen)
                for k, d in sub.items():
                    acc[k]["count"] += d["count"] * trips
                    acc[k]["operand_bytes"] += d["operand_bytes"] * trips
                    acc[k]["link_bytes"] += d["link_bytes"] * trips
                continue
            cm = _COND_RE.search(line)
            if cm:
                branches = re.findall(r"%([\w.\-]+)", cm.group(1))
                subs = [walk(b, seen) for b in branches]
                if subs:  # worst-case branch
                    best = max(subs, key=lambda s: sum(
                        d["link_bytes"] for d in s.values()))
                    for k, d in best.items():
                        for f in d:
                            acc[k][f] += d[f]
                continue
            callm = _CALL_RE.search(line)
            if callm:
                sub = walk(callm.group(1), seen)
                for k, d in sub.items():
                    for f in d:
                        acc[k][f] += d[f]
                continue
            one = _one_collective(line, num_devices)
            if one:
                op, s, link = one
                acc[op]["count"] += 1
                acc[op]["operand_bytes"] += s
                acc[op]["link_bytes"] += link
        return acc

    if entry is None:
        # fall back to flat counting
        acc = {k: {"count": 0, "operand_bytes": 0, "link_bytes": 0}
               for k in _COLLECTIVES}
        for line in hlo_text.splitlines():
            one = _one_collective(line, num_devices)
            if one:
                op, s, link = one
                acc[op]["count"] += 1
                acc[op]["operand_bytes"] += s
                acc[op]["link_bytes"] += link
        return acc
    return walk(entry, frozenset())


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    link_bytes_per_dev: float
    num_devices: int
    model_flops_total: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_compute_ratio(self) -> float:
        total = self.flops_per_dev * self.num_devices
        return self.model_flops_total / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score: how much
        of peak the step could achieve if it runs at its dominant bound)."""
        if not self.t_bound:
            return 0.0
        achieved = self.model_flops_total / self.num_devices / self.t_bound
        return achieved / PEAK_FLOPS

    def as_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "link_bytes_per_dev": self.link_bytes_per_dev,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_compute_ratio": self.useful_compute_ratio,
            "mfu_bound": self.mfu_bound,
        }


def cost_summary(compiled, num_devices: int) -> Tuple[float, float]:
    """(flops_per_dev, hbm_bytes_per_dev) from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return flops, byts


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes)
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "peak_live_bytes": live,
        "fits_hbm": bool(live <= HBM_BYTES),
    }
