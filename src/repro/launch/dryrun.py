import os
# 512 placeholder devices for the production meshes (dry-run only), plus
# B1 (EXPERIMENTS.md §Perf): keep bf16<->f32 converts where the program put
# them — otherwise XLA's excess-precision elision keeps the whole backward
# in f32 and every TP/FSDP collective moves 2x the bytes.
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_allow_excess_precision=false")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the full-size step program — train_step for train shapes,
prefill/serve steps for inference shapes — is lowered with production
shardings on the 16×16 (single-pod, 256 chips) and 2×16×16 (multi-pod,
512 chips) meshes, compiled by XLA's SPMD partitioner, and analyzed:
memory_analysis (fits-HBM proof), cost_analysis (FLOPs/bytes), and the
optimized HLO's collective traffic (launch/hloanalysis.py).  Results append
incrementally to a JSON so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --datapath ship_compute --out results/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding as shardlib
from repro.configs import ARCH_IDS, get_arch, get_shape
from repro.configs.base import (DPCConfig, MeshConfig, RunConfig, ShapeConfig,
                                ShardingConfig, shape_applicable)
from repro.launch import hloanalysis as hlo
from repro.launch.mesh import make_mesh, mesh_config
from repro.models import registry
from repro.serving import steps as sst
from repro.training import presets
from repro.training import train_step as tst


def cell_id(arch_id: str, shape_name: str, mesh: MeshConfig,
            datapath: str) -> str:
    pod = "multi" if mesh.multi_pod else "single"
    return f"{arch_id}|{shape_name}|{pod}|{datapath}"


def build_run(arch_id: str, shape: ShapeConfig, mesh_cfg: MeshConfig,
              datapath: str) -> RunConfig:
    arch = get_arch(arch_id)
    tk = presets.train_knobs(arch_id)
    sk = presets.serve_knobs(arch_id)
    n_nodes = mesh_cfg.num_chips
    page = sk.page_size
    pages_per_req = (shape.seq_len + page - 1) // page
    if shape.kind == "decode":
        pages_per_req += 2  # slack for generated tokens
    total_pages = shape.global_batch * pages_per_req
    pool_pages = max(4, -(-total_pages // n_nodes) + 2)
    dpc = DPCConfig(
        mode="dpc", datapath=datapath, page_size=page,
        pool_pages_per_shard=pool_pages,
        max_pages_per_seq=pages_per_req, kv_dtype=sk.kv_dtype)
    sharding = ShardingConfig(sequence_parallel=tk.sequence_parallel)
    return RunConfig(arch=arch, shape=shape, mesh=mesh_cfg,
                     sharding=sharding, dpc=dpc)


def model_flops(run: RunConfig) -> float:
    """Analytic MODEL_FLOPS per step: 6·N(_active)·tokens for training,
    2·N·tokens forward-only (+ paged-attention dot FLOPs for decode)."""
    arch = run.arch
    n_active = arch.active_param_count()
    if run.shape.kind == "train":
        tokens = run.shape.global_batch * run.shape.seq_len
        return 6.0 * n_active * tokens
    if run.shape.kind == "prefill":
        tokens = run.shape.global_batch * run.shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per request + attention over the cached context
    b, s = run.shape.global_batch, run.shape.seq_len
    attn = 4.0 * b * s * arch.num_attn_layers * \
        arch.num_heads * arch.resolved_head_dim
    return 2.0 * n_active * b + attn


def lower_cell(run: RunConfig, mesh, datapath: str):
    api = registry.get_model(run.arch)
    arch, shape = run.arch, run.shape
    tk = presets.train_knobs(arch.name)

    if shape.kind == "train":
        return tst.lower_train_step(
            run, api, mesh, n_micro=tk.n_micro,
            accum_dtype=tk.accum_dtype,
            moment_dtype=tk.moment_dtype)

    from repro.models.spec import abstract_params
    params = abstract_params(api.specs(arch))
    pshard = shardlib.specs_to_shardings(api.specs(arch), mesh, run.sharding)
    b = shape.global_batch
    pages_per_req = run.dpc.max_pages_per_seq
    # pools are global views: per-shard pages × number of DPC nodes
    global_pool = run.dpc.pool_pages_per_shard * run.mesh.num_chips
    cache = api.init_cache(arch, run.dpc, b, pages_per_req,
                           pool_pages=global_pool, abstract=True)
    csh = sst.cache_shardings(cache, mesh, run)

    if shape.kind == "prefill":
        step = sst.make_prefill_step(run, api, mesh, datapath=datapath)
        batch = registry.prefill_batch_spec(arch, b, shape.seq_len)
        bsh = sst.token_shardings(run, mesh, batch)
        targets = jax.ShapeDtypeStruct((b, pages_per_req), jnp.int32)
        tsh = sst.token_shardings(run, mesh, targets)
        with shardlib.activation_sharding(mesh, run.sharding):
            jitted = jax.jit(step, in_shardings=(pshard, bsh, csh, tsh),
                             donate_argnums=(2,))
            return jitted.lower(params, batch, cache, targets)

    # decode
    step = sst.make_decode_step(run, api, mesh, datapath=datapath)
    tok = registry.decode_token_spec(arch, b)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    toksh = sst.token_shardings(run, mesh, tok)
    possh = sst.token_shardings(run, mesh, pos)
    with shardlib.activation_sharding(mesh, run.sharding):
        jitted = jax.jit(step, in_shardings=(pshard, toksh, possh, csh),
                         donate_argnums=(3,))
        return jitted.lower(params, tok, pos, cache)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             datapath: str) -> Dict:
    shape = get_shape(shape_name)
    arch = get_arch(arch_id)
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"status": "skipped", "reason": why}
    mesh_cfg = mesh_config(multi_pod=multi_pod)
    run = build_run(arch_id, shape, mesh_cfg, datapath)
    mesh = make_mesh(mesh_cfg)
    n_dev = mesh_cfg.num_chips

    t0 = time.time()
    lowered = lower_cell(run, mesh, datapath)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    from repro.launch import analytic
    flops_raw, bytes_raw = hlo.cost_summary(compiled, n_dev)
    mem = hlo.memory_summary(compiled)
    colls = hlo.collective_bytes(compiled.as_text(), n_dev)
    link_bytes = sum(c["link_bytes"] for c in colls.values())
    tk = presets.train_knobs(arch_id)
    costs = analytic.cell_costs(
        run, n_micro=tk.n_micro,
        accum_bytes=2 if tk.accum_dtype == "bfloat16" else 4,
        moment_bytes=2 if tk.moment_dtype == "bfloat16" else 4,
        kv_dtype_bytes=1 if run.dpc.kv_dtype == "int8" else 2)
    roof = hlo.Roofline(flops_per_dev=costs.flops_total / n_dev,
                        hbm_bytes_per_dev=costs.hbm_bytes_total / n_dev,
                        link_bytes_per_dev=link_bytes, num_devices=n_dev,
                        model_flops_total=costs.model_flops)
    print(compiled.memory_analysis())
    return {
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": mem,
        "collectives": colls,
        "roofline": roof.as_dict(),
        # raw cost_analysis (body-once: scan trip counts NOT multiplied)
        "hlo_body_once": {"flops_per_dev": flops_raw,
                          "bytes_per_dev": bytes_raw},
        "knobs": dataclasses.asdict(presets.train_knobs(arch_id))
        if shape.kind == "train" else
        dataclasses.asdict(presets.serve_knobs(arch_id)),
        "pool_pages_per_shard": run.dpc.pool_pages_per_shard,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--datapath", default="ship_compute",
                    choices=["ship_compute", "ship_data", "local"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all" else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    n_fail = 0
    for arch_id in archs:
        for shape_name in shapes:
            for multi in meshes:
                key = cell_id(arch_id, shape_name,
                              mesh_config(multi_pod=multi), args.datapath)
                if key in results and results[key].get("status") in (
                        "ok", "skipped") and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key}", flush=True)
                try:
                    res = run_cell(arch_id, shape_name, multi, args.datapath)
                except Exception as e:  # noqa
                    res = {"status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                    print(f"  ERROR {e}")
                results[key] = res
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(f"  ok lower={res['lower_s']}s "
                          f"compile={res['compile_s']}s "
                          f"bottleneck={r['bottleneck']} "
                          f"t=({r['t_compute_s']:.2e},"
                          f"{r['t_memory_s']:.2e},"
                          f"{r['t_collective_s']:.2e})s "
                          f"fits={res['memory']['fits_hbm']}", flush=True)
    print(f"done; {n_fail} failures")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
