"""Analytic per-step FLOP / HBM-byte model (the napkin math, made exact).

XLA's cost_analysis counts while bodies once (scan trip counts are not
multiplied — verified empirically), so layer/microbatch/tile scans make its
totals meaningless for a roofline.  This module derives the executed-step
costs from the architecture math instead; the HLO is still the source of
truth for *collectives* (trip-aware walker in hloanalysis.py) and for the
memory fit.

Conventions
  MODEL_FLOPS (reported): 6·N_active·tokens (train) / 2·N_active·tokens
  (forward), the standard MFU numerator.
  flops (executed): adds causal attention (4·Hq·hd·T_ctx/2 per token per
  attention layer), the backward 2x, and the full-remat re-forward.
  HBM bytes: weight traffic (per pass over the stacked params), activation
  stash write+read, KV pool read/write, optimizer state traffic.  Activation
  *intra-layer* traffic is approximated as c_act · tokens · d_model · bytes
  per layer pass (c_act ≈ 12 covers the qkv/mlp intermediate reads+writes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, RunConfig

BF16 = 2
F32 = 4
C_ACT = 12.0   # per-layer activation read+write multiplier (see docstring)


def _attn_flops_per_seq(arch: ArchConfig, seq: int, causal: bool = True
                        ) -> float:
    """Score + AV matmul FLOPs for one sequence through all attn layers."""
    if arch.attention_free:
        return 0.0
    hq, hd = arch.num_heads, arch.resolved_head_dim
    if arch.mla is not None:
        qk = arch.mla.qk_nope_head_dim + arch.mla.qk_rope_head_dim
        v = arch.mla.v_head_dim
        per_pair = 2.0 * hq * (qk + v)
    else:
        per_pair = 4.0 * hq * hd
    pairs = seq * seq / 2 if causal else seq * seq
    extra = 0.0
    if arch.vision is not None:   # cross-attn layers over image tokens
        n_cross = arch.num_layers // arch.vision.cross_attn_every
        extra = n_cross * 4.0 * hq * hd * seq * arch.vision.num_image_tokens
    return per_pair * pairs * arch.num_attn_layers + extra


def _decode_attn_flops(arch: ArchConfig, batch: int, ctx: int) -> float:
    if arch.attention_free:
        return 0.0
    hq, hd = arch.num_heads, arch.resolved_head_dim
    if arch.mla is not None:
        rd = arch.mla.kv_lora_rank + arch.mla.qk_rope_head_dim
        per_tok = 2.0 * hq * (rd + arch.mla.kv_lora_rank)
    else:
        per_tok = 4.0 * hq * hd
    return per_tok * ctx * arch.num_attn_layers * batch


def _ssm_flops_per_token(arch: ArchConfig) -> float:
    """Mamba2/RWKV recurrent state math per token (beyond the projections,
    which are inside active_param_count)."""
    if arch.ssm is None:
        return 0.0
    s = arch.ssm
    if arch.block_kind == "mamba2":
        d_in = s.expand * arch.d_model
        n_mamba = arch.num_layers
        return 6.0 * d_in * s.state_dim * n_mamba
    if arch.block_kind == "rwkv6":
        h = arch.d_model // s.head_dim
        return 6.0 * h * s.state_dim * s.head_dim * arch.num_layers
    return 0.0


def kv_bytes_per_token(arch: ArchConfig, kv_dtype_bytes: int = BF16) -> float:
    return arch.kv_dim_per_token * kv_dtype_bytes * arch.num_attn_layers


@dataclasses.dataclass
class StepCosts:
    model_flops: float        # 6/2 · N_active · tokens
    flops_total: float        # executed (incl. attention, backward, remat)
    hbm_bytes_total: float    # cluster-wide; divide by chips for per-device
    notes: str = ""

    def per_device(self, n_dev: int) -> Dict[str, float]:
        return {"flops_per_dev": self.flops_total / n_dev,
                "hbm_bytes_per_dev": self.hbm_bytes_total / n_dev,
                "model_flops_total": self.model_flops}


def train_costs(run: RunConfig, n_micro: int, accum_bytes: int = F32,
                moment_bytes: int = F32) -> StepCosts:
    arch = run.arch
    tokens = run.shape.global_batch * run.shape.seq_len
    n_active = arch.active_param_count()
    n_total = arch.param_count()
    w_bytes = n_total * BF16

    fwd = 2.0 * n_active * tokens \
        + _attn_flops_per_seq(arch, run.shape.seq_len) * run.shape.global_batch \
        + _ssm_flops_per_token(arch) * tokens
    remat_extra = 1.0 if run.sharding.remat != "none" else 0.0
    flops = fwd * (3.0 + remat_extra)
    model_flops = 6.0 * n_active * tokens

    # weights: fwd + bwd + remat passes (active weights only for MoE)
    w_active_bytes = n_active * BF16
    weight_traffic = (2.0 + remat_extra) * w_active_bytes * n_micro \
        + w_bytes  # optimizer pass reads every param once
    # activations: per layer pass, read+write c_act times
    act_traffic = C_ACT * tokens * arch.d_model * BF16 * arch.num_layers \
        * (2.0 + remat_extra)
    # gradients: accumulate read+write per microbatch + optimizer read
    grad_traffic = n_total * accum_bytes * (2.0 * n_micro + 1)
    # optimizer: read mu,nu,params; write mu,nu,params
    opt_traffic = n_total * (2 * moment_bytes * 2 + 2 * BF16)
    total_bytes = weight_traffic + act_traffic + grad_traffic + opt_traffic
    return StepCosts(model_flops, flops, total_bytes,
                     notes=f"n_micro={n_micro} remat={remat_extra:.0f}")


def prefill_costs(run: RunConfig, kv_dtype_bytes: int = BF16) -> StepCosts:
    arch = run.arch
    tokens = run.shape.global_batch * run.shape.seq_len
    n_active = arch.active_param_count()
    fwd = 2.0 * n_active * tokens \
        + _attn_flops_per_seq(arch, run.shape.seq_len) * run.shape.global_batch \
        + _ssm_flops_per_token(arch) * tokens
    kv_write = kv_bytes_per_token(arch, kv_dtype_bytes) * tokens
    bytes_total = n_active * BF16 \
        + C_ACT * tokens * arch.d_model * BF16 * arch.num_layers \
        + kv_write
    return StepCosts(2.0 * n_active * tokens, fwd, bytes_total)


def decode_costs(run: RunConfig, kv_dtype_bytes: int = BF16) -> StepCosts:
    arch = run.arch
    b, ctx = run.shape.global_batch, run.shape.seq_len
    n_active = arch.active_param_count()
    fwd = 2.0 * n_active * b + _decode_attn_flops(arch, b, ctx) \
        + _ssm_flops_per_token(arch) * b
    # bytes: full weight read (batch amortizes it) + full KV read + states
    kv_read = kv_bytes_per_token(arch, kv_dtype_bytes) * ctx * b
    ssm_state = 0.0
    if arch.ssm is not None and arch.block_kind == "mamba2":
        s = arch.ssm
        d_in = s.expand * arch.d_model
        h = d_in // s.head_dim
        ssm_state = 2.0 * b * h * s.head_dim * s.state_dim * F32 \
            * arch.num_layers
    if arch.ssm is not None and arch.block_kind == "rwkv6":
        s = arch.ssm
        h = arch.d_model // s.head_dim
        ssm_state = 2.0 * b * h * s.state_dim * s.head_dim * F32 \
            * arch.num_layers
    bytes_total = n_active * BF16 + kv_read + ssm_state \
        + C_ACT * b * arch.d_model * BF16 * arch.num_layers
    return StepCosts(2.0 * n_active * b, fwd, bytes_total)


def cell_costs(run: RunConfig, n_micro: int = 1, *,
               accum_bytes: int = F32, moment_bytes: int = F32,
               kv_dtype_bytes: int = BF16) -> StepCosts:
    if run.shape.kind == "train":
        return train_costs(run, n_micro, accum_bytes, moment_bytes)
    if run.shape.kind == "prefill":
        return prefill_costs(run, kv_dtype_bytes)
    return decode_costs(run, kv_dtype_bytes)
