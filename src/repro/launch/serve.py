"""Serving launcher: DPC-cached inference over a replica group.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --requests 24 --share 0.75 --mode dpc

Drives the continuous-batching engine with a synthetic workload whose
requests share prompt prefixes with probability ``--share`` (the paper's
data-sharing regime: hot files read by many nodes).  Prints per-mode
throughput + DPC hit statistics; ``--mode`` selects the paper's
configurations (dpc / dpc_sc / replicated / local_only).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_smoke_arch
from repro.configs.base import (DPCConfig, MeshConfig, RunConfig,
                                ShapeConfig)
from repro.models import registry
from repro.models.spec import init_params
from repro.serving.engine import ServingEngine


def synth_workload(n_requests: int, share: float, prompt_len: int,
                   vocab: int, seed: int = 0):
    """Zipf-ish shared-prefix workload: a few hot prefixes, private tails."""
    rng = np.random.RandomState(seed)
    hot = [rng.randint(0, vocab, prompt_len).tolist() for _ in range(3)]
    out = []
    for i in range(n_requests):
        if rng.rand() < share:
            base = hot[rng.randint(len(hot))]
            tail = rng.randint(0, vocab, max(prompt_len // 8, 1)).tolist()
            out.append(base + tail)
        else:
            out.append(rng.randint(0, vocab,
                                   prompt_len + prompt_len // 8).tolist())
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--share", type=float, default=0.75)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--mode", default="dpc",
                    choices=["dpc", "dpc_sc", "replicated", "local_only"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_smoke_arch(args.arch)
    api = registry.get_model(arch)
    params = init_params(api.specs(arch), jax.random.PRNGKey(args.seed))
    run = RunConfig(
        arch=arch,
        shape=ShapeConfig("serve", args.prompt_len * 2, args.max_batch,
                          "decode"),
        mesh=MeshConfig((1,), ("data",)),
        dpc=DPCConfig(mode=args.mode, page_size=args.page_size,
                      pool_pages_per_shard=512))

    max_pages = (args.prompt_len + args.prompt_len // 8 + args.new_tokens
                 ) // args.page_size + 2
    eng = ServingEngine(run, params, max_batch=args.max_batch,
                        max_pages_per_seq=max_pages)
    prompts = synth_workload(args.requests, args.share, args.prompt_len,
                             arch.vocab_size, args.seed)

    t0 = time.monotonic()
    for p in prompts:
        eng.submit(p, max_new_tokens=args.new_tokens)
    for _ in range(100000):
        if eng.step() == 0:
            break
    dt = time.monotonic() - t0

    total_tokens = args.requests * args.new_tokens
    s = eng.prefix_stats
    print(f"mode={args.mode} requests={args.requests} share={args.share}")
    print(f"  wall={dt:.2f}s decode_tokens={total_tokens} "
          f"tput={total_tokens / dt:.1f} tok/s")
    print(f"  pages: needed={s.pages_needed} local={s.pages_local} "
          f"remote={s.pages_remote} filled={s.pages_filled}")
    print(f"  prefill tokens: saved={s.prefill_tokens_saved} "
          f"run={s.prefill_tokens_run}")
    print(f"  directory hit rate={eng.kv.hit_rate():.3f} "
          f"occupancy={eng.kv.directory_occupancy()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
