"""Training launcher: end-to-end driver with checkpointing + fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container training runs the reduced (smoke) configs on one
device; on a real pod the same driver jits with the production mesh
shardings (--mesh single|multi) — the step function, data pipeline,
checkpoint manager and watchdogs are identical.

Fault-tolerance drill (--kill-at N): simulates a node failure at step N —
the membership epoch bumps, the straggler/liveness machinery runs, and the
driver restarts from the last complete checkpoint, proving the
checkpoint/restart path end-to-end (examples/failover.py scripts it).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, get_smoke_arch
from repro.configs.base import (MeshConfig, RunConfig, ShapeConfig,
                                ShardingConfig)
from repro.data import pipeline as dpipe
from repro.models import registry
from repro.runtime.liveness import Membership, StragglerWatchdog
from repro.training import presets
from repro.training import train_step as tst


def build(args):
    arch = (get_smoke_arch(args.arch) if args.smoke else get_arch(args.arch))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(arch=arch, shape=shape,
                    mesh=MeshConfig((1,), ("data",)),
                    sharding=ShardingConfig(remat=args.remat),
                    learning_rate=args.lr, warmup_steps=args.warmup,
                    checkpoint_every=args.ckpt_every)
    api = registry.get_model(arch)
    return run, api


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--remat", default="full", choices=["none", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=0,
                    help="simulate a node failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    run, api = build(args)
    ocfg = tst.adamw_config(run, total_steps=args.steps)
    step_fn = jax.jit(tst.make_train_step(run, api, n_micro=args.n_micro,
                                          ocfg=ocfg))

    data_cfg = dpipe.for_arch(run.arch, args.seq, args.batch)
    pipe = dpipe.TokenPipeline(data_cfg, rank=0, num_ranks=1)
    ckpt = CheckpointManager(args.ckpt_dir)
    membership = Membership(num_nodes=4,
                            timeout_s=run.heartbeat_interval_s * 3)
    watchdog = StragglerWatchdog()

    state = tst.init_train_state(run, api, jax.random.PRNGKey(args.seed),
                                 ocfg=ocfg)
    start = 0
    restored = ckpt.restore_latest(state)
    if restored is not None:
        state, extra, start = restored
        pipe.load_state_dict(extra["data"])
        print(f"[restore] resumed from step {start}")

    killed = False
    step = start
    while step < args.steps:
        if args.kill_at and step == args.kill_at and not killed:
            killed = True
            print(f"[fault] node 3 dies at step {step}; epoch -> "
                  f"{membership.epoch + 1}")
            membership.evict(3, "fail")
            # restart-from-checkpoint path
            restored = ckpt.restore_latest(state)
            if restored is not None:
                state, extra, step = restored
                pipe.load_state_dict(extra["data"])
                print(f"[fault] restarted from checkpoint at step {step}")
            continue

        batch = jax.tree.map(jnp.asarray, pipe.next_batch())
        if run.arch.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (args.batch, run.arch.vision.num_image_tokens,
                 run.arch.d_model), jnp.dtype(run.arch.activation_dtype))
        if run.arch.family == "audio":
            k = run.arch.audio.num_codebooks
            batch = {"tokens": jnp.broadcast_to(
                batch["tokens"][:, None], (args.batch, k, args.seq)),
                "labels": jnp.broadcast_to(
                batch["labels"][:, None], (args.batch, k, args.seq))}

        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        watchdog.observe(dt)
        for n in membership.alive:
            membership.heartbeat(n)
        membership.check()
        step += 1

        if step % args.log_every == 0 or step == args.steps:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
        if step % args.ckpt_every == 0:
            ckpt.save(step, state, extra={"data": pipe.state_dict()})
    ckpt.wait()
    print(f"[done] {step} steps; checkpoints={ckpt.saves}; "
          f"cache hits local/remote={pipe.cache.hits_local}/"
          f"{pipe.cache.hits_remote} misses={pipe.cache.misses}; "
          f"stragglers flagged={len(watchdog.flagged)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
