"""train_step factory: FSDP/TP/SP-sharded, microbatched, remat'd training.

One jitted step = scan over ``n_micro`` microbatches accumulating gradients
(+ the metrics mean), then AdamW.  Gradients accumulate in ``accum_dtype``
(bf16 for the largest archs — see presets).  Weight FSDP sharding comes from
the param specs + ShardingConfig rules; batch dims are sharded over
(pod, data); activation/stash sharding (incl. sequence parallelism) is
installed at trace time via ``sharding.activation_sharding``.

Optional ``grad_compression="int8"`` applies error-feedback int8 compression
to the accumulated gradients before the optimizer — modelling the
reduce-scatter wire format of the DP reduction (optim/compression.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as shardlib
from repro.configs.base import ArchConfig, RunConfig
from repro.models import registry
from repro.models.spec import abstract_params, init_params
from repro.optim import adamw, compression


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    ef: Optional[Any]           # error-feedback state (grad compression)
    step: jax.Array


def adamw_config(run: RunConfig, total_steps: int = 10000,
                 moment_dtype: str = "float32") -> adamw.AdamWConfig:
    return adamw.AdamWConfig(
        learning_rate=run.learning_rate, warmup_steps=run.warmup_steps,
        total_steps=total_steps, b1=run.adam_b1, b2=run.adam_b2,
        eps=run.adam_eps, weight_decay=run.weight_decay,
        grad_clip=run.grad_clip, moment_dtype=moment_dtype)


def init_train_state(run: RunConfig, api, key, *, ocfg: adamw.AdamWConfig,
                     grad_compression: str = "none") -> TrainState:
    params = init_params(api.specs(run.arch), key)
    ef = compression.init_ef(params) if grad_compression == "int8" else None
    return TrainState(params=params, opt=adamw.init(params, ocfg), ef=ef,
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(run: RunConfig, api, *, ocfg: adamw.AdamWConfig,
                         grad_compression: str = "none") -> TrainState:
    params = abstract_params(api.specs(run.arch))
    ef = (jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
        if grad_compression == "int8" else None)
    return TrainState(params=params, opt=adamw.abstract_state(params, ocfg),
                      ef=ef, step=jax.ShapeDtypeStruct((), jnp.int32))


def state_shardings(run: RunConfig, api, mesh: Mesh,
                    state: TrainState) -> TrainState:
    """NamedSharding tree mirroring a TrainState (params/opt by spec rules)."""
    pshard = shardlib.specs_to_shardings(api.specs(run.arch), mesh,
                                         run.sharding)
    scalar = NamedSharding(mesh, P())
    like = lambda tree: jax.tree.map(lambda s: s, pshard)
    return TrainState(
        params=pshard,
        opt=adamw.AdamWState(step=scalar, mu=like(pshard), nu=like(pshard)),
        ef=None if state.ef is None else like(pshard),
        step=scalar,
    )


def batch_shardings(run: RunConfig, mesh: Mesh, batch_spec) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, shardlib.batch_pspec(mesh, run.sharding, len(s.shape))),
        batch_spec)


def make_train_step(run: RunConfig, api, *, n_micro: int = 1,
                    ocfg: adamw.AdamWConfig,
                    accum_dtype: str = "float32",
                    grad_compression: str = "none"):
    """Returns step(state, batch) -> (state, metrics).  Pure; jit at the
    call site with shardings (launch/train.py, launch/dryrun.py)."""
    arch = run.arch
    remat = run.sharding.remat != "none"

    def loss_fn(params, mb):
        loss, metrics = api.train_loss(params, arch, mb, remat=remat)
        return loss, metrics

    def step(state: TrainState, batch):
        adt = jnp.dtype(accum_dtype)

        def to_micro(x):
            # [B, ...] -> [n_micro, B/n_micro, ...]; keep the microbatch dim
            # sharded over the batch axes
            xm = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
            return shardlib.act(xm, (None, "batch") + (None,) * (x.ndim - 1))

        micro = jax.tree.map(to_micro, batch)

        def mb_step(acc, mb):
            g_acc, loss_acc = acc
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, mb)
            g_acc = jax.tree.map(
                lambda a, gg: a + gg.astype(adt), g_acc, g)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), state.params)
        (g_sum, loss_sum), _ = jax.lax.scan(
            mb_step, (g0, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, g_sum)
        loss = loss_sum / n_micro

        ef = state.ef
        if grad_compression == "int8":
            grads, ef = compression.compress_tree_with_ef(grads, ef)

        new_params, new_opt, opt_metrics = adamw.update(
            grads, state.opt, state.params, ocfg)
        metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, ef, state.step + 1), metrics

    return step


def lower_train_step(run: RunConfig, api, mesh: Mesh, *, n_micro: int = 1,
                     ocfg: Optional[adamw.AdamWConfig] = None,
                     accum_dtype: str = "float32",
                     moment_dtype: str = "float32",
                     grad_compression: str = "none",
                     donate: bool = True):
    """Trace+lower the train step on abstract inputs (dry-run entry point)."""
    ocfg = ocfg or adamw_config(run, moment_dtype=moment_dtype)
    step = make_train_step(run, api, n_micro=n_micro, ocfg=ocfg,
                           accum_dtype=accum_dtype,
                           grad_compression=grad_compression)
    state = abstract_train_state(run, api, ocfg=ocfg,
                                 grad_compression=grad_compression)
    st_sh = state_shardings(run, api, mesh, state)
    batch_spec = registry.train_batch_spec(run.arch, run.shape.global_batch,
                                           run.shape.seq_len)
    b_sh = batch_shardings(run, mesh, batch_spec)

    with shardlib.activation_sharding(mesh, run.sharding):
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, NamedSharding(mesh, P())),
            donate_argnums=(0,) if donate else (),
        )
        lowered = jitted.lower(state, batch_spec)
    return lowered
