"""Per-(arch × shape) tuned execution knobs for the production meshes.

These are the memory-fitting levers a perf engineer would set per model:
microbatch count (remat stash size), gradient-accumulation dtype, optimizer
moment dtype, sequence parallelism, and the KV-pool dtype for decode.  Every
choice is driven by the 16 GiB/chip HBM budget of v5e at 256/512 chips —
derivations in DESIGN.md §5 and EXPERIMENTS.md §Dry-run.

Keyed by arch id; ``None`` entries mean "use the global default".
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig, DPCConfig, RunConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class TrainKnobs:
    n_micro: int = 1                  # grad-accum microbatches per step
    accum_dtype: str = "float32"
    moment_dtype: str = "float32"
    sequence_parallel: bool = False


@dataclasses.dataclass(frozen=True)
class ServeKnobs:
    kv_dtype: str = "bfloat16"
    page_size: int = 64


# --- training knobs (train_4k: global_batch=256, seq=4096, 1M tokens/step) --
# stash/chip ≈ n_layers × (mb·4096/data_shards) × d_model × 2 B / (SP factor)
# opt+params/chip ≈ params × (2 + 2·moment_bytes + accum_bytes) / 256
# n_micro is capped at 8: the multi-pod mesh has 32 (pod, data) shards and
# the 256-seq global batch must keep >= 1 sequence per shard per microbatch.
_TRAIN: dict = {
    # 340B dense, d=18432: bf16 moments + bf16 accum + SP are all required
    "nemotron-4-340b": TrainKnobs(n_micro=8, accum_dtype="bfloat16",
                                  moment_dtype="bfloat16",
                                  sequence_parallel=True),
    # 235B MoE: expert weights dominate; bf16 moments, SP for the 4k stash
    "qwen3-moe-235b-a22b": TrainKnobs(n_micro=8, accum_dtype="bfloat16",
                                      moment_dtype="bfloat16",
                                      sequence_parallel=True),
    # 90B VLM, 100 layers of d=8192 + image tokens
    "llama-3.2-vision-90b": TrainKnobs(n_micro=8, accum_dtype="bfloat16",
                                       moment_dtype="bfloat16",
                                       sequence_parallel=True),
    "minitron-8b": TrainKnobs(n_micro=8, sequence_parallel=True),
    "deepseek-v2-lite-16b": TrainKnobs(n_micro=8),
    "granite-3-2b": TrainKnobs(n_micro=8),
    "qwen3-1.7b": TrainKnobs(n_micro=8),
    "zamba2-1.2b": TrainKnobs(n_micro=8),   # mamba chunk tensors are wide
    "rwkv6-3b": TrainKnobs(n_micro=8),      # O(Q^2 N) intra-chunk tensor
    "musicgen-large": TrainKnobs(n_micro=8),
}

# --- serving knobs -----------------------------------------------------------
# decode_32k KV/chip (bf16, 256 chips) for the two largest KV footprints:
#   nemotron-4-340b: 96L·8H·192D·2·2B ≈ 590 KB/token ≈ 9.7 GB/chip -> OK bf16
#   llama-vision-90b: 80 self-L·8H·128D·2·2B ≈ 328 KB/token ≈ 5.4 GB -> OK
# long_500k (zamba2): 6 invocations × 32H·64D ≈ 8 GB total, B=1 -> trivial.
_SERVE: dict = {
    "deepseek-v2-lite-16b": ServeKnobs(page_size=64),   # MLA latent pages
    "nemotron-4-340b": ServeKnobs(kv_dtype="bfloat16"),
}


def train_knobs(arch_id: str) -> TrainKnobs:
    return _TRAIN.get(arch_id, TrainKnobs())


def serve_knobs(arch_id: str) -> ServeKnobs:
    return _SERVE.get(arch_id, ServeKnobs())


def apply_presets(run: RunConfig) -> RunConfig:
    """Fold per-arch knobs into a RunConfig (sharding + dpc fields)."""
    tk = train_knobs(run.arch.name)
    sk = serve_knobs(run.arch.name)
    sharding = dataclasses.replace(
        run.sharding, sequence_parallel=tk.sequence_parallel)
    dpc = dataclasses.replace(run.dpc, kv_dtype=sk.kv_dtype,
                              page_size=sk.page_size)
    return run.replace(sharding=sharding, dpc=dpc)
