"""Asynchronous batched writeback queue with epoch-ordered flush barriers.

This is the missing half of the paper's reclamation path: "writeback to
storage" as a real pipeline instead of a counter.  Dirty-page flush
*obligations* (page bytes captured at enqueue time) are drained in strict
FIFO order by a background flusher (or by explicit ``pump`` calls in
deterministic/sync mode), ``batch_size`` pages per ``BackingStore.sync`` —
so the durable image is always a prefix of the obligation sequence and a
crash can never surface write N+1 without write N.

Ordering / durability API:

  ``advance_epoch``   stamp a boundary (the engine calls it per step)
  ``flush_barrier``   block until every obligation from epochs <= e (default:
                      everything enqueued so far) is durable
  ``fsync_stream``    block until one stream's obligations are durable — the
                      fsync(fd) analog the serving engine runs on request
                      completion
  ``peek``            latest not-yet-durable bytes for a key (read-your-
                      writes: a refault between enqueue and sync must see
                      the pending copy, not the stale durable one)

Flush-before-free: obligations carry an opaque ``token`` (the protocol
passes ``(node, slot)``); tokens surface on ``drain_completions()`` only
after their batch's sync, and the protocol releases the frame only then.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import CLUSTER, Obs
from repro.runtime.faults import InjectedSyncError
from repro.storage.backing import (BackingStore, FileBackingStore,
                                   MemoryBackingStore)

Key = Tuple[int, int]


@dataclasses.dataclass
class WritebackConfig:
    batch_size: int = 32            # obligations per store.sync
    flush_interval_s: float = 0.002  # async flusher wake period
    max_pending: int = 1 << 16      # backpressure bound
    async_mode: bool = True         # background thread; False = caller pumps
    barrier_timeout_s: float = 30.0


@dataclasses.dataclass
class _Obligation:
    seq: int
    epoch: int
    key: Key
    data: np.ndarray
    token: Optional[Tuple[int, int]]
    t_enqueue: float
    in_flight: bool = False


class WritebackQueue:
    """Batched dirty-page flusher over a ``BackingStore``."""

    def __init__(self, store: BackingStore,
                 cfg: Optional[WritebackConfig] = None,
                 obs: Optional[Obs] = None):
        self.store = store
        self.cfg = cfg or WritebackConfig()
        self.obs = obs if obs is not None else Obs("off")
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # serializes flush batches: the durable image must stay a strict
        # prefix of the seq order even when pump() races the flusher thread
        self._flush_mutex = threading.Lock()
        # insertion order == seq order (only the flusher removes entries)
        self._pending: Dict[int, _Obligation] = {}
        self._latest_by_key: Dict[Key, int] = {}
        self._completed: List[Tuple[Tuple[int, int], Key]] = []
        self._seq = 0
        self._epoch = 0
        self._durable_seq = -1
        self._closed = False
        self._barrier_lat_s: List[float] = []
        self.stats = self.obs.view(
            CLUSTER, "writeback",
            ("enqueued", "coalesced", "flushed_pages", "batches",
             "barriers", "bytes_enqueued", "flush_errors"))
        self._h_flush = self.obs.histogram(CLUSTER, "writeback",
                                           "flush_batch_pages")
        self.faults = None          # FaultPlan (attach_faults)
        self._fault_bypass = 0      # >0: serve the next sync clean
        self._thread: Optional[threading.Thread] = None
        if self.cfg.async_mode:
            self._thread = threading.Thread(
                target=self._flusher, name="dpc-writeback", daemon=True)
            self._thread.start()

    def attach_faults(self, plan) -> None:
        """Thread a :class:`repro.runtime.faults.FaultPlan` through the
        sync path: injected transient sync failures exercise the
        un-mark/re-drive recovery without dropping or reordering
        obligations.  ``None`` detaches."""
        self.faults = plan

    # -- producer side -----------------------------------------------------

    def enqueue(self, key: Key, data: np.ndarray,
                token: Optional[Tuple[int, int]] = None) -> int:
        """Record a flush obligation; ``data`` is captured by copy so the
        source frame may be overwritten (though the protocol keeps it in
        WRITEBACK state until the flush commits anyway).  Returns the seq."""
        data = np.array(data, copy=True)
        with self._cv:
            if self._closed:
                raise RuntimeError("writeback queue is closed")
            while len(self._pending) >= self.cfg.max_pending \
                    and self._thread is not None:
                self._cv.wait(0.01)
            self.stats["enqueued"] += 1
            self.stats["bytes_enqueued"] += int(data.nbytes)
            # coalesce token-less rewrites of a still-queued key: the earlier
            # obligation's slot in the FIFO absorbs the newer bytes (per-key
            # ordering is preserved — there is only one pending copy)
            prev = self._latest_by_key.get(key)
            if prev is not None and token is None:
                ob = self._pending.get(prev)
                if ob is not None and not ob.in_flight and ob.token is None:
                    ob.data = data
                    self.stats["coalesced"] += 1
                    return ob.seq
            seq = self._seq
            self._seq += 1
            self._pending[seq] = _Obligation(
                seq=seq, epoch=self._epoch, key=key, data=data, token=token,
                t_enqueue=time.perf_counter())
            self._latest_by_key[key] = seq
            if len(self._pending) >= self.cfg.batch_size:
                self._cv.notify_all()
            return seq

    def advance_epoch(self) -> int:
        """Stamp an ordering boundary; later enqueues belong to the new
        epoch.  ``flush_barrier(upto_epoch=e)`` orders against these."""
        with self._lock:
            self._epoch += 1
            return self._epoch

    def kick(self) -> int:
        """Wake the async flusher immediately instead of letting pending
        obligations sit out the remaining flush interval — the engine kicks
        right after dispatching a decode step so flushes overlap the device
        compute.  Sync mode is unaffected (the caller pumps).  Returns the
        pending count at kick time."""
        with self._cv:
            n = len(self._pending)
            if n and self._thread is not None:
                self._cv.notify_all()
            return n

    # -- read-your-writes --------------------------------------------------

    def peek(self, key: Key) -> Optional[np.ndarray]:
        """Latest pending (not yet durable) bytes for ``key``, else None."""
        with self._lock:
            seq = self._latest_by_key.get(key)
            if seq is None:
                return None
            ob = self._pending.get(seq)
            return None if ob is None else np.array(ob.data, copy=True)

    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    def has_pending_stream(self, stream: int) -> bool:
        with self._lock:
            return any(ob.key[0] == stream for ob in self._pending.values())

    # -- flush side --------------------------------------------------------

    def _take_batch(self) -> List[_Obligation]:
        with self._lock:
            batch = []
            for ob in self._pending.values():      # FIFO: insertion order
                if len(batch) >= self.cfg.batch_size:
                    break
                if ob.in_flight:
                    continue
                ob.in_flight = True
                batch.append(ob)
            return batch

    def _flush_once(self) -> int:
        with self._flush_mutex:
            batch = self._take_batch()
            if not batch:
                return 0
            try:
                for ob in batch:
                    self.store.write(ob.key[0], ob.key[1], ob.data)
                if self.faults is not None and not self._fault_bypass \
                        and self.faults.sync_fails():
                    raise InjectedSyncError(
                        "fault-injected transient sync failure")
                self.store.sync()                  # the durability point
            except Exception:
                # a failed sync must not wedge the pipeline: un-mark the
                # batch so the next flush re-drives it (obligations and
                # their frame pins are still intact)
                with self._cv:
                    for ob in batch:
                        ob.in_flight = False
                    self.stats["flush_errors"] += 1
                    self._cv.notify_all()
                raise
            with self._cv:
                for ob in batch:
                    del self._pending[ob.seq]
                    if self._latest_by_key.get(ob.key) == ob.seq:
                        del self._latest_by_key[ob.key]
                    if ob.token is not None:
                        self._completed.append((ob.token, ob.key))
                self._durable_seq = max(self._durable_seq, batch[-1].seq)
                self.stats["flushed_pages"] += len(batch)
                self.stats["batches"] += 1
                if self._h_flush is not None:
                    self._h_flush.observe(len(batch))
                self._cv.notify_all()
            return len(batch)

    def _flusher(self) -> None:
        while True:
            with self._cv:
                if self._closed and not self._pending:
                    return
                if len(self._pending) < self.cfg.batch_size \
                        and not self._closed:
                    self._cv.wait(self.cfg.flush_interval_s)
                if not self._pending:
                    if self._closed:
                        return
                    continue
            try:
                self._flush_once_retrying()
            except Exception:
                # transient store failure (disk full, ...): the thread
                # survives and retries the re-driven batch after a beat
                time.sleep(self.cfg.flush_interval_s or 0.01)

    def _flush_once_retrying(self) -> int:
        """:meth:`_flush_once` with bounded retry of *injected* sync
        failures (the batch survives each attempt un-marked and intact,
        so re-driving preserves FIFO order and flush-before-free).  Real
        store errors still propagate to the caller."""
        attempts = 0
        while True:
            try:
                return self._flush_once()
            except InjectedSyncError:
                attempts += 1
                limit = (self.faults.cfg.max_retries
                         if self.faults is not None else 0)
                # the injected fault is *transient* by contract: past the
                # retry budget the next attempt is served clean
                if attempts > limit:
                    self._fault_bypass += 1
                    try:
                        return self._flush_once()
                    finally:
                        self._fault_bypass -= 1

    def pump(self, max_batches: Optional[int] = None) -> int:
        """Drain synchronously on the caller's thread (sync mode, tests,
        and the engine's step-boundary pump).  Returns pages flushed."""
        flushed = 0
        while max_batches is None or max_batches > 0:
            n = self._flush_once_retrying()
            if n == 0:
                break
            flushed += n
            if max_batches is not None:
                max_batches -= 1
        return flushed

    # -- barriers ----------------------------------------------------------

    def _barrier_done(self, upto_epoch: Optional[int],
                      stream: Optional[int]) -> bool:
        if stream is not None:
            return not any(ob.key[0] == stream
                           for ob in self._pending.values())
        if upto_epoch is None:
            return not self._pending
        return all(ob.epoch > upto_epoch for ob in self._pending.values())

    def _wait(self, upto_epoch: Optional[int], stream: Optional[int],
              timeout: Optional[float]) -> float:
        t0 = time.perf_counter()
        deadline = t0 + (timeout if timeout is not None
                         else self.cfg.barrier_timeout_s)
        while True:
            with self._cv:
                if self._barrier_done(upto_epoch, stream):
                    break
                if self._thread is not None:
                    # expedite: wake the flusher now instead of letting the
                    # obligations sit out the remaining flush interval
                    self._cv.notify_all()
                    if not self._cv.wait(min(0.05, self.cfg.flush_interval_s
                                             or 0.05)):
                        pass
                    if time.perf_counter() > deadline:
                        raise TimeoutError(
                            f"flush barrier: {len(self._pending)} obligations"
                            " still pending")
                    continue
            # sync mode: the barrier itself pumps the queue dry
            if self._flush_once_retrying() == 0 \
                    and time.perf_counter() > deadline:
                raise TimeoutError("flush barrier stalled in sync mode")
        lat = time.perf_counter() - t0
        self.stats["barriers"] += 1
        self._barrier_lat_s.append(lat)
        return lat

    def flush_barrier(self, upto_epoch: Optional[int] = None,
                      timeout: Optional[float] = None) -> float:
        """Block until every obligation from epochs <= ``upto_epoch``
        (default: everything enqueued so far) is durable.  Returns the
        barrier latency in seconds."""
        return self._wait(upto_epoch, None, timeout)

    def fsync_stream(self, stream: int,
                     timeout: Optional[float] = None) -> float:
        """Block until all of ``stream``'s enqueued obligations are durable
        (the per-file fsync analog)."""
        return self._wait(None, stream, timeout)

    # -- completions / teardown -------------------------------------------

    def drain_completions(self) -> List[Tuple[Tuple[int, int], Key]]:
        """Tokens of obligations whose flush committed since the last call
        — the protocol releases exactly these frames (flush-before-free)."""
        with self._lock:
            out, self._completed = self._completed, []
            return out

    def close(self, drain: bool = True) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=self.cfg.barrier_timeout_s)
            self._thread = None
        if drain:
            self.pump()

    # -- metrics -----------------------------------------------------------

    def write_amplification(self) -> float:
        """Durable bytes written per logical dirty byte flushed (extent
        rewrites and failed coalescing push this above 1.0)."""
        logical = self.stats["bytes_enqueued"]
        return self.store.stats["bytes_written"] / max(logical, 1)

    def barrier_latencies_s(self) -> List[float]:
        return list(self._barrier_lat_s)

    def barrier_p99_s(self) -> float:
        if not self._barrier_lat_s:
            return 0.0
        return float(np.percentile(np.asarray(self._barrier_lat_s), 99))


def make_storage(backend: str, *, root: str = "", extent_pages: int = 8,
                 batch_size: int = 32, flush_interval_s: float = 0.002,
                 async_mode: bool = True, obs: Optional[Obs] = None
                 ) -> Tuple[Optional[BackingStore],
                            Optional[WritebackQueue]]:
    """Config-driven factory: build the (store, queue) pair for a DPCConfig.

    ``backend``: "none" (disabled) | "memory" | "file".
    """
    if backend in ("", "none"):
        return None, None
    if backend == "memory":
        store: BackingStore = MemoryBackingStore()
    elif backend == "file":
        store = FileBackingStore(root or None, extent_pages=extent_pages)
    else:
        raise ValueError(f"unknown storage backend {backend!r}")
    queue = WritebackQueue(store, WritebackConfig(
        batch_size=batch_size, flush_interval_s=flush_interval_s,
        async_mode=async_mode), obs=obs)
    return store, queue
