"""Backing-store implementations: the durable tier below the page cache.

A ``BackingStore`` is page-granular: keys are ``(stream, page)`` like every
other layer of the protocol, values are numpy arrays of any shape/dtype (KV
page bytes, token shards, ...).  Durability is explicit: ``write`` stages a
page, ``sync`` is the durability point (everything staged before it survives
``crash()``).  The ``WritebackQueue`` flushes obligations in FIFO order and
calls ``sync`` once per batch, so the durable image is always a prefix of the
write sequence — the crash-consistency ordering DAXFS-style filesystems make
the hard part of shared storage.

``FileBackingStore`` groups pages into fixed-size *extents*, one ``.npz``
file per extent (data + presence mask), written via tmp-file + fsync +
atomic rename.  A one-page flush rewrites its whole extent — that is the
write amplification ``benchmarks/writeback.py`` measures, and why batching
adjacent dirty pages into one sync matters.
"""

from __future__ import annotations

import os
import shutil
import tempfile
from typing import Dict, Optional, Set, Tuple

import numpy as np

Key = Tuple[int, int]  # (stream, page) — same key space as the directory


class BackingStore:
    """Interface + shared accounting for the durable page tier."""

    def __init__(self):
        self.stats = {
            "pages_written": 0, "pages_read": 0, "read_misses": 0,
            "bytes_staged": 0, "bytes_written": 0, "bytes_read": 0,
            "syncs": 0,
        }

    # -- required ---------------------------------------------------------

    def write(self, stream: int, page: int, data: np.ndarray) -> None:
        """Stage one page (durable only after the next ``sync``)."""
        raise NotImplementedError

    def read(self, stream: int, page: int) -> Optional[np.ndarray]:
        """Latest staged-or-durable copy, or None if never written."""
        raise NotImplementedError

    def sync(self) -> None:
        """Durability point: everything staged so far survives a crash."""
        raise NotImplementedError

    # -- optional ---------------------------------------------------------

    def contains(self, stream: int, page: int) -> bool:
        return self.read(stream, page) is not None

    def delete(self, stream: int, page: int) -> None:
        raise NotImplementedError

    def crash(self) -> None:
        """Simulate power loss: drop every write staged since the last sync
        (test hook; the file store reloads from disk on next read)."""
        raise NotImplementedError

    def close(self) -> None:
        """Release resources (the file store removes a self-created root)."""

    # -- accounting -------------------------------------------------------

    def _note_write(self, data: np.ndarray) -> None:
        self.stats["pages_written"] += 1
        self.stats["bytes_staged"] += int(data.nbytes)

    def _note_read(self, data: Optional[np.ndarray]) -> None:
        if data is None:
            self.stats["read_misses"] += 1
        else:
            self.stats["pages_read"] += 1
            self.stats["bytes_read"] += int(data.nbytes)


class MemoryBackingStore(BackingStore):
    """Staged/durable dict pair — the fast tier-0 store and the crash-
    consistency test double (``crash`` drops the staged dict)."""

    def __init__(self):
        super().__init__()
        self._staged: Dict[Key, np.ndarray] = {}
        self._durable: Dict[Key, np.ndarray] = {}

    def write(self, stream: int, page: int, data: np.ndarray) -> None:
        data = np.array(data, copy=True)
        self._staged[(stream, page)] = data
        self._note_write(data)

    def read(self, stream: int, page: int) -> Optional[np.ndarray]:
        key = (stream, page)
        data = self._staged.get(key)
        if data is None:
            data = self._durable.get(key)
        self._note_read(data)
        return None if data is None else np.array(data, copy=True)

    def sync(self) -> None:
        for data in self._staged.values():
            self.stats["bytes_written"] += int(data.nbytes)
        self._durable.update(self._staged)
        self._staged.clear()
        self.stats["syncs"] += 1

    def delete(self, stream: int, page: int) -> None:
        self._staged.pop((stream, page), None)
        self._durable.pop((stream, page), None)

    def crash(self) -> None:
        self._staged.clear()

    def __len__(self) -> int:
        return len(self._durable | self._staged)


class _Extent:
    """In-memory working copy of one extent file (data + presence mask)."""

    __slots__ = ("data", "mask")

    def __init__(self, data: np.ndarray, mask: np.ndarray):
        self.data = data
        self.mask = mask


class FileBackingStore(BackingStore):
    """npy-per-extent file store with atomic, fsync'd extent rewrites.

    Pages are grouped ``extent_pages`` to a file; the first write to an
    extent fixes its page shape/dtype.  ``sync`` rewrites every dirty extent
    (tmp file -> fsync -> rename), so bytes_written / bytes_staged exposes
    the extent-granularity write amplification.
    """

    def __init__(self, root: Optional[str] = None, extent_pages: int = 8):
        super().__init__()
        self._owns_root = not root
        self.root = root or tempfile.mkdtemp(prefix="dpc_store_")
        os.makedirs(self.root, exist_ok=True)
        self.extent_pages = int(extent_pages)
        self._extents: Dict[Key, _Extent] = {}     # (stream, extent_id) ->
        self._dirty: Set[Key] = set()
        # extents known absent on disk: first-touch fills probe the store on
        # every miss, so the common never-written case must not pay a
        # stat() syscall per page (single-writer assumption)
        self._absent: Set[Key] = set()

    # -- extent plumbing --------------------------------------------------

    def _path(self, stream: int, eid: int) -> str:
        return os.path.join(self.root, f"s{stream & 0xFFFFFFFF:08x}_e{eid}.npz")

    def _load(self, stream: int, eid: int,
              template: Optional[np.ndarray] = None) -> Optional[_Extent]:
        ext = self._extents.get((stream, eid))
        if ext is not None:
            return ext
        if (stream, eid) in self._absent and template is None:
            return None
        path = self._path(stream, eid)
        if os.path.exists(path):
            with np.load(path) as z:
                ext = _Extent(z["data"].copy(), z["mask"].copy())
        elif template is not None:
            ext = _Extent(
                np.zeros((self.extent_pages,) + template.shape,
                         template.dtype),
                np.zeros((self.extent_pages,), bool))
        else:
            self._absent.add((stream, eid))
            return None
        self._absent.discard((stream, eid))
        self._extents[(stream, eid)] = ext
        return ext

    # -- BackingStore -----------------------------------------------------

    def write(self, stream: int, page: int, data: np.ndarray) -> None:
        data = np.asarray(data)
        eid, off = page // self.extent_pages, page % self.extent_pages
        ext = self._load(stream, eid, template=data)
        if ext.data.shape[1:] != data.shape or ext.data.dtype != data.dtype:
            raise ValueError(
                f"extent ({stream},{eid}) holds {ext.data.dtype}"
                f"{ext.data.shape[1:]} pages, got {data.dtype}{data.shape}")
        ext.data[off] = data
        ext.mask[off] = True
        self._dirty.add((stream, eid))
        self._note_write(data)

    def read(self, stream: int, page: int) -> Optional[np.ndarray]:
        eid, off = page // self.extent_pages, page % self.extent_pages
        ext = self._load(stream, eid)
        data = None
        if ext is not None and ext.mask[off]:
            data = np.array(ext.data[off], copy=True)
        self._note_read(data)
        return data

    def sync(self) -> None:
        for stream, eid in sorted(self._dirty):
            ext = self._extents[(stream, eid)]
            path = self._path(stream, eid)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    np.savez(f, data=ext.data, mask=ext.mask)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
            self.stats["bytes_written"] += int(ext.data.nbytes
                                               + ext.mask.nbytes)
        self._dirty.clear()
        self.stats["syncs"] += 1

    def delete(self, stream: int, page: int) -> None:
        eid, off = page // self.extent_pages, page % self.extent_pages
        ext = self._load(stream, eid)
        if ext is not None:
            ext.mask[off] = False
            self._dirty.add((stream, eid))

    def crash(self) -> None:
        # staged state is exactly the dirty working copies: drop them and the
        # next read reloads whatever the last atomic rename published
        for key in self._dirty:
            self._extents.pop(key, None)
        self._dirty.clear()

    def extent_files(self) -> int:
        return sum(1 for n in os.listdir(self.root) if n.endswith(".npz"))

    def close(self) -> None:
        """Drop working copies; a self-created temp root is removed so
        benchmark/test runs do not leak extent files into /tmp."""
        self._extents.clear()
        self._dirty.clear()
        if self._owns_root:
            shutil.rmtree(self.root, ignore_errors=True)
