"""Durable backing-store + async writeback subsystem.

The storage tier DPC's single-copy invariant was always implicitly leaning
on: an evicted dirty page has *no* other DRAM replica, so reclamation and
migration must end in a real "writeback to storage" before the frame is
reusable.  This package provides

  ``BackingStore``        the storage-tier interface (page-granular put/get
                          with an explicit ``sync`` durability point)
  ``MemoryBackingStore``  staged/durable dict pair; ``crash()`` drops the
                          staged writes — the crash-consistency test double
  ``FileBackingStore``    npy-per-extent files with atomic replace + fsync
  ``WritebackQueue``      batched asynchronous dirty-page flusher with
                          epoch-ordered flush barriers and per-stream fsync

The page tier (``core/protocol.py``) and the host tier
(``data/pipeline.ShardStore``) both speak ``BackingStore``.
"""

from repro.storage.backing import (BackingStore, FileBackingStore,
                                   MemoryBackingStore)
from repro.storage.writeback import (WritebackConfig, WritebackQueue,
                                     make_storage)

__all__ = [
    "BackingStore", "MemoryBackingStore", "FileBackingStore",
    "WritebackConfig", "WritebackQueue", "make_storage",
]
