"""Continuous-batching serving engine with the DPC page cache.

The engine is the "kernel" of the paper's client: it admits requests, asks
the DistributedKVCache (directory) for each prefix page, builds device page
tables, runs prefill for missing spans (the "storage fetch"), commits the
installed pages (E -> O), and drives decode steps — reclaiming pages through
the deterministic invalidation protocol when pools run low.

Replica model: each DPC node is one serving replica (a model slice); the
engine process drives all replicas SPMD-style, mirroring how one virtiofsd
serves all clients in the paper's testbed.  The decode *data plane* is the
jitted step (local or DPC datapaths from serving/steps.py); the engine is
pure host control plane.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import DPCConfig, RunConfig
from repro.core import descriptors as D
from repro.core.dpc_cache import DistributedKVCache, PageLookup
from repro.models import registry
from repro.models.cache import MLAPagedCache
from repro.obs import trace as T
from repro.serving import prefix_index, steps
from repro.serving.prefix_index import PrefixStats


@dataclasses.dataclass
class Request:
    rid: int
    tokens: List[int]                 # prompt
    max_new_tokens: int = 16
    node: int = 0                     # home replica
    # runtime state
    generated: List[int] = dataclasses.field(default_factory=list)
    page_ids: List[int] = dataclasses.field(default_factory=list)
    page_keys: List = dataclasses.field(default_factory=list)
    # predictive prefetch: tree-matched keys promoted for this request
    # while it sat queued, tagged with the issuing membership generation
    # (a drain/fail bump drops them as stale at admit, like any prefetch)
    predicted: List = dataclasses.field(default_factory=list)
    predicted_gen: int = -1
    done: bool = False
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    """Single-replica-group engine (CPU smoke scale; the distributed data
    plane is exercised by the dry-run and spmd tests)."""

    def __init__(self, run: RunConfig, params, *, max_batch: int = 8,
                 max_pages_per_seq: int = 64, node: int = 0,
                 num_nodes: int = 1, kv_cache: Optional[DistributedKVCache] = None):
        self.run = run
        self.arch = run.arch
        self.api = registry.get_model(self.arch)
        self.params = params
        self.node = node
        self.max_batch = max_batch
        self.max_pages = max_pages_per_seq
        self.kv = kv_cache or DistributedKVCache(run.dpc, num_nodes)
        self.obs = self.kv.obs
        self.trace = self.obs.tracer
        self.prefix_stats = PrefixStats()

        self.queue: deque = deque()
        self.active: List[Optional[Request]] = [None] * max_batch
        self._next_rid = 0

        self.cache = self.api.init_cache(
            self.arch, run.dpc, max_batch, max_pages_per_seq,
            pool_pages=run.dpc.pool_pages_per_shard)
        self._decode = jax.jit(steps.make_decode_step(run, self.api))
        self._prefill = jax.jit(steps.make_prefill_step(run, self.api))

        self._pt = np.full((max_batch, max_pages_per_seq), -1, np.int32)
        self._sl = np.zeros((max_batch,), np.int32)
        # -1 = no append target: inactive slots never write KV (backends
        # drop negative append slots)
        self._ap = np.full((max_batch,), -1, np.int32)
        self._step_count = 0

        # async data plane (DPCConfig.async_data_plane): while decode step N
        # computes on device, the host allocates the page each request will
        # need at its next boundary.  A prefetched page installs behind a
        # generation check — drain/fail bump _gen and any issued-but-
        # uninstalled prefetch is dropped as stale (the directory re-lookup
        # in _alloc_page is idempotent, so dropping leaks nothing).
        self._gen = 0
        self._prefetch: Dict[int, tuple] = {}  # slot -> (gen, rid, idx, pid)
        # per-node registry rows (fold at rejoin, like every node counter)
        self._obs_stats = self.obs.view(
            node, "engine", ("prefetch_hits", "prefetch_stale", "steps"))

        # storage tier: evicted dirty KV pages flush through the writeback
        # queue; this engine's pools are the byte source (and refill sink)
        if self.kv.writeback is not None:
            self.kv.set_page_bytes_fn(self._fetch_page_bytes)

    # ------------------------------------------------------------------

    @property
    def prefetch_hits(self) -> int:
        return self._obs_stats["prefetch_hits"]

    @property
    def prefetch_stale(self) -> int:
        return self._obs_stats["prefetch_stale"]

    def stats(self) -> dict:
        """Cluster-wide snapshot (counters, per-node rows, histograms,
        gauges) plus this engine's prefix-reuse tallies."""
        snap = self.obs.snapshot()
        snap["prefix"] = self.prefix_stats.as_dict()
        return snap

    def submit(self, tokens: Sequence[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, tokens=list(tokens),
                                  max_new_tokens=max_new_tokens,
                                  node=self.node, t_admit=time.monotonic()))
        return rid

    def _alloc_page(self, key) -> int:
        """Grab one page id via the directory (reclaim + retry on pressure)."""
        for _ in range(3):
            lk = self.kv.lookup([key[0]], [key[1]], self.node)[0]
            if lk.page_id >= 0:
                if lk.needs_fill:
                    # the caller decodes fresh KV into this frame without
                    # installing any store bytes — strip a (stale) refill so
                    # the commit stays dirty and eviction writes it back
                    self.kv.commit([key[0]], [key[1]], self.node,
                                   [dataclasses.replace(lk, refill=None)])
                return lk.page_id
            if lk.status in (D.ST_FULL,):
                self.kv.reclaim(self.node, self.kv.dpc.inv_batch_threshold)
                continue
            if lk.status == D.ST_BLOCKED:
                continue
        return -1

    def _page_keys(self, tokens: Sequence[int]) -> List:
        """Directory keys for a prompt.  The cluster tree shares one key
        space (salt 0); the per-node-index ablation salts with the node id
        so no request ever resolves to another node's prefill."""
        salt = 0 if self.kv.dpc.prefix_cluster else self.node + 1
        return prefix_index.page_keys(tokens, self.run.dpc.page_size,
                                      modality_salt=salt)

    def _admit(self, slot: int, req: Request) -> None:
        page = self.run.dpc.page_size
        keys = req.page_keys or self._page_keys(req.tokens)
        req.page_keys = keys
        lookups = self.kv.lookup([k[0] for k in keys], [k[1] for k in keys],
                                 self.node)
        self.prefix_stats.pages_needed += len(keys)

        # reconcile the queued-time prediction: a promoted page that is
        # still resident at admit is a predict hit (the lookup above was a
        # TLB hit for it — zero directory ops); one evicted/moved since is
        # a miss; a generation bump since issue drops the whole prediction
        # as stale, exactly like a boundary prefetch
        if req.predicted:
            if req.predicted_gen != self._gen:
                self.prefix_stats.predict_stale += len(req.predicted)
            else:
                by_idx = {k[1]: k for k in keys}
                for pk in req.predicted:
                    lk = (lookups[pk[1]] if pk[1] < len(lookups)
                          and by_idx.get(pk[1]) == pk else None)
                    if lk is not None and lk.page_id >= 0 \
                            and not lk.needs_fill:
                        self.prefix_stats.predict_hits += 1
                    else:
                        self.prefix_stats.predict_misses += 1
            req.predicted = []

        # storage refill: an evicted full page whose bytes survive in the
        # backing store (or the still-pending writeback queue) is installed
        # directly — the refault path skips prefill recompute.  Only the
        # contiguous leading prefix is refilled: a refilled page must land
        # inside the reuse prefix below, or the page-table assembly would
        # alloc a private duplicate and double-commit its key.
        for i, lk in enumerate(lookups[:len(req.tokens) // page]):
            if not lk.needs_fill and lk.page_id >= 0:
                continue   # already present: the prefix keeps extending
            if lk.needs_fill and lk.refill is not None and lk.page_id >= 0 \
                    and self._install_page_bytes(lk.page_id, lk.refill):
                self.kv.commit([keys[i][0]], [keys[i][1]], self.node, [lk])
                lookups[i] = dataclasses.replace(lk, needs_fill=False)
                self.prefix_stats.pages_refilled += 1
            else:
                break      # gap: later refills would leave the prefix

        # longest prefix of already-present pages (full pages only)
        n_full = len(req.tokens) // page
        reuse = 0
        for i, lk in enumerate(lookups[:n_full]):
            if lk.page_id >= 0 and not lk.needs_fill:
                reuse = i + 1
                self.prefix_stats.pages_remote += int(lk.remote)
                self.prefix_stats.pages_local += int(not lk.remote)
            else:
                break
        self.prefix_stats.prefill_tokens_saved += reuse * page
        self.prefix_stats.prefill_tokens_run += len(req.tokens) - reuse * page

        # page table: reused pages + to-fill pages (tail pages are private)
        req.page_ids = []
        n_pages = len(keys)
        pool_pages = self.kv.dpc.pool_pages_per_shard
        for i, (key, lk) in enumerate(zip(keys, lookups)):
            if i < reuse:
                req.page_ids.append(lk.page_id)
            else:
                pid = (lk.page_id if lk.page_id >= 0 and lk.needs_fill
                       else self._alloc_page((key[0] ^ 0x5A5A5A ^ req.rid,
                                              key[1])))
                req.page_ids.append(pid)
                self.prefix_stats.pages_filled += 1
        self._pt[slot, :] = -1
        self._pt[slot, :n_pages] = req.page_ids
        self.active[slot] = req

        if 0 < reuse == n_full:
            # cached-prefix admission: every full page reused — skip prefill
            # entirely and DECODE the short tail over the cached pages
            self.kv.prefix_insert(keys[:n_full], self.node)
            self._sl[slot] = reuse * page
            self._ap[slot] = (req.page_ids[reuse] % pool_pages
                              if reuse < n_pages else -1)
            self._sync_cache_tables()
            for t in req.tokens[reuse * page:]:
                self._decode_one(slot, int(t))
            return

        # whole-span prefill (first sight of this prefix)
        targets = np.full((self.max_batch, n_pages), -1, np.int32)
        for i in range(reuse, n_pages):
            if req.page_ids[i] >= 0:
                targets[slot, i] = req.page_ids[i] % pool_pages
        batch_tokens = np.zeros((self.max_batch, len(req.tokens)), np.int32)
        batch_tokens[slot] = req.tokens
        batch = {"tokens": jnp.asarray(batch_tokens)}
        if self.arch.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (self.max_batch, self.arch.vision.num_image_tokens,
                 self.arch.d_model), jnp.dtype(self.arch.activation_dtype))
        if self.arch.family == "audio":
            k = self.arch.audio.num_codebooks
            bt = np.zeros((self.max_batch, k, len(req.tokens)), np.int32)
            bt[slot, :] = np.asarray(req.tokens)[None, :]
            batch = {"tokens": jnp.asarray(bt)}
        _, self.cache = self._prefill(self.params, batch, self.cache,
                                      jnp.asarray(targets))
        # commit newly filled pages
        fill_rows = [i for i in range(reuse, n_pages)
                     if req.page_ids[i] >= 0]
        if fill_rows:
            self.kv.commit([keys[i][0] for i in fill_rows],
                           [keys[i][1] for i in fill_rows], self.node,
                           [PageLookup(0, req.page_ids[i], self.node, True,
                                       False) for i in fill_rows])

        # advertise the published path in the cluster prefix tree: only the
        # contiguous run of full pages committed under their true keys —
        # a page granted under a private (salted) key is not shareable and
        # must not be predicted for anyone else
        pub = 0
        for i in range(n_full):
            if i < reuse or (lookups[i].needs_fill
                             and lookups[i].page_id >= 0):
                pub += 1
            else:
                break
        if pub:
            self.kv.prefix_insert(keys[:pub], self.node)

        self._sl[slot] = len(req.tokens)
        self._ap[slot] = (req.page_ids[-1] % pool_pages if req.page_ids
                          else 0)
        self._sync_cache_tables()

    def _decode_one(self, slot: int, token: int) -> np.ndarray:
        """Push one (prompt-tail) token through the decode path for a single
        slot, handling page-boundary allocation.  Returns last logits row."""
        page = self.run.dpc.page_size
        pool_pages = self.kv.dpc.pool_pages_per_shard
        total = self._sl[slot]
        if total % page == 0:
            idx = total // page
            if idx < self.max_pages and self._pt[slot, idx] < 0:
                req = self.active[slot]
                pid = self._alloc_page((0x7E57 ^ req.rid, int(idx)))
                if pid >= 0:
                    self._pt[slot, idx] = pid
            if idx < self.max_pages and self._pt[slot, idx] >= 0:
                self._ap[slot] = self._pt[slot, idx] % pool_pages
        # mask every OTHER slot's append: only this slot writes real KV
        ap_saved = self._ap.copy()
        mask = np.full_like(self._ap, -1)
        mask[slot] = self._ap[slot]
        self._ap = mask
        self._sync_cache_tables()
        self._ap = ap_saved
        tokens = np.zeros((self.max_batch,), np.int32)
        tokens[slot] = token
        tok = jnp.asarray(tokens)
        if self.arch.family == "audio":
            tok = jnp.broadcast_to(tok[:, None],
                                   (self.max_batch,
                                    self.arch.audio.num_codebooks))
        logits, self.cache = self._decode(self.params, tok,
                                          jnp.asarray(self._sl), self.cache)
        pc = steps.paged_part(self.cache)
        if pc is not None:
            sl = np.asarray(pc.seq_lens).copy()
            # only this slot's position advances; others were padding
            self._sl[slot] = sl[slot]
            self._sync_seq_lens()
        else:
            self._sl[slot] += 1
        return np.asarray(logits)[slot]

    def _sync_seq_lens(self):
        pc = steps.paged_part(self.cache)
        if pc is not None:
            self.cache = steps.replace_paged(
                self.cache, pc._replace(seq_lens=jnp.asarray(self._sl)))

    def _sync_cache_tables(self):
        pc = steps.paged_part(self.cache)
        if pc is None:
            return
        pc = pc._replace(page_table=jnp.asarray(self._pt),
                         seq_lens=jnp.asarray(self._sl),
                         append_slot=jnp.asarray(self._ap))
        self.cache = steps.replace_paged(self.cache, pc)

    # ------------------------------------------------------------------

    def step(self) -> int:
        """One engine iteration: admit -> decode -> harvest.  Returns number
        of active requests.

        Async data plane: the decode step is dispatched, not awaited — the
        host spends the device time issuing next-boundary page prefetches,
        flushing buffered TLB touches / dirty marks, and pumping the
        writeback queue, then blocks only when it samples the tokens."""
        async_dp = self.kv.dpc.async_data_plane
        if async_dp:
            # settle lane-carried COPY/FLUSH obligations (end-of-last-step
            # migrations, deferred writeback captures) before page tables
            # are read or rewritten
            self.kv.settle_data_plane()
        for slot in range(self.max_batch):
            if self.active[slot] is None and self.queue:
                self._admit(slot, self.queue.popleft())

        live = [r for r in self.active if r is not None]
        if not live:
            return 0
        step_id = self._step_count
        if self.trace is not None:
            self.trace.emit(T.EV_STEP_BEGIN, self.node, step_id, len(live))

        # page-boundary allocation for requests whose filling page is full;
        # under the async data plane the page was usually allocated during
        # the previous step's overlap window (generation-checked install)
        page = self.run.dpc.page_size
        pool_pages = self.kv.dpc.pool_pages_per_shard
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            total = self._sl[slot]
            if total % page == 0:
                idx = total // page
                if idx < self.max_pages and self._pt[slot, idx] < 0:
                    pid = self._take_prefetch(slot, int(idx), req)
                    if pid < 0:
                        pid = self._alloc_page((0x7E57 ^ req.rid, int(idx)))
                    if pid >= 0:
                        self._pt[slot, idx] = pid
                        self._ap[slot] = pid % pool_pages
                elif idx < self.max_pages:
                    self._ap[slot] = self._pt[slot, idx] % pool_pages
        self._sync_cache_tables()

        tokens = np.zeros((self.max_batch,), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            last = (req.generated[-1] if req.generated
                    else req.tokens[-1])
            tokens[slot] = last
        tok = jnp.asarray(tokens)
        if self.arch.family == "audio":
            tok = jnp.broadcast_to(tok[:, None],
                                   (self.max_batch,
                                    self.arch.audio.num_codebooks))
        positions = jnp.asarray(self._sl)

        if async_dp:
            inflight = steps.InFlightDecode(
                *self._decode(self.params, tok, positions, self.cache))
            self.cache = inflight.cache
            # ---- overlap window: device decodes while the host works ----
            with steps.OverlapWindow(self.trace, self.node, step_id) as ow:
                ow.note(self._issue_predictions())
                self._issue_prefetch()
                self.kv.flush_tlb_touches()
                self.kv.flush_dirty_marks()
                if self.kv.writeback is not None:
                    self.kv.advance_epoch()
                    self.kv.pump_storage()
                    self.kv.writeback.kick()
            nxt = inflight.sample()  # sync point: ends the overlap window
        else:
            logits, self.cache = self._decode(self.params, tok, positions,
                                              self.cache)
            nxt = np.asarray(registry.greedy_sample(logits))
            # sync reference mode issues the same predictions at the same
            # step boundary, just serialized after the decode — the async
            # ≡ sync equivalence property covers the promoted state too
            self._issue_predictions()

        pc = steps.paged_part(self.cache)
        if pc is not None:
            self._sl = np.asarray(pc.seq_lens).copy()
        else:
            self._sl = self._sl + 1

        now = time.monotonic()
        n_active = 0
        completed: List[Request] = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            t = nxt[slot] if nxt.ndim == 1 else nxt[slot, 0]
            if not req.generated:
                req.t_first = now
            req.generated.append(int(t))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                req.t_done = now
                completed.append(req)
                self.active[slot] = None
                self._prefetch.pop(slot, None)  # unused, not a race: drop
                self._sl[slot] = 0
                self._pt[slot, :] = -1
                self._ap[slot] = -1
                self._sync_cache_tables()
            else:
                n_active += 1

        # TLB-hit CLOCK touches buffered during this step's lookups land in
        # one batched device call — the hit path itself stayed device-free.
        # Write-grant dirty bits ride the same boundary: one batched
        # mark_dirty per node instead of one per written page.  Under the
        # async data plane both flushes (and the epoch stamp + pump) already
        # happened inside the overlap window above.
        if not async_dp:
            self.kv.flush_tlb_touches()
            self.kv.flush_dirty_marks()

        # durability rides the step boundary: stamp an epoch, pump the
        # queue (sync mode flushes one batch; async harvests completions),
        # and fsync each completed request's streams — its pages are
        # guaranteed refillable once the response is surfaced
        if self.kv.writeback is not None:
            if not async_dp:
                self.kv.advance_epoch()
                self.kv.pump_storage()
            for req in completed:
                for stream in {k[0] for k in req.page_keys}:
                    self.kv.fsync_stream(stream)

        # ownership migration rides the step boundary — batched, never inside
        # the per-token decode (the paper's "off the critical path" batching)
        self._step_count += 1
        self._obs_stats["steps"] += 1
        dpc = self.run.dpc
        if dpc.migration_enabled and \
                self._step_count % dpc.migrate_interval_steps == 0:
            self._run_migrations()
        if self.trace is not None:
            self.trace.emit(T.EV_STEP_END, self.node, step_id, n_active)
        return n_active + len(self.queue)

    # -- predictive prefetch (cluster prefix tree) -----------------------------

    def _issue_predictions(self, budget: int = 16) -> int:
        """Overlap-window work: match queued prompts against the cluster
        prefix tree and batch-promote the matched pages before admission
        needs them.  ``promote_predicted`` skips pages this node's TLB
        already holds, so in steady state only the *tail* of the matched
        path pays a directory op — and a promoted page's later real lookup
        is a pure TLB hit.  Predictions carry the membership generation;
        drain/fail bumps drop them at admit like any stale prefetch.
        Returns promotion batches issued."""
        if self.kv.prefix_tree is None:
            return 0
        page = self.run.dpc.page_size
        issued = 0
        for req in self.queue:
            if issued >= budget:
                break
            if req.predicted_gen >= 0:
                continue   # one prediction per queued request
            req.predicted_gen = self._gen
            keys = req.page_keys or self._page_keys(req.tokens)
            req.page_keys = keys
            matched = self.kv.prefix_match(keys[:len(req.tokens) // page],
                                           self.node)
            if not matched:
                continue
            promoted, _ = self.kv.promote_predicted(matched, self.node)
            if promoted:
                req.predicted = promoted
                self.prefix_stats.pages_predicted += len(promoted)
                issued += 1
        return issued

    # -- async data plane: next-boundary page prefetch -------------------------

    def _take_prefetch(self, slot: int, idx: int, req: Request) -> int:
        """Consume the prefetched page id for (slot, idx) if one was issued
        during the previous step's overlap window and is still valid: same
        membership generation, same request, same page index.  A stale entry
        is counted and dropped — the directory re-lookup in _alloc_page is
        idempotent, so dropping never leaks the frame."""
        ent = self._prefetch.pop(slot, None)
        if ent is None:
            return -1
        gen, rid, p_idx, pid = ent
        if gen == self._gen and rid == req.rid and p_idx == idx and pid >= 0:
            self._obs_stats["prefetch_hits"] += 1
            return pid
        self._obs_stats["prefetch_stale"] += 1
        return -1

    def _issue_prefetch(self) -> None:
        """Overlap-window work: allocate the page each live request will need
        at its NEXT boundary so step N+1's table build is a dictionary hit.
        Runs while the dispatched decode computes; uses only pre-step host
        state (``self._sl`` has not been advanced yet)."""
        page = self.run.dpc.page_size
        for slot, req in enumerate(self.active):
            if req is None or slot in self._prefetch:
                continue
            if len(req.generated) + 1 >= req.max_new_tokens:
                continue  # request completes this step: no next boundary
            total = int(self._sl[slot]) + 1  # position after this step
            if total % page != 0:
                continue
            idx = total // page
            if idx >= self.max_pages or self._pt[slot, idx] >= 0:
                continue
            pid = self._alloc_page((0x7E57 ^ req.rid, int(idx)))
            if pid >= 0:
                self._prefetch[slot] = (self._gen, req.rid, idx, pid)

    # -- ownership migration (core/migration.py) ------------------------------

    def _run_migrations(self) -> int:
        """Drain the hotness ledger: migrate hot pages toward their traffic,
        copy the KV rows, and rewrite every table that named the old frame."""
        moved = self.kv.run_migrations(copy_fn=self._copy_page)
        self._apply_remap(moved)
        return len(moved)

    def _apply_remap(self, moved) -> None:
        """Rewrite every table naming a moved frame: page hand-offs (migrate
        or drain) return [(key, old_pfn, new_pfn)]."""
        if not moved:
            return
        remap = {old: new for _, old, new in moved}
        for old, new in remap.items():
            self._pt[self._pt == old] = new
        for req in self.active:
            if req is not None:
                req.page_ids = [remap.get(p, p) for p in req.page_ids]
        # issued-but-uninstalled prefetches name frames too
        self._prefetch = {s: (g, r, i, remap.get(p, p))
                          for s, (g, r, i, p) in self._prefetch.items()}
        self._sync_cache_tables()

    # -- elastic membership ----------------------------------------------------

    def drain_node(self, node: int, alive=None):
        """Planned node departure: evacuate its pages (KV rows move with
        them) and rewrite the page tables for the new homes."""
        self._gen += 1  # issued prefetches may name the departing node
        st = self.kv.drain_node(node, alive=alive, copy_fn=self._copy_page)
        self._apply_remap(st.get("moved", []))
        if self.kv.dpc.async_data_plane:
            # tail evacuation chunk's COPY lanes: settle before any caller
            # reads the rewritten tables' bytes
            self.kv.settle_data_plane()
        return st

    def _rehome_install(self, key, pfn: int, data) -> bool:
        """Failover refill sink: land durable bytes in the survivor's pool."""
        return self._install_page_bytes(pfn, np.asarray(data))

    def fail_node(self, node: int, rehome_to=None) -> int:
        """Heartbeat-loss failover; with ``rehome_to``, orphans refill from
        the durable tier into the survivor's pool."""
        self._gen += 1  # drop issued-but-uninstalled prefetches as stale
        return self.kv.fail_node(node, rehome_to=rehome_to,
                                 install_fn=self._rehome_install)

    # -- storage tier (repro/storage) -----------------------------------------

    def _fetch_page_bytes(self, key, pfn: int):
        """Writeback byte source: one page's KV rows as float32 (bf16-exact;
        npy extents want a builtin dtype).  None when there is no paged
        cache to read from."""
        pc = steps.paged_part(self.cache)
        if pc is None:
            return None
        slot = pfn % self.kv.dpc.pool_pages_per_shard
        if isinstance(pc, MLAPagedCache):
            return np.asarray(pc.latent_pools[:, slot]).astype(np.float32)
        return np.stack([np.asarray(pc.k_pools[:, slot]),
                         np.asarray(pc.v_pools[:, slot])]).astype(np.float32)

    def _install_page_bytes(self, pid: int, data: np.ndarray) -> bool:
        """Refill sink: scatter store bytes back into the paged pools.
        Returns False on shape mismatch (caller falls back to prefill)."""
        pc = steps.paged_part(self.cache)
        if pc is None:
            return False
        slot = pid % self.kv.dpc.pool_pages_per_shard
        if isinstance(pc, MLAPagedCache):
            if data.shape != pc.latent_pools[:, slot].shape:
                return False
            pc = pc._replace(latent_pools=pc.latent_pools.at[:, slot].set(
                jnp.asarray(data, pc.latent_pools.dtype)))
        else:
            if data.shape != (2,) + pc.k_pools[:, slot].shape:
                return False
            pc = pc._replace(
                k_pools=pc.k_pools.at[:, slot].set(
                    jnp.asarray(data[0], pc.k_pools.dtype)),
                v_pools=pc.v_pools.at[:, slot].set(
                    jnp.asarray(data[1], pc.v_pools.dtype)))
        self.cache = steps.replace_paged(self.cache, pc)
        return True

    def _copy_page(self, key, src_pfn: int, dst_pfn: int) -> None:
        """Data-plane hook for migrate_finish: move one page's KV rows.

        At smoke scale the engine holds one pool array indexed by local slot
        (global ids alias mod P); the distributed datapaths do this copy as a
        ship_data fetch instead."""
        pc = steps.paged_part(self.cache)
        if pc is None:
            return
        P = self.kv.dpc.pool_pages_per_shard
        src, dst = src_pfn % P, dst_pfn % P
        if src == dst:
            return
        if isinstance(pc, MLAPagedCache):
            pc = pc._replace(latent_pools=pc.latent_pools.at[:, dst]
                             .set(pc.latent_pools[:, src]))
        else:
            pc = pc._replace(
                k_pools=pc.k_pools.at[:, dst].set(pc.k_pools[:, src]),
                v_pools=pc.v_pools.at[:, dst].set(pc.v_pools[:, src]))
        self.cache = steps.replace_paged(self.cache, pc)

    def run_to_completion(self, max_steps: int = 10000) -> List[Request]:
        finished: List[Request] = []
        seen = set()
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return finished
