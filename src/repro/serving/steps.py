"""Jitted serving steps: prefill (with page install) + decode, local or DPC.

``datapath``:
  local         single-shard pools, LocalBackend (smoke tests, 1 replica)
  ship_compute  DPC default (queries to owners, LSE combine)
  ship_data     paper-faithful page fetch (remote_read.py)

The cache sharding scheme (DESIGN.md §5): pool slot dims over every DPC axis,
page tables / seq_lens / append slots over the batch axes, SSM states over
batch, cross-attn KV over batch.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as shardlib
from repro.configs.base import RunConfig
from repro.core.remote_read import ShipDataBackend
from repro.core.ship_compute import DPCBackend
from repro.models import registry
from repro.models.cache import (DPCPageWriter, HybridCache, LocalPageWriter,
                                MLAPagedCache, PagedKVCache, RWKVCache,
                                VLMCache)
from repro.obs import trace as T


def paged_part(cache):
    if isinstance(cache, (PagedKVCache, MLAPagedCache)):
        return cache
    if isinstance(cache, HybridCache):
        return cache.attn
    if isinstance(cache, VLMCache):
        return cache.self_attn
    return None


def replace_paged(cache, pc):
    if isinstance(cache, (PagedKVCache, MLAPagedCache)):
        return pc
    if isinstance(cache, HybridCache):
        return cache._replace(attn=pc)
    if isinstance(cache, VLMCache):
        return cache._replace(self_attn=pc)
    return cache


def pick_batch_axes(mesh: Mesh, batch: int):
    """Largest prefix of (pod, data) whose product divides the batch (a
    global_batch of 1 — long_500k — replicates requests; the pool still
    shards over every chip)."""
    axes = []
    prod = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names and batch % (prod * mesh.shape[ax]) == 0:
            axes.append(ax)
            prod *= mesh.shape[ax]
    return tuple(axes)


def _backend_for(run: RunConfig, mesh: Optional[Mesh], datapath: str, pc):
    if pc is None or datapath == "local" or mesh is None:
        return None  # models fall back to LocalBackend
    kw = dict(
        batch_axes=pick_batch_axes(mesh, pc.page_table.shape[0]),
        head_axis="model",
        pool_pages=run.dpc.pool_pages_per_shard,
    )
    if datapath == "ship_compute":
        return DPCBackend(mesh, pc.page_table, pc.seq_lens, pc.append_slot,
                          **kw)
    if datapath == "ship_data":
        return ShipDataBackend(mesh, pc.page_table, pc.seq_lens,
                               pc.append_slot, **kw)
    raise ValueError(datapath)


def make_decode_step(run: RunConfig, api, mesh: Optional[Mesh] = None,
                     datapath: str = "local"):
    arch = run.arch

    def decode(params, tokens, positions, cache):
        backend = _backend_for(run, mesh, datapath, paged_part(cache))
        return api.decode_step(params, arch, tokens, positions, cache,
                               backend)

    return decode


class InFlightDecode:
    """Handle for a dispatched decode step (the async data plane's
    double-buffer point).

    jax dispatch is asynchronous: the jitted step returns lazy device
    arrays immediately.  The engine wraps them here, overlaps host-side
    directory work — next-step page prefetch, predictive prefix-tree
    promotion, dirty-mark flushes, the writeback pump — with the device
    compute, and only blocks when it calls ``sample()`` for the tokens it
    actually needs."""

    def __init__(self, logits, cache):
        self._logits = logits
        self.cache = cache

    def sample(self) -> np.ndarray:
        """Greedy-sample the dispatched logits; materializing the result is
        the synchronization point that ends the overlap window."""
        return np.asarray(registry.greedy_sample(self._logits))


class OverlapWindow:
    """Trace-bracketed host-work window while a dispatched decode computes.

    Everything the engine runs between decode dispatch and ``sample()``
    belongs in one of these: the tracer sees a single EV_OVERLAP span per
    step (the audit pairs them), and the window object counts the work
    batches issued inside it so benchmarks can report how full the bubble
    actually is.  Usable as a no-op when tracing is off."""

    def __init__(self, trace, node: int, step_id: int):
        self.trace = trace
        self.node = node
        self.step_id = step_id
        self.issued = 0          # host-work batches issued in the window

    def note(self, n: int = 1) -> None:
        self.issued += n

    def __enter__(self) -> "OverlapWindow":
        if self.trace is not None:
            self.trace.emit(T.EV_OVERLAP_BEGIN, self.node, self.step_id)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self.trace is not None:
            self.trace.emit(T.EV_OVERLAP_END, self.node, self.step_id)


def make_prefill_step(run: RunConfig, api, mesh: Optional[Mesh] = None,
                      datapath: str = "local"):
    """prefill(params, batch, cache, targets) -> (logits, cache').

    ``targets``: [B, n_prefill_pages] page ids (global under DPC, local slot
    ids otherwise) granted by the directory for the install.
    """
    arch = run.arch
    page = run.dpc.page_size

    def prefill(params, batch, cache, targets):
        pc = paged_part(cache)
        if pc is None:  # rwkv: state prefill, no pages
            out = api.prefill(params, arch, batch, remat=False)
            return out[0], cache
        pools = (pc.latent_pools if isinstance(pc, MLAPagedCache)
                 else (pc.k_pools, pc.v_pools))
        if datapath == "local" or mesh is None:
            writer = LocalPageWriter(targets, page)
        else:
            writer = DPCPageWriter(
                mesh, targets, page, run.dpc.pool_pages_per_shard,
                batch_axes=pick_batch_axes(mesh, targets.shape[0]))
        out = api.prefill(params, arch, batch, remat=False, pools=pools,
                          writer=writer)
        logits, new_pools = out[0], out[1]
        if isinstance(pc, MLAPagedCache):
            pc = pc._replace(latent_pools=new_pools)
        else:
            pc = pc._replace(k_pools=new_pools[0], v_pools=new_pools[1])
        seq = batch["tokens"].shape[-1]
        pc = pc._replace(seq_lens=jnp.full_like(pc.seq_lens, seq))
        cache = replace_paged(cache, pc)
        # family extras: hybrid ssm state / vlm cross kv
        if isinstance(cache, HybridCache):
            conv, ssd = out[2]
            cache = cache._replace(ssm=cache.ssm._replace(conv=conv,
                                                          state=ssd))
        if isinstance(cache, VLMCache):
            ck, cv = out[2]
            cache = cache._replace(cross_k=ck.astype(cache.cross_k.dtype),
                                   cross_v=cv.astype(cache.cross_v.dtype))
        return logits, cache

    return prefill


# ---------------------------------------------------------------------------
# shardings for the serving state (dry-run + real launch)
# ---------------------------------------------------------------------------


def cache_shardings(cache, mesh: Mesh, run: RunConfig):
    """NamedSharding tree for a decode cache on the production mesh."""
    pc = paged_part(cache)
    batch = (pc.seq_lens.shape[0] if pc is not None
             else jax.tree.leaves(cache)[0].shape[1])
    batch_axes = pick_batch_axes(mesh, batch)
    dpc_axes = tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    bp = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    dp = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    def paged(pc):
        common = dict(page_table=ns(bp, None), seq_lens=ns(bp),
                      append_slot=ns(bp))
        if isinstance(pc, MLAPagedCache):
            return MLAPagedCache(latent_pools=ns(None, dp, None, None),
                                 **common)
        return PagedKVCache(k_pools=ns(None, dp, None, None, None),
                            v_pools=ns(None, dp, None, None, None), **common)

    if isinstance(cache, (PagedKVCache, MLAPagedCache)):
        return paged(cache)
    if isinstance(cache, RWKVCache):
        return RWKVCache(tm_shift=ns(None, bp, None),
                         cm_shift=ns(None, bp, None),
                         wkv=ns(None, bp, None, None, None))
    if isinstance(cache, HybridCache):
        from repro.models.cache import SSMCache
        return HybridCache(
            ssm=SSMCache(conv=ns(None, bp, None, None),
                         state=ns(None, bp, None, None, None)),
            attn=paged(cache.attn))
    if isinstance(cache, VLMCache):
        return VLMCache(self_attn=paged(cache.self_attn),
                        cross_k=ns(None, bp, None, None, None),
                        cross_v=ns(None, bp, None, None, None))
    raise TypeError(type(cache))


def token_shardings(run: RunConfig, mesh: Mesh, spec):
    def one(s):
        batch_axes = pick_batch_axes(mesh, s.shape[0])
        bp = batch_axes if len(batch_axes) > 1 else (
            batch_axes[0] if batch_axes else None)
        return NamedSharding(mesh, P(bp, *([None] * (len(s.shape) - 1))))
    return jax.tree.map(one, spec)
