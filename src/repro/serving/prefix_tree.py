"""Cluster-visible prefix tree over content-addressed KV page keys.

``prefix_index.page_keys`` gives every full prompt page a cluster-unique
identity ``(chain_hash, page_idx)`` — the chain hash covers every token up
to the page's end, so a page key *is* its whole prefix.  That makes the
radix structure degenerate in the nicest possible way: each tree node is
one page key, a node's children are the observed one-page extensions of
its prefix, and a root-to-node path is exactly the key sequence a request
with that prompt would look up.

The tree is the cluster's **prediction** metadata (the directory remains
the source of truth for residency): nodes are partitioned by the same
``dir_shard_of`` placement as their directory entries, so the structure
lives with the sharded directory — any serving node's commit inserts into
the shard that owns the page, and any other node's match reads it there.
Per-edge state is a refcount (paths through the edge) plus a decaying
per-node hotness, which feeds the migration ledger when a match turns
into a prediction (prediction-sourced promotion credit).

Privacy caveat (mirrors ``page_keys``): only **full** pages enter the
tree.  A partial trailing page's hash covers a token count nobody else
can match page-for-page, so it stays private to its request and is never
inserted, matched, or predicted.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

Key = Tuple[int, int]  # (chain_hash, page_idx) — the directory page key


class TreeNode:
    """One full prompt page; identity is its directory key."""

    __slots__ = ("key", "parent", "children", "refs", "hot")

    def __init__(self, key: Key, parent: Optional["TreeNode"]):
        self.key = key
        self.parent = parent
        # child chain-hash -> node (page_idx is implied: depth + 1)
        self.children: Dict[int, "TreeNode"] = {}
        self.refs = 0                       # paths inserted through this edge
        self.hot: Dict[int, int] = {}       # node id -> decaying access count

    def hottest(self) -> Tuple[int, int]:
        if not self.hot:
            return -1, 0
        n = max(self.hot, key=lambda k: (self.hot[k], -k))
        return n, self.hot[n]


class ClusterPrefixTree:
    """Radix/chain tree of committed prompt prefixes, sharded like the
    directory.

    ``shard_of(stream, page) -> shard`` is the directory's placement
    function; nodes are bucketed per shard purely so the metadata lives
    (and is accounted) where its directory entry lives — matching walks
    parent->child links and never scans a shard.
    """

    def __init__(self, capacity: int = 4096,
                 shard_of: Optional[Callable[[int, int], int]] = None):
        self.capacity = max(capacity, 1)
        self.shard_of = shard_of or (lambda s, p: 0)
        self.roots: Dict[int, TreeNode] = {}    # first-page hash -> node
        # shard id -> {key -> node}: the "directory entry" view of the tree
        self.shards: Dict[int, Dict[Key, TreeNode]] = {}
        self.size = 0
        self.inserts = 0
        self.evicted = 0

    # -- growth -------------------------------------------------------------

    def insert(self, keys: Sequence[Key], node_id: int) -> int:
        """Record a committed prompt path (full-page keys only, in page
        order starting at page 0).  Returns nodes created."""
        created = 0
        parent: Optional[TreeNode] = None
        for depth, key in enumerate(keys):
            if key[1] != depth:
                break  # not a root-anchored path: refuse quietly
            table = self.roots if parent is None else parent.children
            tn = table.get(key[0])
            if tn is None:
                tn = TreeNode(key, parent)
                table[key[0]] = tn
                self.shards.setdefault(
                    self.shard_of(key[0], key[1]), {})[key] = tn
                self.size += 1
                created += 1
            tn.refs += 1
            tn.hot[node_id] = tn.hot.get(node_id, 0) + 1
            parent = tn
        self.inserts += 1
        if self.size > self.capacity:
            self._prune()
        return created

    # -- lookup -------------------------------------------------------------

    def match(self, keys: Sequence[Key], node_id: int = -1,
              weight: int = 1) -> List[Key]:
        """Longest root-anchored path matching ``keys``; returns the matched
        keys (every one is a page some request already committed somewhere
        in the cluster).  ``node_id >= 0`` heats the matched edges — the
        refcounted hotness that later feeds the migration ledger."""
        out: List[Key] = []
        parent: Optional[TreeNode] = None
        for depth, key in enumerate(keys):
            if key[1] != depth:
                break
            table = self.roots if parent is None else parent.children
            tn = table.get(key[0])
            if tn is None or tn.key != key:
                break
            out.append(key)
            if node_id >= 0:
                tn.hot[node_id] = tn.hot.get(node_id, 0) + weight
            parent = tn
        return out

    def predicted_tail(self, keys: Sequence[Key]) -> List[Key]:
        """Matched keys beyond the first page — the pages a request walking
        this path will need *after* admission starts (the prefetch set)."""
        return self.match(keys)[1:]

    # -- maintenance --------------------------------------------------------

    def decay(self) -> None:
        """Halve every edge's per-node heat (migration-round cadence)."""
        for table in self.shards.values():
            for tn in table.values():
                tn.hot = {n: c >> 1 for n, c in tn.hot.items() if c >> 1 > 0}

    def _prune(self) -> None:
        """Drop the coldest leaf until back under capacity.  One at a time:
        removing a leaf can expose its (colder) parent as the next victim,
        so the leaf set is re-ranked after every drop — a bulk cut from one
        snapshot could evict a hot path's tail instead."""
        while self.size > self.capacity:
            leaves = [tn for table in self.shards.values()
                      for tn in table.values() if not tn.children]
            if not leaves:
                return
            self._drop(min(leaves,
                           key=lambda tn: (sum(tn.hot.values()), tn.refs,
                                           tn.key)))

    def _drop(self, tn: TreeNode) -> None:
        table = tn.parent.children if tn.parent is not None else self.roots
        if table.get(tn.key[0]) is tn:
            del table[tn.key[0]]
        shard = self.shards.get(self.shard_of(tn.key[0], tn.key[1]), {})
        if shard.get(tn.key) is tn:
            del shard[tn.key]
        self.size -= 1
        self.evicted += 1

    def stats(self) -> dict:
        return {"nodes": self.size, "inserts": self.inserts,
                "evicted": self.evicted,
                "shards": {s: len(t) for s, t in self.shards.items() if t}}
