"""Content-addressed prefix index: token prefixes -> DPC page keys.

DPC keys file pages by (inode, offset); the serving analog keys KV pages by
(chain hash of the token prefix up to the page's end, page index), so two
requests sharing a prompt prefix — on *any* replica — resolve to the same
directory entries.  This is the "hot file shared by many nodes" case.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

FNV_PRIME = 0x01000193
FNV_BASIS = 0x811C9DC5
MASK = 0x7FFFFFFF


def page_keys(tokens: Sequence[int], page_size: int,
              modality_salt: int = 0) -> List[Tuple[int, int]]:
    """Rolling chain hash per page: key_p = (H(tokens[:(p+1)*page]), p).

    Partial trailing pages get keys too (they are only *shareable* once
    full; the engine treats partial-page keys as private).
    """
    keys = []
    h = (FNV_BASIS ^ (modality_salt & 0xFFFF)) & MASK
    n = len(tokens)
    n_pages = (n + page_size - 1) // page_size
    for p in range(n_pages):
        end = min((p + 1) * page_size, n)
        for t in tokens[p * page_size:end]:
            h = ((h ^ (int(t) & 0xFFFFFF)) * FNV_PRIME) & MASK
        keys.append((h or 1, p))
    return keys


def shared_page_count(a: Sequence[int], b: Sequence[int],
                      page_size: int) -> int:
    """How many leading *full* pages two token streams share."""
    ka = page_keys(a, page_size)
    kb = page_keys(b, page_size)
    n = 0
    for (ha, _), (hb, _) in zip(ka, kb):
        if ha != hb:
            break
        n += 1
    # a trailing partial page never counts as shared
    full_a = len(a) // page_size
    full_b = len(b) // page_size
    return min(n, full_a, full_b)


class PrefixStats:
    """Aggregate hit accounting for the engine."""

    def __init__(self):
        self.pages_needed = 0
        self.pages_local = 0
        self.pages_remote = 0
        self.pages_filled = 0
        self.pages_refilled = 0   # evicted pages restored from the store
        self.prefill_tokens_saved = 0
        self.prefill_tokens_run = 0
        # predictive prefetch (cluster prefix tree): pages promoted off a
        # tree match during the overlap window, and how the prediction aged
        self.pages_predicted = 0      # matched-tail pages promoted
        self.predict_hits = 0         # predicted pages admit then reused
        self.predict_misses = 0       # predicted but gone by admit time
        self.predict_stale = 0        # dropped by a generation bump

    @property
    def predict_hit_rate(self) -> float:
        issued = self.predict_hits + self.predict_misses
        return self.predict_hits / issued if issued else 0.0

    def as_dict(self):
        d = dict(vars(self))
        d["predict_hit_rate"] = self.predict_hit_rate
        return d
