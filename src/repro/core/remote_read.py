"""ship_data datapath — the paper-faithful CXL remote read, ported to TPU.

Under CXL a remote cache hit pulls the page *bytes* to the consumer.  The TPU
rendering: each data-row fetches every page its requests reference — striped
across its model columns so each page crosses the fabric once per consuming
row — via a fixed-capacity all_to_all exchange with the owning nodes
(request ids out, page payloads back; the two virtqueue directions of
FUSE_DPC_READ).  Attention then runs locally over the staged pages, with an
LSE combine across the row's stripe columns.

Collective bytes scale with context KV per step — this is the baseline the
beyond-paper ship_compute datapath (queries out, O(q+o) bytes) is measured
against in EXPERIMENTS.md §Perf.

Capacity note: per-(requester, owner) queue capacity is static (like MoE
expert capacity).  Pages are hash-striped across owners, so a 4x-expected
capacity overflows with negligible probability; overflowed fetches are
dropped from attention and counted (`overflow`), never silently wrong about
which tokens were attended (the mask excludes them).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.ship_compute import lse_combine_allreduce, _my_node
from repro.kernels import dispatch


def fetch_capacity(n_fetch: int, n_nodes: int, safety: int = 4) -> int:
    expected = (n_fetch + n_nodes - 1) // n_nodes
    return max(8, safety * expected)


def build_fetch_plan(wanted: jax.Array, n_nodes: int, pool_pages: int,
                     cap: int):
    """wanted: [F] global page ids (-1 = skip).

    Returns (req [n_nodes, cap] local slot ids for each owner (-1 pad),
             owner_of [F], pos_of [F] (position in that owner's queue, -1 if
             dropped), overflow count)."""
    f = wanted.shape[0]
    valid = wanted >= 0
    owner = jnp.where(valid, wanted // pool_pages, n_nodes)
    slot = jnp.where(valid, wanted % pool_pages, 0)

    onehot = jax.nn.one_hot(owner, n_nodes + 1, dtype=jnp.int32)   # [F, O+1]
    pos_mat = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_mat, owner[:, None], 1)[:, 0]    # [F]
    keep = valid & (pos < cap)
    overflow = jnp.sum(valid & ~keep)

    req = jnp.full((n_nodes + 1, cap), -1, jnp.int32)
    req = req.at[jnp.where(keep, owner, n_nodes),
                 jnp.where(keep, pos, 0)].set(jnp.where(keep, slot, -1))
    pos_of = jnp.where(keep, pos, -1)
    return req[:n_nodes], jnp.minimum(owner, n_nodes - 1), pos_of, overflow


def _a2a(x: jax.Array, axes) -> jax.Array:
    """all_to_all over (possibly multiple) mesh axes on dim 0.

    x: [n_nodes, ...] with n_nodes = prod(axis sizes), row-major over
    ``axes`` (matching ``_my_node``); returns the transposed exchange
    (row r of the result came from node r)."""
    sizes = [jax.lax.psum(1, a) for a in axes]
    lead = x.shape[0]
    x = x.reshape(tuple(sizes) + x.shape[1:])
    for i, ax in enumerate(axes):
        # dim i (target index along ax) is exchanged; afterwards dim i is the
        # sender index along ax
        x = jax.lax.all_to_all(x, ax, split_axis=i, concat_axis=i,
                               tiled=False)
    return x.reshape((lead,) + x.shape[len(sizes):])


def make_shipdata_attend(mesh: Mesh, *, batch_axes=("pod", "data"),
                         head_axis="model", pool_pages: int,
                         capacity_safety: int = 4, impl: str = "auto"):
    """Returns attend(q, k_new, v_new, k_pool, v_pool, page_table, seq_lens,
    append_slot) with paper-faithful fetch-the-page semantics.

    Same global shardings as ship_compute's attend.
    """
    dpc_axes = tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    b_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)
    import numpy as np
    n_nodes_static = int(np.prod([mesh.shape[a] for a in dpc_axes]))
    tp_static = mesh.shape[head_axis] if head_axis in mesh.axis_names else 1

    def attend(q, k_new, v_new, k_pool, v_pool, page_table, seq_lens,
               append_slot):
        me = _my_node(dpc_axes)
        page = k_pool.shape[1]
        b_loc, n_pages = page_table.shape

        # --- owner-side append (identical to ship_compute): gather the
        # tiny new-token KV over DP so the owning node installs it
        kn_all, vn_all = k_new, v_new
        sl_g, ap_g = seq_lens, append_slot
        for ax in reversed(b_axes):
            kn_all = jax.lax.all_gather(kn_all, ax, axis=0, tiled=True)
            vn_all = jax.lax.all_gather(vn_all, ax, axis=0, tiled=True)
            sl_g = jax.lax.all_gather(sl_g, ax, axis=0, tiled=True)
            ap_g = jax.lax.all_gather(ap_g, ax, axis=0, tiled=True)
        local = (ap_g >= 0) & (ap_g // pool_pages == me)
        slot = jnp.where(local, ap_g % pool_pages, pool_pages)
        off = sl_g % page
        k_pool = k_pool.at[slot, off].set(kn_all.astype(k_pool.dtype),
                                          mode="drop")
        v_pool = v_pool.at[slot, off].set(vn_all.astype(v_pool.dtype),
                                          mode="drop")

        # --- stripe: this column fetches pages n with n % tp == my_col
        my_col = (jax.lax.axis_index(head_axis)
                  if head_axis in mesh.axis_names else jnp.int32(0))
        stripe = (jnp.arange(n_pages) % tp_static)[None, :] == my_col
        wanted = jnp.where(stripe & (page_table >= 0), page_table, -1)
        wanted = wanted.reshape(-1)                               # [F]

        cap = fetch_capacity(wanted.shape[0], n_nodes_static,
                             capacity_safety)
        req, owner_of, pos_of, overflow = build_fetch_plan(
            wanted, n_nodes_static, pool_pages, cap)

        # --- FUSE_DPC_READ out: request ids to owners
        req_recv = _a2a(req, dpc_axes)                            # [O, cap]
        # --- owner DMA: gather my slots for each peer
        safe = jnp.maximum(req_recv, 0)
        pages_k = jnp.where((req_recv >= 0)[..., None, None, None],
                            k_pool[safe], 0)
        pages_v = jnp.where((req_recv >= 0)[..., None, None, None],
                            v_pool[safe], 0)
        # barrier pins the wire format to the pool dtype — XLA otherwise
        # hoists the attention kernel's f32 upcast through the exchange and
        # doubles the fabric bytes (§Perf iteration C1)
        pages_k, pages_v = jax.lax.optimization_barrier((pages_k, pages_v))
        # --- payload back: the page bytes cross the fabric here
        resp_k = _a2a(pages_k, dpc_axes)    # [O, cap, page, Hkv, D]
        resp_v = _a2a(pages_v, dpc_axes)

        # --- stage into per-request layout; dropped/invalid -> zero + mask
        got = pos_of >= 0
        staged_k = jnp.where(
            got[:, None, None, None],
            resp_k[owner_of, jnp.maximum(pos_of, 0)], 0)
        staged_v = jnp.where(
            got[:, None, None, None],
            resp_v[owner_of, jnp.maximum(pos_of, 0)], 0)
        staged_k = staged_k.reshape((b_loc, n_pages) + staged_k.shape[1:])
        staged_v = staged_v.reshape((b_loc, n_pages) + staged_v.shape[1:])

        # --- local attention over the stripe (full q heads for the row)
        q_all = q
        if head_axis in mesh.axis_names:
            q_all = jax.lax.all_gather(q_all, head_axis, axis=1, tiled=True)
        flat_k = staged_k.reshape((b_loc * n_pages,) + staged_k.shape[2:])
        flat_v = staged_v.reshape((b_loc * n_pages,) + staged_v.shape[2:])
        pt_stripe = jnp.where(
            stripe & (page_table >= 0) & got.reshape(b_loc, n_pages),
            jnp.arange(b_loc * n_pages, dtype=jnp.int32).reshape(
                b_loc, n_pages),
            -1)
        out, (m, l) = dispatch.paged_attention(
            q_all, flat_k, flat_v, pt_stripe, seq_lens + 1, impl=impl,
            with_stats=True)

        # --- combine across the row's stripe columns only
        if head_axis in mesh.axis_names:
            o = lse_combine_allreduce(out.astype(jnp.float32), m, l,
                                      (head_axis,), wire_dtype=q.dtype)
            h_loc = q.shape[1]
            h_idx = jax.lax.axis_index(head_axis)
            o = jax.lax.dynamic_slice_in_dim(o, h_idx * h_loc, h_loc, 1)
        else:
            o = out.astype(jnp.float32)
        overflow = jax.lax.psum(overflow, dpc_axes)
        return o.astype(q.dtype), k_pool, v_pool, overflow

    batch_p = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    head_p = head_axis if head_axis in mesh.axis_names else None
    dpc_p = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]

    return shard_map(
        attend, mesh=mesh,
        in_specs=(
            P(batch_p, head_p, None),
            P(batch_p, None, None),
            P(batch_p, None, None),
            P(dpc_p, None, None, None),
            P(dpc_p, None, None, None),
            P(batch_p, None),
            P(batch_p),
            P(batch_p),
        ),
        out_specs=(
            P(batch_p, head_p, None),
            P(dpc_p, None, None, None),
            P(dpc_p, None, None, None),
            P(),
        ),
        check_rep=False,
    )


def make_shipdata_attend_mla(mesh: Mesh, *, batch_axes=("pod", "data"),
                             head_axis="model", pool_pages: int,
                             capacity_safety: int = 4, impl: str = "auto",
                             sm_scale=None):
    """MLA variant: fetch latent pages [P, page, R+Dr] to the consumer and
    attend locally (same stripe/a2a structure as the GQA path)."""
    dpc_axes = tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    b_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)
    import numpy as np
    n_nodes_static = int(np.prod([mesh.shape[a] for a in dpc_axes]))
    tp_static = mesh.shape[head_axis] if head_axis in mesh.axis_names else 1

    def attend(q_latent, q_rope, latent_new, pool, page_table, seq_lens,
               append_slot):
        me = _my_node(dpc_axes)
        page = pool.shape[1]
        b_loc, n_pages = page_table.shape

        ln_all, sl_g, ap_g = latent_new, seq_lens, append_slot
        for ax in reversed(b_axes):
            ln_all = jax.lax.all_gather(ln_all, ax, axis=0, tiled=True)
            sl_g = jax.lax.all_gather(sl_g, ax, axis=0, tiled=True)
            ap_g = jax.lax.all_gather(ap_g, ax, axis=0, tiled=True)
        local = (ap_g >= 0) & (ap_g // pool_pages == me)
        slot = jnp.where(local, ap_g % pool_pages, pool_pages)
        pool = pool.at[slot, sl_g % page].set(ln_all.astype(pool.dtype),
                                              mode="drop")

        my_col = (jax.lax.axis_index(head_axis)
                  if head_axis in mesh.axis_names else jnp.int32(0))
        stripe = (jnp.arange(n_pages) % tp_static)[None, :] == my_col
        wanted = jnp.where(stripe & (page_table >= 0),
                           page_table, -1).reshape(-1)
        cap = fetch_capacity(wanted.shape[0], n_nodes_static,
                             capacity_safety)
        req, owner_of, pos_of, overflow = build_fetch_plan(
            wanted, n_nodes_static, pool_pages, cap)
        req_recv = _a2a(req, dpc_axes)
        safe = jnp.maximum(req_recv, 0)
        pages_lat = jnp.where((req_recv >= 0)[..., None, None], pool[safe], 0)
        pages_lat = jax.lax.optimization_barrier(pages_lat)  # bf16 wire (C1)
        resp = _a2a(pages_lat, dpc_axes)

        got = pos_of >= 0
        staged = jnp.where(got[:, None, None],
                           resp[owner_of, jnp.maximum(pos_of, 0)], 0)
        staged = staged.reshape((b_loc, n_pages) + staged.shape[1:])

        ql, qr = q_latent, q_rope
        if head_axis in mesh.axis_names:
            ql = jax.lax.all_gather(ql, head_axis, axis=1, tiled=True)
            qr = jax.lax.all_gather(qr, head_axis, axis=1, tiled=True)
        flat = staged.reshape((b_loc * n_pages,) + staged.shape[2:])
        pt_stripe = jnp.where(
            stripe & (page_table >= 0) & got.reshape(b_loc, n_pages),
            jnp.arange(b_loc * n_pages, dtype=jnp.int32).reshape(
                b_loc, n_pages), -1)
        out, (m, l) = dispatch.mla_paged_attention(
            ql, qr, flat, pt_stripe, seq_lens + 1, impl=impl,
            with_stats=True, sm_scale=sm_scale)

        if head_axis in mesh.axis_names:
            o = lse_combine_allreduce(out.astype(jnp.float32), m, l,
                                      (head_axis,), wire_dtype=q_latent.dtype)
            h_loc = q_latent.shape[1]
            h_idx = jax.lax.axis_index(head_axis)
            o = jax.lax.dynamic_slice_in_dim(o, h_idx * h_loc, h_loc, 1)
        else:
            o = out.astype(jnp.float32)
        overflow = jax.lax.psum(overflow, dpc_axes)
        return o.astype(q_latent.dtype), pool, overflow

    batch_p = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    head_p = head_axis if head_axis in mesh.axis_names else None
    dpc_p = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]

    return shard_map(
        attend, mesh=mesh,
        in_specs=(
            P(batch_p, head_p, None),
            P(batch_p, head_p, None),
            P(batch_p, None),
            P(dpc_p, None, None),
            P(batch_p, None),
            P(batch_p),
            P(batch_p),
        ),
        out_specs=(
            P(batch_p, head_p, None),
            P(dpc_p, None, None),
            P(),
        ),
        check_rep=False,
    )


class ShipDataBackend:
    """Model-facing backend using the paper-faithful fetch-pages datapath."""

    def __init__(self, mesh: Mesh, page_table, seq_lens, append_slot, *,
                 pool_pages: int, batch_axes=("pod", "data"),
                 head_axis="model", impl="auto"):
        self.page_table = page_table
        self.seq_lens = seq_lens
        self.append_slot = append_slot
        self._attend = make_shipdata_attend(
            mesh, batch_axes=batch_axes, head_axis=head_axis,
            pool_pages=pool_pages, impl=impl)
        self._mesh = mesh
        self._kw = dict(batch_axes=batch_axes, head_axis=head_axis,
                        pool_pages=pool_pages, impl=impl)
        self._mla_cache = {}

    def attend_mla(self, q_latent, q_rope, latent_new, latent_pool, *,
                   sm_scale=None):
        key = float(sm_scale) if sm_scale is not None else None
        if key not in self._mla_cache:
            self._mla_cache[key] = make_shipdata_attend_mla(
                self._mesh, sm_scale=sm_scale, **self._kw)
        out, pool, _ = self._mla_cache[key](
            q_latent, q_rope, latent_new, latent_pool,
            self.page_table, self.seq_lens, self.append_slot)
        return out, pool

    def attend(self, q, k_new, v_new, k_pool, v_pool):
        # overflow (dropped fetches beyond queue capacity) is returned by the
        # raw attend; the backend interface discards it — benchmarks that
        # track it call ``self._attend`` directly.
        out, k_pool, v_pool, _ = self._attend(
            q, k_new, v_new, k_pool, v_pool, self.page_table, self.seq_lens,
            self.append_slot)
        return out, k_pool, v_pool
