"""DistributedKVCache — the facade tying the directory protocol (host control
plane) to the device page pools (data plane).

This is the DPC Client + DPC MM of the paper, specialized to KV pages: the
serving engine asks it for pages by (stream, page_idx) key; it runs the
read/commit/reclaim protocol against the cluster directory and hands back
*global page ids* for the device page tables.  The data plane (ship_compute /
ship_data / local backends) then serves the actual bytes.

Coherence mode mapping (paper §6 configurations):
    dpc / dpc_sc  pages shared cluster-wide through the directory
    replicated    every node installs its own copy (uncoordinated per-node
                  caches — the paper's NFS/per-node baseline regime)
    local_only    no reuse at all: every miss "refetches from storage"
                  (= prefill recompute; the Virtiofs baseline)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core import pagepool as pp
from repro.core.migration import MigrationConfig, OwnershipMigrator
from repro.core.protocol import DPCProtocol, ProtocolConfig, dir_shard_of
from repro.core.tlb import MODE_S
from repro.serving.prefix_tree import ClusterPrefixTree
from repro.obs import CLUSTER, Obs
from repro.runtime.liveness import DirectoryClientGuard
from repro.storage import make_storage


@dataclasses.dataclass
class PageLookup:
    """Engine-facing result for one page key."""
    status: int
    page_id: int          # global page id to put in the page table (-1 n/a)
    owner: int
    needs_fill: bool      # True -> caller must materialize (prefill) + commit
    remote: bool          # True -> served from a peer's pool slice
    # bytes recovered from the backing store (or the still-pending writeback
    # queue) for an evicted page: the caller installs these instead of
    # recomputing — the refault half of the evict -> refault loop
    refill: Optional[np.ndarray] = None


class DistributedKVCache:
    """Cluster-wide single-copy KV page cache (one instance per cluster,
    nodes addressed by id — in SPMD serving the engine process drives all
    nodes' control planes, exactly like the directory daemon does)."""

    def __init__(self, dpc: DPCConfig, num_nodes: int):
        self.dpc = dpc
        self.num_nodes = num_nodes
        # one observability hub for the whole cluster: protocol, TLBs,
        # page pools, writeback, engines, and membership all report here
        self.obs = Obs(dpc.obs_level, num_nodes=num_nodes,
                       trace_capacity=dpc.obs_trace_events)
        # durable tier: built from config, shared by every node's control
        # plane (the storage server of the paper's testbed)
        self.store, self.writeback = make_storage(
            dpc.storage_backend, root=dpc.storage_dir,
            extent_pages=dpc.storage_extent_pages,
            batch_size=dpc.writeback_batch,
            flush_interval_s=dpc.writeback_interval_s,
            async_mode=dpc.writeback_async, obs=self.obs)
        self.proto = DPCProtocol(ProtocolConfig(
            num_nodes=num_nodes,
            pool_pages=dpc.pool_pages_per_shard,
            directory_capacity=dpc.directory_capacity,
            inv_batch_threshold=dpc.inv_batch_threshold,
            placement=dpc.directory_placement,
            tlb_slots=dpc.tlb_slots if dpc.tlb_enabled else 0,
            tlb_max_probe=dpc.tlb_max_probe,
            tlb_write_grants=dpc.tlb_write_grants,
            tlb_piggyback=dpc.tlb_shootdown_piggyback,
            async_data_plane=dpc.async_data_plane,
            shadow_oracle=dpc.shadow_oracle,
            obs_level=dpc.obs_level,
            obs_trace_events=dpc.obs_trace_events,
        ), store=self.store, writeback=self.writeback, obs=self.obs)
        # buffered CLOCK touches for TLB owner-hits: slot -> hit count per
        # node, flushed in ONE batched pp.touch_weighted per engine step —
        # the steady-state hit path itself never talks to the device
        self._touch_buf: List[Dict[int, int]] = [
            {} for _ in range(num_nodes)]
        # replicated-mode bookkeeping: per-node private caches
        self._replica_maps: List[Dict[Tuple[int, int], int]] = [
            {} for _ in range(num_nodes)]
        self._replica_free: List[List[int]] = [
            list(range(dpc.pool_pages_per_shard - 1, -1, -1))
            for _ in range(num_nodes)]
        # per-node directory-client guards: server-side fencing trips them
        # (local-only degradation on the minority side of a partition) and
        # heal rejoins ride their re-probe hysteresis
        self.guards: List[DirectoryClientGuard] = [
            DirectoryClientGuard() for _ in range(num_nodes)]
        # promotion policy: every remote hit feeds the hotness ledger; the
        # engine drains it periodically through run_migrations()
        self.migrator = OwnershipMigrator(self.proto, MigrationConfig(
            threshold=dpc.migrate_threshold,
            batch_size=dpc.migrate_batch,
            decay_every=dpc.migrate_decay_every,
            cooldown_rounds=dpc.migrate_cooldown,
        ))
        # dict-compatible facade counters; ``kv.stats()`` (the view is
        # callable) returns the whole cluster's snapshot — counters,
        # per-node rows, histograms, gauges, incarnations
        self.stats = self.obs.view(
            CLUSTER, "cache",
            ("lookups", "fills", "remote_hits", "local_hits", "evictions",
             "migrations", "refills", "sync_flushes", "tlb_hits",
             "tlb_misses", "prefix_matches", "prefix_promotes",
             "prefix_promote_hits"))
        # cluster prefix tree: committed prompt paths, keyed + sharded
        # exactly like their directory entries, so any node's prefill is
        # matchable (and promotable) from any other node
        self.prefix_tree: Optional[ClusterPrefixTree] = None
        if dpc.enabled and dpc.prefix_tree_enabled:
            self.prefix_tree = ClusterPrefixTree(
                capacity=dpc.prefix_tree_capacity,
                shard_of=lambda s, p: dir_shard_of(self.proto.cfg, s, p))
        if self.obs.registry is not None:
            # pool occupancy gauges are sampled lazily at snapshot time
            # (one device readback per node per snapshot, zero data-path
            # cost between snapshots)
            self.obs.registry.add_gauge_provider(self._publish_pool_gauges)

    def _publish_pool_gauges(self) -> None:
        """Gauge provider: per-node slot-state census of every page pool."""
        for node in range(self.proto.cfg.num_nodes):
            for state, count in pp.occupancy(
                    self.proto.state.pools[node]).items():
                self.obs.gauge(node, "pagepool", state, count)

    # ------------------------------------------------------------------
    # storage tier
    # ------------------------------------------------------------------

    def set_page_bytes_fn(self, fn: Callable) -> None:
        """Data-plane hook: ``fn(key, pfn) -> np.ndarray | None`` captures a
        frame's bytes when a dirty eviction enqueues its flush obligation."""
        self.proto.attach_storage(page_bytes_fn=fn)

    def _storage_read(self, key: Tuple[int, int]) -> Optional[np.ndarray]:
        """Read-your-writes refill: pending queue copy first, then durable.

        A FLUSH lane still in flight holds bytes neither ``peek`` nor the
        store can see yet — settle the lanes first so a refault between an
        async eviction and its lane service returns the last-committed
        bytes, exactly like the sync reference mode."""
        self.proto.fence_data_lanes()
        if self.writeback is not None:
            data = self.writeback.peek(key)
            if data is not None:
                return data
        if self.store is not None:
            return self.store.read(key[0], key[1])
        return None

    def pump_storage(self, max_batches: Optional[int] = 1) -> int:
        """Step-boundary pump: drive flushes (sync mode) and release frames
        whose writeback committed.  Returns frames freed."""
        return self.proto.pump_writeback(max_batches)

    def flush(self, upto_epoch: Optional[int] = None) -> int:
        """Flush barrier over the whole queue (+ frame harvest)."""
        return self.proto.flush(upto_epoch=upto_epoch)

    def fsync_stream(self, stream: int) -> int:
        """Per-stream durability barrier (the engine's request-completion
        fsync).  No-op when the stream has nothing pending."""
        if self.writeback is None or \
                not self.writeback.has_pending_stream(stream):
            return 0
        return self.proto.flush(stream=stream)

    def advance_epoch(self) -> int:
        return 0 if self.writeback is None else self.writeback.advance_epoch()

    def settle_data_plane(self) -> int:
        """Force every in-flight lane-carried obligation (COPY / FLUSH) to
        land.  The engine runs this before dispatching a decode step so the
        compute can never read a frame whose bytes are still riding a lane.
        Returns obligations settled."""
        return self.proto.fence_data_lanes()

    def close(self) -> None:
        if self.writeback is not None:
            self.proto.fence_data_lanes()   # enqueue before close refuses
            self.writeback.close()
            self.proto.harvest_writebacks()
        if self.store is not None:
            self.store.close()

    # ------------------------------------------------------------------
    # shared-mode path (dpc / dpc_sc)
    # ------------------------------------------------------------------

    def lookup(self, streams: Sequence[int], pages: Sequence[int],
               node: int) -> List[PageLookup]:
        """Batched page lookup for ``node`` (FUSE_DPC_READ).

        Runs TLB-first: rows whose mapping is cached in the node's software
        TLB (core/tlb.py) are answered with zero directory opcodes and zero
        device round trips — CLOCK touches for owner-hits are buffered and
        flushed once per engine step (``flush_tlb_touches``).  Only the
        remaining rows fall through to the directory pipeline.
        """
        n = len(streams)
        self.stats["lookups"] += n
        mode = self.dpc.mode
        if mode in ("replicated", "local_only") or \
                self.proto.is_fenced(node):
            # a fenced node (minority side of a partition) degrades to
            # purely local caching — no ownership transitions, no
            # directory traffic, exactly the client-guard fallback
            return self._lookup_uncoordinated(streams, pages, node)

        out: List[Optional[PageLookup]] = [None] * n
        miss = list(range(n))
        tlbs = self.proto.tlbs
        if tlbs is not None and n:
            owners, pfns, modes, hit = tlbs.lookup_batch(node, streams,
                                                         pages)
            shared = modes == MODE_S
            miss = []
            pool_pages = self.dpc.pool_pages_per_shard
            touch_buf = self._touch_buf[node]
            oracle_on = self.proto.oracle is not None
            # hotness signal keeps flowing on cached hits — host-side dict
            # work, still no directory traffic
            migrator = self.migrator if self.dpc.migration_enabled else None
            n_shared = 0
            for i in range(n):
                if not hit[i]:
                    miss.append(i)
                    continue
                key = (int(streams[i]), int(pages[i]))
                owner, pfn = int(owners[i]), int(pfns[i])
                if oracle_on:
                    self.proto.check_tlb_grant(key, node, owner, pfn,
                                               bool(shared[i]))
                if shared[i]:
                    out[i] = PageLookup(D.ST_HIT_SHARER, pfn, owner,
                                        False, True)
                    n_shared += 1
                    if migrator is not None:
                        migrator.note_remote_access(key, node)
                else:
                    out[i] = PageLookup(D.ST_HIT_OWNER, pfn, node,
                                        False, False)
                    slot = pfn % pool_pages
                    touch_buf[slot] = touch_buf.get(slot, 0) + 1
            # counters are flushed once per batch, not per row — the
            # registry's hot-path budget (bench.obs_overhead) rides on this
            hits = n - len(miss)
            self.stats["tlb_hits"] += hits
            self.stats["tlb_misses"] += len(miss)
            if n_shared:
                self.stats["remote_hits"] += n_shared
            if hits - n_shared:
                self.stats["local_hits"] += hits - n_shared
        if not miss:
            return out  # pure steady-state: the directory saw nothing

        res = self.proto.read_pages([streams[i] for i in miss],
                                    [pages[i] for i in miss], node)
        pool_pages = self.dpc.pool_pages_per_shard
        for j, i in enumerate(miss):
            st = int(res.status[j])
            if st == D.ST_GRANT_E:
                slot = int(res.slot[j])
                key = (int(streams[i]), int(pages[i]))
                refill = self._storage_read(key)
                if refill is not None:
                    self.stats["refills"] += 1
                out[i] = PageLookup(st, node * pool_pages + slot, node,
                                    needs_fill=True, remote=False,
                                    refill=refill)
                self.stats["fills"] += 1
            elif st in (D.ST_MAP_S, D.ST_HIT_SHARER):
                out[i] = PageLookup(st, int(res.pfn[j]),
                                    int(res.owner[j]), False, True)
                self.stats["remote_hits"] += 1
                if self.dpc.migration_enabled:  # else the ledger never drains
                    self.migrator.note_remote_access(
                        (int(streams[i]), int(pages[i])), node)
            elif st == D.ST_HIT_OWNER:
                out[i] = PageLookup(st, int(res.pfn[j]), node, False,
                                    False)
                self.stats["local_hits"] += 1
            else:  # BLOCKED / FULL -> caller reclaims or recomputes
                out[i] = PageLookup(st, -1, -1, True, False)
        return out

    def flush_tlb_touches(self) -> int:
        """Apply every buffered TLB-hit CLOCK touch in one batched device
        call per node (the engine runs this at step boundaries; reclaim runs
        it first so the scan sees current heat).  Returns slots touched."""
        total = 0
        for node, buf in enumerate(self._touch_buf):
            if not buf:
                continue
            self.proto.touch_slots(node, list(buf.keys()),
                                   list(buf.values()))
            total += len(buf)
            buf.clear()
        return total

    def flush_dirty_marks(self) -> int:
        """Register every buffered write-grant dirty bit in one batched
        directory op per node (step boundary; teardowns flush on their own
        before they could observe the page).  Returns keys flushed."""
        return self.proto.flush_dirty_marks()

    # ------------------------------------------------------------------
    # cluster prefix tree + predictive promotion
    # ------------------------------------------------------------------

    def prefix_insert(self, keys: Sequence[Tuple[int, int]],
                      node: int) -> int:
        """Record a committed full-page prompt path in the cluster tree
        (engines call this right after admission commits).  No-op for
        uncoordinated modes and fenced nodes — their prefills are not
        cluster-visible, so advertising them would predict falsely."""
        if self.prefix_tree is None or self.proto.is_fenced(node):
            return 0
        return self.prefix_tree.insert(list(keys), node)

    def prefix_match(self, keys: Sequence[Tuple[int, int]],
                     node: int) -> List[Tuple[int, int]]:
        """Longest committed path matching ``keys`` (full pages only);
        heats the matched tree edges for ``node``."""
        if self.prefix_tree is None or self.proto.is_fenced(node):
            return []
        matched = self.prefix_tree.match(list(keys), node)
        if matched:
            self.stats["prefix_matches"] += 1
        return matched

    def promote_predicted(self, keys: Sequence[Tuple[int, int]],
                          node: int) -> Tuple[List[Tuple[int, int]], int]:
        """Predictive prefetch for matched tail pages: batch-promote their
        directory entries (sharer bit + TLB install + owner CLOCK credit;
        misses allocate nothing) and credit the migration ledger for the
        remote ones — prediction-sourced promotion.  Keys already cached in
        the node's TLB are skipped (they are as warm as promotion could
        make them).  Returns (promoted_keys, hits)."""
        if self.prefix_tree is None or self.proto.is_fenced(node) \
                or not keys:
            return [], 0
        keys = list(keys)
        tlbs = self.proto.tlbs
        if tlbs is not None:
            _, _, _, hit = tlbs.lookup_batch(
                node, [k[0] for k in keys], [k[1] for k in keys])
            keys = [k for k, h in zip(keys, hit) if not h]
            if not keys:
                return [], 0
        streams = [k[0] for k in keys]
        pages = [k[1] for k in keys]
        status = self.proto.promote_pages(streams, pages, node)
        hits = 0
        weight = self.dpc.prefix_predict_weight
        migrator = self.migrator if self.dpc.migration_enabled else None
        for k, st in zip(keys, status):
            st = int(st)
            if st in (D.ST_MAP_S, D.ST_HIT_SHARER):
                hits += 1
                if migrator is not None:
                    migrator.note_predicted_access(k, node, weight)
            elif st == D.ST_HIT_OWNER:
                hits += 1
        self.stats["prefix_promotes"] += len(keys)
        self.stats["prefix_promote_hits"] += hits
        return keys, hits

    def commit(self, streams, pages, node: int, lookups: List[PageLookup],
               dirty=None):
        """Publish filled pages (E -> O).

        With a backing store attached, freshly materialized pages commit
        *dirty* (their only copy is the frame — eviction owes a writeback)
        while pages installed from a ``refill`` commit clean (a durable copy
        already exists).  ``dirty`` overrides per-row when given.
        """
        rows = [i for i, lk in enumerate(lookups)
                if lk.needs_fill and lk.page_id >= 0]
        if not rows or self.dpc.mode in ("replicated", "local_only") \
                or self.proto.is_fenced(node):
            return
        pool_pages = self.dpc.pool_pages_per_shard
        if dirty is None:
            dirty = ([lookups[i].refill is None for i in rows]
                     if self.store is not None else None)
        else:
            dirty = np.broadcast_to(np.asarray(dirty, bool),
                                    (len(lookups),))[rows]
        self.proto.commit_pages(
            [streams[i] for i in rows], [pages[i] for i in rows], node,
            [lookups[i].page_id % pool_pages for i in rows], dirty=dirty)

    def reclaim(self, node: int, want: int) -> int:
        """Synchronous reclaim round (engine calls under pool pressure).

        Dirty victims are pinned behind their flush obligations; if clean
        frames (or already-durable harvests) satisfied the pressure the
        async pipeline stays off the critical path, otherwise we wait the
        barrier out (the synchronous-writeback fallback) so the caller's
        retry sees free frames instead of spinning."""
        self.flush_tlb_touches()   # CLOCK must see the buffered heat
        freed, wb = self.proto.reclaim_sync(node, want)
        self.stats["evictions"] += freed
        if self.writeback is not None and wb:
            self.proto.pump_writeback()     # harvest whatever is durable
            if int(pp.num_free(self.proto.state.pools[node])) == 0:
                self.stats["sync_flushes"] += 1
                self.proto.flush()
        return freed

    def run_migrations(self, copy_fn=None) -> List[Tuple[Tuple[int, int],
                                                         int, int]]:
        """One ownership-migration round (engine calls off the critical
        path).  Promotes pages whose decayed remote-access count crossed the
        threshold; returns [(key, old_page_id, new_page_id)] so the caller
        can rewrite its page tables.  No-op for uncoordinated modes."""
        if not self.dpc.migration_enabled:
            return []
        moved = self.migrator.run_round(copy_fn=copy_fn)
        self.stats["migrations"] += len(moved)
        return moved

    def fail_node(self, node: int, rehome_to: Optional[int] = None,
                  install_fn: Optional[Callable] = None) -> int:
        lost = self.proto.fail_node(node, rehome_to=rehome_to,
                                    install_fn=install_fn)
        self._replica_maps[node].clear()
        self._touch_buf[node].clear()
        return lost

    # ------------------------------------------------------------------
    # elastic membership
    # ------------------------------------------------------------------

    def join_node(self) -> int:
        """Grow the cluster by one node (facade + protocol state)."""
        node = self.proto.add_node()
        self.num_nodes = self.proto.cfg.num_nodes
        self._touch_buf.append({})
        self._replica_maps.append({})
        self._replica_free.append(
            list(range(self.dpc.pool_pages_per_shard - 1, -1, -1)))
        self.guards.append(DirectoryClientGuard())
        return node

    def rejoin_node(self, node: int) -> None:
        """A known node returns from drain/failure with empty caches."""
        self.proto.rejoin_node(node)
        self._touch_buf[node].clear()
        self._replica_maps[node].clear()
        self._replica_free[node] = list(
            range(self.dpc.pool_pages_per_shard - 1, -1, -1))

    def rebalance_join(self, node: int, batch: Optional[int] = None,
                       copy_fn=None) -> List[Tuple[Tuple[int, int],
                                                   int, int]]:
        """Seed a joined node with the cluster's coldest pages (ordinary
        MIGRATE rounds through the hotness machinery)."""
        return self.migrator.rebalance_join(node, batch=batch,
                                            copy_fn=copy_fn)

    def drain_node(self, node: int, alive: Optional[Sequence[int]] = None,
                   copy_fn=None) -> Dict:
        """Planned departure: evacuate everything ``node`` holds before it
        leaves.  Destinations prefer the hotness ledger's heaviest remote
        accessor per page, falling back to a deterministic spread over
        ``alive``.  Returns the protocol drain stats (``moved`` carries
        (key, old_pfn, new_pfn) for page-table rewriting)."""
        self.flush_tlb_touches()
        if alive is None:
            alive = [n for n in range(self.num_nodes) if n != node]
        alive = [n for n in alive if n != node]
        assert alive, "drain needs at least one surviving node"

        def dest_fn(key):
            hot, _ = self.migrator.ledger.hottest(key)
            if hot in alive:
                return hot
            return alive[(key[0] ^ key[1]) % len(alive)]

        st = self.proto.drain_node(node, dest_fn=dest_fn, copy_fn=copy_fn)
        self._touch_buf[node].clear()
        self._replica_maps[node].clear()
        return st

    def checkpoint_dirty(self, node: Optional[int] = None) -> int:
        """Persist registered dirty pages out-of-band (see protocol)."""
        return self.proto.checkpoint_dirty(node)

    def attach_faults(self, plan) -> None:
        """Thread a :class:`repro.runtime.faults.FaultPlan` through the
        protocol's routed batches / lanes / crash points and the writeback
        queue's sync path.  ``None`` detaches."""
        self.proto.attach_faults(plan)
        if self.writeback is not None and \
                hasattr(self.writeback, "attach_faults"):
            self.writeback.attach_faults(plan)

    def attach_membership(self, membership, install_fn=None,
                          copy_fn=None) -> None:
        """Subscribe the cache to membership epochs: joins grow (or re-seed)
        state, drains evacuate through the protocol, failures re-home
        orphans from the durable tier onto the first survivor, fences cut
        the minority side off (stale-epoch rejection + local-only guard
        trip + re-home, like a failure the node survives), heals arm the
        guard's re-probe path (:meth:`probe_fenced` drives the rejoin)."""
        if hasattr(membership, "attach_obs"):
            membership.attach_obs(self.obs)

        def _rehome_target(node: int) -> Optional[int]:
            survivors = sorted(membership.alive - {node})
            return survivors[0] if (survivors and (
                self.store is not None or self.writeback is not None)) \
                else None

        def on_change(ev) -> None:
            # every committed transition carries its fencing token into
            # the protocol before the reaction runs — the trace audit
            # checks the resulting EV_EPOCH stream is monotone
            self.proto.epoch_bump(ev.epoch, getattr(ev, "fence", ev.epoch))
            if ev.kind == "join":
                if ev.node >= self.num_nodes:
                    self.join_node()
                else:
                    self.proto.unfence_nodes([ev.node])
                    self.rejoin_node(ev.node)
            elif ev.kind == "drain":
                # drain fires while the node is still listed alive
                dests = sorted(membership.alive - {ev.node})
                if dests:
                    self.drain_node(ev.node, alive=dests, copy_fn=copy_fn)
            elif ev.kind in ("fail", "evict_straggler"):
                self.fail_node(ev.node, rehome_to=_rehome_target(ev.node),
                               install_fn=install_fn)
            elif ev.kind == "fence":
                # majority-side reaction: reject the minority node's
                # batches at the committed token, trip its client guard
                # (it degrades to local-only), and reclaim its pages so
                # the surviving majority keeps serving them
                self.proto.fence_nodes([ev.node], token=ev.fence)
                self.guards[ev.node].trip()
                self.fail_node(ev.node, rehome_to=_rehome_target(ev.node),
                               install_fn=install_fn)
            # "heal" needs no immediate reaction: the fenced node stays
            # cut off until its guard's re-probe streak completes

        membership.on_change(on_change)

    def probe_fenced(self, membership) -> List[int]:
        """One re-probe round for fenced nodes (call periodically, e.g.
        per engine step).  A node whose partition healed sees its probes
        answered (it can reach quorum again) and accumulates the guard's
        hysteresis streak; once the guard returns to ``dpc`` the node
        rejoins through the epoch log — which unfences it and re-seeds
        its caches.  Nodes still partitioned reset their streak.  Returns
        the nodes that rejoined this round."""
        rejoined: List[int] = []
        for node in sorted(membership.fenced):
            guard = self.guards[node]
            if membership.has_quorum(node):
                guard.response_received()
                if guard.check() == "dpc":
                    membership.join(node)
                    rejoined.append(node)
            else:
                guard.probe_failed()
        return rejoined

    # ------------------------------------------------------------------
    # uncoordinated baselines
    # ------------------------------------------------------------------

    def _lookup_uncoordinated(self, streams, pages, node: int
                              ) -> List[PageLookup]:
        """replicated: per-node private page cache (hits only on own copies);
        local_only: never caches across requests at all."""
        out = []
        pool_pages = self.dpc.pool_pages_per_shard
        pmap = self._replica_maps[node]
        free = self._replica_free[node]
        for s, p in zip(streams, pages):
            key = (int(s), int(p))
            if self.dpc.mode == "replicated" and key in pmap:
                out.append(PageLookup(D.ST_HIT_OWNER,
                                      node * pool_pages + pmap[key], node,
                                      False, False))
                self.stats["local_hits"] += 1
                continue
            if not free:
                # evict an arbitrary victim (FIFO) to stay honest about
                # capacity — uncoordinated caches thrash under big sets
                if pmap:
                    victim_key = next(iter(pmap))
                    free.append(pmap.pop(victim_key))
                    self.stats["evictions"] += 1
                else:
                    out.append(PageLookup(D.ST_FULL, -1, -1, True, False))
                    continue
            slot = free.pop()
            if self.dpc.mode == "replicated":
                pmap[key] = slot
            self.stats["fills"] += 1
            out.append(PageLookup(D.ST_GRANT_E, node * pool_pages + slot,
                                  node, True, False))
        return out

    # ------------------------------------------------------------------

    def hit_rate(self) -> float:
        h = self.stats["remote_hits"] + self.stats["local_hits"]
        return h / max(self.stats["lookups"], 1)

    def directory_occupancy(self) -> int:
        return len(self.proto.directory_view())
