"""DistributedKVCache — the facade tying the directory protocol (host control
plane) to the device page pools (data plane).

This is the DPC Client + DPC MM of the paper, specialized to KV pages: the
serving engine asks it for pages by (stream, page_idx) key; it runs the
read/commit/reclaim protocol against the cluster directory and hands back
*global page ids* for the device page tables.  The data plane (ship_compute /
ship_data / local backends) then serves the actual bytes.

Coherence mode mapping (paper §6 configurations):
    dpc / dpc_sc  pages shared cluster-wide through the directory
    replicated    every node installs its own copy (uncoordinated per-node
                  caches — the paper's NFS/per-node baseline regime)
    local_only    no reuse at all: every miss "refetches from storage"
                  (= prefill recompute; the Virtiofs baseline)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core.migration import MigrationConfig, OwnershipMigrator
from repro.core.protocol import DPCProtocol, ProtocolConfig


@dataclasses.dataclass
class PageLookup:
    """Engine-facing result for one page key."""
    status: int
    page_id: int          # global page id to put in the page table (-1 n/a)
    owner: int
    needs_fill: bool      # True -> caller must materialize (prefill) + commit
    remote: bool          # True -> served from a peer's pool slice


class DistributedKVCache:
    """Cluster-wide single-copy KV page cache (one instance per cluster,
    nodes addressed by id — in SPMD serving the engine process drives all
    nodes' control planes, exactly like the directory daemon does)."""

    def __init__(self, dpc: DPCConfig, num_nodes: int):
        self.dpc = dpc
        self.num_nodes = num_nodes
        self.proto = DPCProtocol(ProtocolConfig(
            num_nodes=num_nodes,
            pool_pages=dpc.pool_pages_per_shard,
            directory_capacity=dpc.directory_capacity,
            inv_batch_threshold=dpc.inv_batch_threshold,
            placement=dpc.directory_placement,
        ))
        # replicated-mode bookkeeping: per-node private caches
        self._replica_maps: List[Dict[Tuple[int, int], int]] = [
            {} for _ in range(num_nodes)]
        self._replica_free: List[List[int]] = [
            list(range(dpc.pool_pages_per_shard - 1, -1, -1))
            for _ in range(num_nodes)]
        # promotion policy: every remote hit feeds the hotness ledger; the
        # engine drains it periodically through run_migrations()
        self.migrator = OwnershipMigrator(self.proto, MigrationConfig(
            threshold=dpc.migrate_threshold,
            batch_size=dpc.migrate_batch,
            decay_every=dpc.migrate_decay_every,
            cooldown_rounds=dpc.migrate_cooldown,
        ))
        self.stats = {"lookups": 0, "fills": 0, "remote_hits": 0,
                      "local_hits": 0, "evictions": 0, "migrations": 0}

    # ------------------------------------------------------------------
    # shared-mode path (dpc / dpc_sc)
    # ------------------------------------------------------------------

    def lookup(self, streams: Sequence[int], pages: Sequence[int],
               node: int) -> List[PageLookup]:
        """Batched page lookup for ``node`` (FUSE_DPC_READ)."""
        self.stats["lookups"] += len(streams)
        mode = self.dpc.mode
        if mode in ("replicated", "local_only"):
            return self._lookup_uncoordinated(streams, pages, node)

        res = self.proto.read_pages(list(streams), list(pages), node)
        out = []
        pool_pages = self.dpc.pool_pages_per_shard
        for i in range(len(streams)):
            st = int(res.status[i])
            if st == D.ST_GRANT_E:
                slot = int(res.slot[i])
                out.append(PageLookup(st, node * pool_pages + slot, node,
                                      needs_fill=True, remote=False))
                self.stats["fills"] += 1
            elif st in (D.ST_MAP_S, D.ST_HIT_SHARER):
                out.append(PageLookup(st, int(res.pfn[i]),
                                      int(res.owner[i]), False, True))
                self.stats["remote_hits"] += 1
                if self.dpc.migration_enabled:  # else the ledger never drains
                    self.migrator.note_remote_access(
                        (int(streams[i]), int(pages[i])), node)
            elif st == D.ST_HIT_OWNER:
                out.append(PageLookup(st, int(res.pfn[i]), node, False,
                                      False))
                self.stats["local_hits"] += 1
            else:  # BLOCKED / FULL -> caller reclaims or recomputes
                out.append(PageLookup(st, -1, -1, True, False))
        return out

    def commit(self, streams, pages, node: int, lookups: List[PageLookup]):
        """Publish filled pages (E -> O)."""
        rows = [i for i, lk in enumerate(lookups)
                if lk.needs_fill and lk.page_id >= 0]
        if not rows or self.dpc.mode in ("replicated", "local_only"):
            return
        pool_pages = self.dpc.pool_pages_per_shard
        self.proto.commit_pages(
            [streams[i] for i in rows], [pages[i] for i in rows], node,
            [lookups[i].page_id % pool_pages for i in rows])

    def reclaim(self, node: int, want: int) -> int:
        """Synchronous reclaim round (engine calls under pool pressure)."""
        freed, _ = self.proto.reclaim_sync(node, want)
        self.stats["evictions"] += freed
        return freed

    def run_migrations(self, copy_fn=None) -> List[Tuple[Tuple[int, int],
                                                         int, int]]:
        """One ownership-migration round (engine calls off the critical
        path).  Promotes pages whose decayed remote-access count crossed the
        threshold; returns [(key, old_page_id, new_page_id)] so the caller
        can rewrite its page tables.  No-op for uncoordinated modes."""
        if not self.dpc.migration_enabled:
            return []
        moved = self.migrator.run_round(copy_fn=copy_fn)
        self.stats["migrations"] += len(moved)
        return moved

    def fail_node(self, node: int) -> int:
        lost = self.proto.fail_node(node)
        self._replica_maps[node].clear()
        return lost

    # ------------------------------------------------------------------
    # uncoordinated baselines
    # ------------------------------------------------------------------

    def _lookup_uncoordinated(self, streams, pages, node: int
                              ) -> List[PageLookup]:
        """replicated: per-node private page cache (hits only on own copies);
        local_only: never caches across requests at all."""
        out = []
        pool_pages = self.dpc.pool_pages_per_shard
        pmap = self._replica_maps[node]
        free = self._replica_free[node]
        for s, p in zip(streams, pages):
            key = (int(s), int(p))
            if self.dpc.mode == "replicated" and key in pmap:
                out.append(PageLookup(D.ST_HIT_OWNER,
                                      node * pool_pages + pmap[key], node,
                                      False, False))
                self.stats["local_hits"] += 1
                continue
            if not free:
                # evict an arbitrary victim (FIFO) to stay honest about
                # capacity — uncoordinated caches thrash under big sets
                if pmap:
                    victim_key = next(iter(pmap))
                    free.append(pmap.pop(victim_key))
                    self.stats["evictions"] += 1
                else:
                    out.append(PageLookup(D.ST_FULL, -1, -1, True, False))
                    continue
            slot = free.pop()
            if self.dpc.mode == "replicated":
                pmap[key] = slot
            self.stats["fills"] += 1
            out.append(PageLookup(D.ST_GRANT_E, node * pool_pages + slot,
                                  node, True, False))
        return out

    # ------------------------------------------------------------------

    def hit_rate(self) -> float:
        h = self.stats["remote_hits"] + self.stats["local_hits"]
        return h / max(self.stats["lookups"], 1)

    def directory_occupancy(self) -> int:
        return len(self.proto.directory_view())
