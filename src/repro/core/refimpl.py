"""Pure-Python reference implementation of the DPC protocol.

This is the executable spec: a dict-based model of the paper's directory
(Fig. 2 state machine + Fig. 3 components) against which the array-based JAX
directory is property-tested.  It is also used directly by the *host-tier*
data-pipeline cache (``repro/data``), where a Python directory is the natural
implementation (the paper's directory is itself a user-space daemon).

States per entry (cluster view):  the paper stores a per-node state vector;
the equivalent normal form we store is ``(state, owner, sharers)`` where
``state ∈ {E, O, TBI}`` for present entries, absence == all-I.  A node's
per-node state is derived:  owner in O/E/TBI, members of ``sharers`` in S,
everyone else I — exactly the encoding the paper's 14 B entry uses.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core import descriptors as D

# entry states (global view; per-node states are derived).  TBM is the
# migration-flavored TBI: same sharer-teardown semantics, distinct code so
# reclaim and migrate transactions can never complete each other.
FREE, E, O, TBI, TBM = 0, 1, 2, 3, 4
STATE_NAMES = {FREE: "FREE", E: "E", O: "O", TBI: "TBI", TBM: "TBM"}

Key = Tuple[int, int]  # (stream_id, page_idx)


@dataclass
class Entry:
    state: int
    owner: int
    sharers: Set[int] = field(default_factory=set)
    pfn: int = -1
    dirty: bool = False
    # dirty bits reported by sharers during an invalidation round
    inv_dirty: bool = False


@dataclass
class RefStats:
    grants_e: int = 0
    maps_s: int = 0
    hits_owner: int = 0
    hits_sharer: int = 0
    blocked: int = 0
    full: int = 0
    bad: int = 0
    invalidations: int = 0
    inv_acks: int = 0
    completions: int = 0


class RefDirectory:
    """Executable spec of the DPC cache directory."""

    def __init__(self, capacity: int, num_nodes: int):
        self.capacity = capacity
        self.num_nodes = num_nodes
        self.entries: Dict[Key, Entry] = {}
        self.stats = RefStats()

    # -- derived per-node state (paper Fig. 2 vocabulary) --------------------

    def node_state(self, key: Key, node: int) -> str:
        e = self.entries.get(key)
        if e is None:
            return "I"
        if node == e.owner:
            return STATE_NAMES[e.state]  # E / O / TBI
        if node in e.sharers:
            return "S"
        return "I"

    # -- opcode: FUSE_DPC_READ / FUSE_DPC_LOOKUP_LOCK -------------------------

    def lookup_and_install(self, stream: int, page: int, node: int
                           ) -> Tuple[int, int, int]:
        """Returns (status, owner, pfn).  Drives ACC_MISS_ALLOC/ACC_MISS_RMAP."""
        key = (stream, page)
        e = self.entries.get(key)
        if e is None:
            if len(self.entries) >= self.capacity:
                self.stats.full += 1
                return D.ST_FULL, -1, -1
            self.entries[key] = Entry(state=E, owner=node)
            self.stats.grants_e += 1
            return D.ST_GRANT_E, node, -1
        if e.state in (E, TBI, TBM):
            self.stats.blocked += 1
            return D.ST_BLOCKED, -1, -1
        # state == O
        if e.owner == node:
            self.stats.hits_owner += 1
            return D.ST_HIT_OWNER, node, e.pfn
        if node in e.sharers:
            self.stats.hits_sharer += 1
            return D.ST_HIT_SHARER, e.owner, e.pfn
        e.sharers.add(node)
        self.stats.maps_s += 1
        return D.ST_MAP_S, e.owner, e.pfn

    # -- opcode: FUSE_DPC_UNLOCK (COMMIT, E -> O) ------------------------------

    def commit(self, stream: int, page: int, node: int, pfn: int) -> int:
        e = self.entries.get((stream, page))
        if e is None or e.state != E or e.owner != node:
            self.stats.bad += 1
            return D.ST_BAD
        e.state = O
        e.pfn = pfn
        return D.ST_OK

    def abort_install(self, stream: int, page: int, node: int) -> int:
        """E holder failed to materialize (e.g. admission rejected): back to all-I."""
        key = (stream, page)
        e = self.entries.get(key)
        if e is None or e.state != E or e.owner != node:
            self.stats.bad += 1
            return D.ST_BAD
        del self.entries[key]
        return D.ST_OK

    # -- opcode: FUSE_DPC_BATCH_INV (owner-initiated reclaim, LOCAL_INV) ------

    def begin_invalidate(self, stream: int, page: int, node: int
                         ) -> Tuple[int, Set[int]]:
        """O -> TBI.  Returns sharer set the directory must DIR_INV."""
        e = self.entries.get((stream, page))
        if e is None or e.state != O or e.owner != node:
            self.stats.bad += 1
            return D.ST_BAD, set()
        e.state = TBI
        e.inv_dirty = e.dirty
        self.stats.invalidations += 1
        return D.ST_OK, set(e.sharers)

    # -- opcode: FUSE_DPC_INV_ACK (sharer acknowledges DIR_INV) ---------------

    def ack_invalidate(self, stream: int, page: int, node: int,
                       dirty: bool) -> int:
        e = self.entries.get((stream, page))
        if e is None or e.state not in (TBI, TBM) or node not in e.sharers:
            self.stats.bad += 1
            return D.ST_BAD
        e.sharers.discard(node)
        e.inv_dirty = e.inv_dirty or dirty
        self.stats.inv_acks += 1
        return D.ST_OK

    # -- INVALIDATION_ACK: all sharers gone -> owner writes back, entry -> I --

    def complete_invalidate(self, stream: int, page: int, node: int
                            ) -> Tuple[int, bool]:
        """Returns (status, needs_writeback)."""
        key = (stream, page)
        e = self.entries.get(key)
        if e is None or e.state != TBI or e.owner != node:
            self.stats.bad += 1
            return D.ST_BAD, False
        if e.sharers:
            return D.ST_BLOCKED, False  # ACKs outstanding
        # a sharer_drop(dirty=True) landing mid-teardown accumulates into
        # e.dirty, not inv_dirty — fold both in, like the array's single lane
        dirty = e.inv_dirty or e.dirty
        del self.entries[key]
        self.stats.completions += 1
        return D.ST_OK, dirty

    # -- opcode: FUSE_DPC_MIGRATE (hotness-driven ownership hand-off) ---------

    def begin_migrate(self, stream: int, page: int, dst: int
                      ) -> Tuple[int, int, int, Set[int]]:
        """O -> TBM.  Returns (status, old_owner, old_pfn, sharers to DIR_INV).

        dst == current owner is a no-op (ST_HIT_OWNER); a page already in a
        teardown/install transition is BLOCKED; an absent page is BAD."""
        e = self.entries.get((stream, page))
        if e is None:
            self.stats.bad += 1
            return D.ST_BAD, -1, -1, set()
        if e.state != O:
            self.stats.blocked += 1
            return D.ST_BLOCKED, -1, -1, set()
        if e.owner == dst:
            return D.ST_HIT_OWNER, e.owner, e.pfn, set()
        old_owner, old_pfn = e.owner, e.pfn
        e.state = TBM
        e.inv_dirty = e.dirty
        self.stats.invalidations += 1
        return D.ST_OK, old_owner, old_pfn, set(e.sharers)

    def complete_migrate(self, stream: int, page: int, dst: int, old: int
                         ) -> Tuple[int, bool]:
        """TBM -> E@dst once every sharer ACKed.  Returns (status, dirty).

        dst == old is the abort path (ownership returns to the source).  The
        entry re-enters E with pfn unpublished: the new owner copies the page
        and runs the ordinary COMMIT (E -> O)."""
        e = self.entries.get((stream, page))
        if e is None or e.state != TBM or e.owner != old:
            self.stats.bad += 1
            return D.ST_BAD, False
        if e.sharers:
            return D.ST_BLOCKED, False
        dirty = e.dirty or e.inv_dirty
        e.state = E
        e.owner = dst
        e.pfn = -1
        e.dirty = dirty
        self.stats.completions += 1
        return D.ST_OK, dirty

    # -- sharer-side LOCAL_INV (drop a remote mapping voluntarily) ------------

    def sharer_drop(self, stream: int, page: int, node: int,
                    dirty: bool = False) -> int:
        e = self.entries.get((stream, page))
        if e is None or node not in e.sharers:
            self.stats.bad += 1
            return D.ST_BAD
        e.sharers.discard(node)
        e.dirty = e.dirty or dirty
        return D.ST_OK

    def mark_dirty(self, stream: int, page: int, node: int) -> int:
        """A write through an O/S mapping dirties the page (relaxed-mode path)."""
        e = self.entries.get((stream, page))
        if e is None or e.state != O or (node != e.owner and node not in e.sharers):
            self.stats.bad += 1
            return D.ST_BAD
        e.dirty = True
        return D.ST_OK

    def clear_dirty(self, stream: int, page: int, node: int
                    ) -> Tuple[int, bool]:
        """CLEAR_DIRTY: the owner persisted the bytes out-of-band (e.g. a
        migration hand-off checkpointed the moving frame) — drop the
        writeback obligation.  Returns (status, was_dirty)."""
        e = self.entries.get((stream, page))
        if e is None or e.state != O or e.owner != node:
            self.stats.bad += 1
            return D.ST_BAD, False
        was = e.dirty
        e.dirty = False
        e.inv_dirty = False
        return D.ST_OK, was

    # -- TLB oracle (core/tlb.py coherence assert) ----------------------------

    def grants_mapping(self, stream: int, page: int, node: int, owner: int,
                       pfn: int, shared: bool) -> Tuple[bool, str]:
        """Does the directory still grant ``node`` this cached mapping?

        Owner-mode entries require a live O entry owned by ``node`` with the
        same published PFN.  Shared-mode entries require the node's sharer
        bit and the same (owner, pfn) — a sharer may legally keep reading
        through TBI/TBM *until its INV_ACK lands* (the bit is still set),
        which is exactly the window real hardware has before a shootdown.
        """
        e = self.entries.get((stream, page))
        if e is None:
            return False, "no directory entry"
        if shared:
            if node not in e.sharers:
                return False, f"sharer bit gone (state={STATE_NAMES[e.state]})"
            if e.owner != owner or e.pfn != pfn:
                return False, f"mapping moved to ({e.owner}, pfn={e.pfn})"
            return True, ""
        if e.state != O or e.owner != node:
            return False, (f"not the owner (state={STATE_NAMES[e.state]}, "
                           f"owner={e.owner})")
        if e.pfn != pfn:
            return False, f"pfn republished ({e.pfn})"
        return True, ""

    def grants_write(self, stream: int, page: int, node: int, pfn: int
                     ) -> Tuple[bool, str, bool]:
        """Does the directory still grant ``node`` a cached *write* grant
        (a MODE_M mapping-cache entry)?

        A write grant requires live ownership of an O entry with the same
        published PFN — exactly the owner-mode read grant.  The third return
        is the entry's dirty bit: the caller (core/protocol.py) asserts the
        M promise — dirty already registered *or* sitting in the owner's
        buffered-dirty set awaiting the next batched flush — so a buffered
        mark can never be dropped behind a teardown.
        """
        e = self.entries.get((stream, page))
        if e is None:
            return False, "no directory entry", False
        if e.state != O or e.owner != node:
            return False, (f"not the owner (state={STATE_NAMES[e.state]}, "
                           f"owner={e.owner})"), False
        if e.pfn != pfn:
            return False, f"pfn republished ({e.pfn})", False
        return True, "", e.dirty

    # -- liveness (paper §5): node failure -------------------------------------

    def fail_node(self, node: int) -> Tuple[List[Key], List[Key]]:
        """Directory-side failure handling: drop the node from every sharer
        set; entries it owned are lost (cache-capacity shrink) and removed.
        Returns (owned_lost, shares_dropped)."""
        owned, shared = [], []
        for key, e in list(self.entries.items()):
            if e.owner == node:
                owned.append(key)
                del self.entries[key]
            elif node in e.sharers:
                e.sharers.discard(node)
                shared.append(key)
        return owned, shared

    # -- invariants (property tests assert these after every op) --------------

    def check_invariants(self) -> None:
        for key, e in self.entries.items():
            assert e.state in (E, O, TBI, TBM), f"{key}: bad state {e.state}"
            assert 0 <= e.owner < self.num_nodes, f"{key}: bad owner {e.owner}"
            # single-copy invariant: exactly one owner, owner not in sharers
            assert e.owner not in e.sharers, f"{key}: owner in sharers"
            if e.state == E:
                # no valid copy exists anywhere: nobody may map it
                assert not e.sharers, f"{key}: sharers while in E"
                assert e.pfn == -1, f"{key}: pfn published while in E"
            for s in e.sharers:
                assert 0 <= s < self.num_nodes
        assert len(self.entries) <= self.capacity

    def resident_pages(self, node: int) -> List[Key]:
        return [k for k, e in self.entries.items()
                if e.owner == node and e.state in (O, E, TBI, TBM)]

    def __len__(self) -> int:
        return len(self.entries)


class RefPagePool:
    """Executable spec of one node's physical page pool (+ GCLOCK reclaim)."""

    HOT_MAX = 8  # mirror of pagepool.HOT_MAX

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self.key_of: Dict[int, Optional[Key]] = {i: None for i in range(num_pages)}
        self.ref_bit: List[int] = [0] * num_pages
        self.hot: List[int] = [0] * num_pages
        self.clock_hand = 0
        # flush-before-free mirror of pagepool.S_WRITEBACK: slots whose dirty
        # contents are being persisted; pinned until the flush commits
        self.writeback: set = set()

    def alloc(self) -> int:
        """Returns a free slot or -1 (caller must reclaim)."""
        if not self.free:
            return -1
        slot = self.free.pop()
        self.ref_bit[slot] = 1
        self.hot[slot] = 1
        return slot

    def install(self, slot: int, key: Key) -> None:
        assert self.key_of[slot] is None
        self.key_of[slot] = key

    def touch(self, slot: int) -> None:
        self.ref_bit[slot] = 1
        self.hot[slot] = min(self.hot[slot] + 1, self.HOT_MAX)

    def decay_hot(self) -> None:
        self.hot = [h >> 1 for h in self.hot]

    def retire(self, slot: int) -> None:
        """DRAINING -> WRITEBACK: pin the slot until its flush commits."""
        assert self.key_of[slot] is not None
        assert slot not in self.free
        self.writeback.add(slot)

    def release(self, slot: int) -> Optional[Key]:
        key = self.key_of[slot]
        self.key_of[slot] = None
        self.ref_bit[slot] = 0
        self.hot[slot] = 0
        self.writeback.discard(slot)
        self.free.append(slot)
        return key

    def clock_scan(self, want: int) -> List[int]:
        """GCLOCK: ref bit is the second chance, the hotness counter buys
        further passes (halved each time) — cold slots are victimized."""
        victims: List[int] = []
        scanned = 0
        limit = (2 + self.HOT_MAX.bit_length()) * self.num_pages
        while len(victims) < want and scanned < limit:
            slot = self.clock_hand
            self.clock_hand = (self.clock_hand + 1) % self.num_pages
            scanned += 1
            if self.key_of[slot] is None or slot in self.writeback \
                    or slot in victims:   # never pick the same slot twice
                continue
            if self.ref_bit[slot]:
                self.ref_bit[slot] = 0
            elif self.hot[slot] > 1:
                self.hot[slot] >>= 1
            else:
                victims.append(slot)
        return victims

    @property
    def num_free(self) -> int:
        return len(self.free)

    def check_invariants(self) -> None:
        installed = {s for s, k in self.key_of.items() if k is not None}
        assert installed.isdisjoint(set(self.free))
        assert len(set(self.free)) == len(self.free)
        assert len(installed) + len(self.free) == self.num_pages
        # flush-before-free: a retiring slot is never free nor unbound
        assert self.writeback <= installed, "WRITEBACK slot without a key"
        assert self.writeback.isdisjoint(set(self.free))
