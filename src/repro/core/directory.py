"""Page-granular cache directory — the paper's Fig. 2/3 as JAX arrays.

The directory is an open-addressed (linear probe + tombstone) hash table held
in flat device arrays, so directory opcodes are jitted batched programs: one
call processes a whole descriptor batch, mirroring the paper's batched FUSE
messages ("each opcode carries a batch of fixed-size 64 B page descriptors").

Entry normal form per slot (the paper's 14 B entry, widened to array lanes):

    keys     [C, 2] int32   (stream_id, page_idx); stream EMPTY/TOMB sentinels
    state    [C]    int32   FREE / E / O / TBI
    owner    [C]    int32   owner node id (paper: 5 b node id)
    sharers  [C, W] uint32  bitmask of S-state nodes (W = ceil(nodes/32))
    pfn      [C]    int32   owner's page-frame number (paper: 52 b PFN)
    dirty    [C]    bool    dirty accumulation (incl. INV_ACK dirty bits)

Batch semantics: descriptors are applied **in order** (a ``fori_loop``), so
two requests for the same absent page in one batch behave exactly like two
serialized directory transactions: first gets E, the second BLOCKED —
"directory operations are atomic at the page level".  Rows whose lane 0 is
negative are inert here: INVALID (-1) pads fixed-capacity batches and
SHOOTDOWN (-3) marks piggybacked TLB-shootdown lanes that only the receiving
node's mapping cache consumes (descriptors.encode_shootdowns).

Placement: these arrays live wherever the caller puts them — replicated on
shard 0 for the paper-faithful *central* directory, or hash-partitioned over
the data axis for the *sharded* default (see core/protocol.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import descriptors as D

# entry states.  TBM is the migration-flavored TBI from the MIGRATE
# transaction (O -> TBM -> E@new_owner): it reuses the invalidation fan-out
# (sharers must tear down mappings into the moving frame and ACK) but keeps a
# distinct code so a concurrent reclaim (O -> TBI) and a concurrent migrate
# can never complete each other's transaction — whichever transition lands
# first wins and the loser observes BLOCKED/BAD.
FREE, E, O, TBI, TBM = 0, 1, 2, 3, 4

EMPTY = -1   # slot never used (probe chains stop here)
TOMB = -2    # slot deleted (probe chains continue past)

# stats vector layout (length 16; indices = status codes where applicable)
N_STATS = 16
STAT_SKIP = 15  # padded descriptor rows count here


class DirectoryConfig(NamedTuple):
    capacity: int            # power of two
    num_nodes: int
    max_probe: int = 128

    @property
    def sharer_words(self) -> int:
        return (self.num_nodes + 31) // 32


class DirectoryState(NamedTuple):
    keys: jax.Array      # [C, 2] int32
    state: jax.Array     # [C] int32
    owner: jax.Array     # [C] int32
    sharers: jax.Array   # [C, W] uint32
    pfn: jax.Array       # [C] int32
    dirty: jax.Array     # [C] bool
    stats: jax.Array     # [N_STATS] int32


def init_directory(cfg: DirectoryConfig) -> DirectoryState:
    c, w = cfg.capacity, cfg.sharer_words
    assert c & (c - 1) == 0, "capacity must be a power of two"
    return DirectoryState(
        keys=jnp.full((c, 2), EMPTY, jnp.int32),
        state=jnp.zeros((c,), jnp.int32),
        owner=jnp.full((c,), -1, jnp.int32),
        sharers=jnp.zeros((c, w), jnp.uint32),
        pfn=jnp.full((c,), -1, jnp.int32),
        dirty=jnp.zeros((c,), bool),
        stats=jnp.zeros((N_STATS,), jnp.int32),
    )


def abstract_directory(cfg: DirectoryConfig):
    """ShapeDtypeStruct tree for dry-runs."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        init_directory(cfg))


# ---------------------------------------------------------------------------
# probe
# ---------------------------------------------------------------------------


def probe(keys: jax.Array, stream: jax.Array, page: jax.Array,
          max_probe: int) -> Tuple[jax.Array, jax.Array]:
    """Linear probe.  Returns (found_slot, insert_slot); -1 = none.

    Stops at a match or at an EMPTY slot; tombstones are remembered as
    insertion candidates but probed past (standard open addressing).
    """
    cap = keys.shape[0]
    h0 = (D.hash_key(stream, page) & jnp.uint32(cap - 1)).astype(jnp.int32)

    def cond(c):
        _, steps, _, _, done = c
        return jnp.logical_and(~done, steps < max_probe)

    def body(c):
        i, steps, found, insert, _ = c
        s = keys[i, 0]
        match = jnp.logical_and(s == stream, keys[i, 1] == page)
        is_empty = s == EMPTY
        is_tomb = s == TOMB
        found = jnp.where(match, i, found)
        insert = jnp.where(jnp.logical_and(insert < 0, is_empty | is_tomb),
                           i, insert)
        done = match | is_empty
        return ((i + 1) & (cap - 1), steps + 1, found, insert, done)

    init = (h0, jnp.int32(0), jnp.int32(-1), jnp.int32(-1), jnp.bool_(False))
    _, _, found, insert, _ = lax.while_loop(cond, body, init)
    return found, insert


def _bit(node: jax.Array, word_idx: jax.Array) -> jax.Array:
    """uint32 bit for ``node`` in sharer word ``word_idx`` (0 elsewhere)."""
    in_word = (node // 32) == word_idx
    return jnp.where(in_word, jnp.uint32(1) << (node % 32).astype(jnp.uint32),
                     jnp.uint32(0))


def _sharer_row_ops(num_words: int):
    widx = jnp.arange(num_words, dtype=jnp.int32)

    def set_bit(row, node):
        return row | _bit(node, widx)

    def clear_bit(row, node):
        return row & ~_bit(node, widx)

    def has_bit(row, node):
        return jnp.any((row & _bit(node, widx)) != 0)

    def empty(row):
        return jnp.all(row == 0)

    return set_bit, clear_bit, has_bit, empty


# ---------------------------------------------------------------------------
# batched opcodes
# ---------------------------------------------------------------------------
# Each op: (DirectoryState, descs [N,4]) -> (DirectoryState, results)
# Results row: (status, owner, pfn) int32.


def _cond_write(arr, slot, value, do):
    """Write ``value`` at ``slot`` iff ``do`` (else rewrite current value)."""
    slot = jnp.where(do, slot, 0)
    cur = arr[slot]
    return arr.at[slot].set(jnp.where(do, value, cur))


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def lookup_and_install(d: DirectoryState, descs: jax.Array,
                       *, max_probe: int = 128):
    """FUSE_DPC_READ: ACC_MISS_ALLOC / ACC_MISS_RMAP / hits / blocked.

    For each valid descriptor:
      absent           -> claim slot in E for requester        (GRANT_E)
      present, E/TBI   -> BLOCKED (retry after transition)
      present, O self  -> HIT_OWNER
      present, O other -> add requester to sharers             (MAP_S / HIT_SHARER)
    """
    n_words = d.sharers.shape[1]
    set_bit, _, has_bit, _ = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, insert = probe(d.keys, stream, page, max_probe)

        present = found >= 0
        st = d.state[jnp.maximum(found, 0)]
        own = d.owner[jnp.maximum(found, 0)]
        row = d.sharers[jnp.maximum(found, 0)]
        cur_pfn = d.pfn[jnp.maximum(found, 0)]

        is_blocked = present & ((st == E) | (st == TBI) | (st == TBM))
        is_owner = present & (st == O) & (own == node)
        already_s = present & (st == O) & (own != node) & has_bit(row, node)
        new_s = present & (st == O) & (own != node) & ~has_bit(row, node)
        can_claim = ~present & (insert >= 0)
        no_room = ~present & (insert < 0)

        status = jnp.where(is_blocked, D.ST_BLOCKED,
                 jnp.where(is_owner, D.ST_HIT_OWNER,
                 jnp.where(already_s, D.ST_HIT_SHARER,
                 jnp.where(new_s, D.ST_MAP_S,
                 jnp.where(can_claim, D.ST_GRANT_E,
                 jnp.where(no_room, D.ST_FULL, D.ST_BAD))))))
        status = jnp.where(valid, status, jnp.int32(STAT_SKIP))

        # --- claim path (GRANT_E): install fresh entry at `insert`
        do_claim = valid & can_claim
        keys = _cond_write(d.keys, insert, jnp.stack([stream, page]), do_claim)
        state = _cond_write(d.state, insert, jnp.int32(E), do_claim)
        owner = _cond_write(d.owner, insert, node, do_claim)
        sharers = _cond_write(d.sharers, insert,
                              jnp.zeros((n_words,), jnp.uint32), do_claim)
        pfn = _cond_write(d.pfn, insert, jnp.int32(-1), do_claim)
        dirty = _cond_write(d.dirty, insert, jnp.bool_(False), do_claim)

        # --- map path (MAP_S): set requester's sharer bit at `found`
        do_map = valid & new_s
        sharers = _cond_write(sharers, found, set_bit(row, node), do_map)

        out_owner = jnp.where(is_owner | already_s | new_s, own,
                    jnp.where(can_claim, node, jnp.int32(-1)))
        out_pfn = jnp.where(is_owner | already_s | new_s, cur_pfn,
                            jnp.int32(-1))
        res = res.at[i].set(jnp.stack([status, out_owner, out_pfn]))

        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (DirectoryState(keys, state, owner, sharers, pfn, dirty, stats),
                res)

    n = descs.shape[0]
    res0 = jnp.zeros((n, 3), jnp.int32)
    d, res = lax.fori_loop(0, n, step, (d, res0))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def map_shared(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """Predictive promotion probe: sharer-map **present** O entries only.

    The prefetch-flavored half of FUSE_DPC_READ: a predicted page that is
    resident gains the requester's sharer bit (MAP_S / HIT_* like the read
    path), but a wrong prediction must cost nothing — an absent key comes
    back ST_BAD with **no claim** (lookup_and_install would allocate an E
    entry the predictor never fills), and an in-transition entry (E / TBI /
    TBM) comes back BLOCKED untouched.  Pure directory transition: frame
    allocation, TLB install, and pool touches stay caller-side.
    """
    n_words = d.sharers.shape[1]
    set_bit, _, has_bit, _ = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0
        found, _ = probe(d.keys, stream, page, max_probe)

        present = found >= 0
        st = d.state[jnp.maximum(found, 0)]
        own = d.owner[jnp.maximum(found, 0)]
        row = d.sharers[jnp.maximum(found, 0)]
        cur_pfn = d.pfn[jnp.maximum(found, 0)]

        is_blocked = present & ((st == E) | (st == TBI) | (st == TBM))
        is_owner = present & (st == O) & (own == node)
        already_s = present & (st == O) & (own != node) & has_bit(row, node)
        new_s = present & (st == O) & (own != node) & ~has_bit(row, node)

        status = jnp.where(is_blocked, D.ST_BLOCKED,
                 jnp.where(is_owner, D.ST_HIT_OWNER,
                 jnp.where(already_s, D.ST_HIT_SHARER,
                 jnp.where(new_s, D.ST_MAP_S, D.ST_BAD))))
        status = jnp.where(valid, status, jnp.int32(STAT_SKIP))

        do_map = valid & new_s
        sharers = _cond_write(d.sharers, found, set_bit(row, node), do_map)

        out_owner = jnp.where(is_owner | already_s | new_s, own,
                              jnp.int32(-1))
        out_pfn = jnp.where(is_owner | already_s | new_s, cur_pfn,
                            jnp.int32(-1))
        res = res.at[i].set(jnp.stack([status, out_owner, out_pfn]))

        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (DirectoryState(d.keys, d.state, d.owner, sharers, d.pfn,
                               d.dirty, stats), res)

    n = descs.shape[0]
    res0 = jnp.zeros((n, 3), jnp.int32)
    d, res = lax.fori_loop(0, n, step, (d, res0))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def commit(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """FUSE_DPC_UNLOCK: COMMIT (E -> O), publish the owner's PFN (aux lane)."""

    def step(i, carry):
        d, res = carry
        stream, page, node, pfn_in = (descs[i, 0], descs[i, 1],
                                      descs[i, 2], descs[i, 3])
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        ok = valid & (found >= 0) & (d.state[slot] == E) & (d.owner[slot] == node)

        state = _cond_write(d.state, found, jnp.int32(O), ok)
        pfn = _cond_write(d.pfn, found, pfn_in, ok)

        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, pfn_in]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(state=state, pfn=pfn, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def abort_install(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """E holder backs out without materializing: entry returns to all-I."""

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        ok = valid & (found >= 0) & (d.state[slot] == E) & (d.owner[slot] == node)

        keys = _cond_write(d.keys, found,
                           jnp.full((2,), TOMB, jnp.int32), ok)
        state = _cond_write(d.state, found, jnp.int32(FREE), ok)

        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, jnp.int32(-1)]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(keys=keys, state=state, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def begin_invalidate(d: DirectoryState, descs: jax.Array,
                     *, max_probe: int = 128):
    """FUSE_DPC_BATCH_INV: owner reclaim, O -> TBI.

    Returns (state, results, sharer_masks [N, W]) — the Invalidation Manager
    fans DIR_INV out to every set bit and collects ACKs.
    """
    n_words = d.sharers.shape[1]

    def step(i, carry):
        d, res, masks = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        ok = valid & (found >= 0) & (d.state[slot] == O) & (d.owner[slot] == node)

        state = _cond_write(d.state, found, jnp.int32(TBI), ok)

        row = jnp.where(ok, d.sharers[slot], jnp.zeros((n_words,), jnp.uint32))
        masks = masks.at[i].set(row)

        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, d.pfn[slot]]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(state=state, stats=stats), res, masks)

    n = descs.shape[0]
    masks0 = jnp.zeros((n, n_words), jnp.uint32)
    d, res, masks = lax.fori_loop(
        0, n, step, (d, jnp.zeros((n, 3), jnp.int32), masks0))
    return d, res, masks


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def ack_invalidate(d: DirectoryState, descs: jax.Array,
                   *, max_probe: int = 128):
    """FUSE_DPC_INV_ACK: a sharer tore down its mapping (aux lane = dirty).

    Accepted in TBI (reclamation) and TBM (migration) — both transactions
    fan DIR_INV out to the same sharer set and drain the same bits."""
    n_words = d.sharers.shape[1]
    _, clear_bit, has_bit, _ = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node, is_dirty = (descs[i, 0], descs[i, 1],
                                        descs[i, 2], descs[i, 3])
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        row = d.sharers[slot]
        in_teardown = (d.state[slot] == TBI) | (d.state[slot] == TBM)
        ok = valid & (found >= 0) & in_teardown & has_bit(row, node)

        sharers = _cond_write(d.sharers, found, clear_bit(row, node), ok)
        dirty = _cond_write(d.dirty, found,
                            d.dirty[slot] | (is_dirty != 0), ok)

        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, jnp.int32(-1)]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(sharers=sharers, dirty=dirty, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def complete_invalidate(d: DirectoryState, descs: jax.Array,
                        *, max_probe: int = 128):
    """INVALIDATION_ACK: all sharers gone -> entry removed (TBI -> all-I).

    Result pfn lane carries the writeback flag (1 = page was dirty somewhere:
    owner must write back before freeing the frame).
    BLOCKED is returned while sharer ACKs are still outstanding.
    """
    n_words = d.sharers.shape[1]
    _, _, _, empty = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        in_tbi = valid & (found >= 0) & (d.state[slot] == TBI) & \
            (d.owner[slot] == node)
        done = in_tbi & empty(d.sharers[slot])

        wb = jnp.where(done & d.dirty[slot], jnp.int32(1), jnp.int32(0))

        keys = _cond_write(d.keys, found, jnp.full((2,), TOMB, jnp.int32), done)
        state = _cond_write(d.state, found, jnp.int32(FREE), done)
        dirty = _cond_write(d.dirty, found, jnp.bool_(False), done)
        pfn = _cond_write(d.pfn, found, jnp.int32(-1), done)

        status = jnp.where(~valid, jnp.int32(STAT_SKIP),
                 jnp.where(done, D.ST_OK,
                 jnp.where(in_tbi, D.ST_BLOCKED, D.ST_BAD)))
        res = res.at[i].set(jnp.stack([status, node, wb]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(keys=keys, state=state, dirty=dirty, pfn=pfn,
                           stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def begin_migrate(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """FUSE_DPC_MIGRATE: hand ownership to the descriptor's node (lane 2).

    O -> TBM when the destination differs from the current owner.  Returns
    (state, results, sharer_masks [N, W]): results carry (status, old_owner,
    old_pfn) — the frame the destination must copy from — and the masks are
    the DIR_INV fan-out (every sharer maps the *moving* frame and must tear
    down + ACK before the hand-off completes; the destination itself is
    usually in that set — that is exactly the hot-page case).

      absent                  -> BAD        (nothing to migrate)
      O, owner == dst         -> HIT_OWNER  (no-op: already home)
      O, owner != dst         -> OK         (transition to TBM)
      E / TBI / TBM           -> BLOCKED    (transaction in flight; retry)
    """
    n_words = d.sharers.shape[1]

    def step(i, carry):
        d, res, masks = carry
        stream, page, dst = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        st = d.state[slot]
        own = d.owner[slot]

        present = valid & (found >= 0)
        is_noop = present & (st == O) & (own == dst)
        ok = present & (st == O) & (own != dst)
        busy = present & ((st == E) | (st == TBI) | (st == TBM))

        state = _cond_write(d.state, found, jnp.int32(TBM), ok)

        row = jnp.where(ok, d.sharers[slot], jnp.zeros((n_words,), jnp.uint32))
        masks = masks.at[i].set(row)

        status = jnp.where(~valid, jnp.int32(STAT_SKIP),
                 jnp.where(ok, D.ST_OK,
                 jnp.where(is_noop, D.ST_HIT_OWNER,
                 jnp.where(busy, D.ST_BLOCKED, D.ST_BAD))))
        res = res.at[i].set(jnp.stack([status, own, d.pfn[slot]]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(state=state, stats=stats), res, masks)

    n = descs.shape[0]
    masks0 = jnp.zeros((n, n_words), jnp.uint32)
    d, res, masks = lax.fori_loop(
        0, n, step, (d, jnp.zeros((n, 3), jnp.int32), masks0))
    return d, res, masks


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def complete_migrate(d: DirectoryState, descs: jax.Array,
                     *, max_probe: int = 128):
    """MIGRATION_ACK: all sharer ACKs in -> TBM -> E@new_owner.

    Descriptor: lane 2 = new owner, aux lane = expected old owner (the host
    transaction token — a completion races nothing because TBM entries only
    ever belong to one in-flight MIGRATE).  The entry re-enters E exactly as
    a fresh install would (pfn unpublished): the new owner materializes the
    copy from the old frame and then runs the ordinary COMMIT (E -> O).
    Passing new_owner == old_owner is the abort path (ownership stays put).
    The result pfn lane carries the accumulated dirty bit so writeback
    obligations travel with ownership.  BLOCKED while ACKs are outstanding.
    """
    n_words = d.sharers.shape[1]
    _, _, _, empty = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, dst, old = (descs[i, 0], descs[i, 1],
                                  descs[i, 2], descs[i, 3])
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        in_tbm = valid & (found >= 0) & (d.state[slot] == TBM) & \
            (d.owner[slot] == old)
        done = in_tbm & empty(d.sharers[slot])

        was_dirty = jnp.where(done & d.dirty[slot], jnp.int32(1),
                              jnp.int32(0))
        state = _cond_write(d.state, found, jnp.int32(E), done)
        owner = _cond_write(d.owner, found, dst, done)
        pfn = _cond_write(d.pfn, found, jnp.int32(-1), done)

        status = jnp.where(~valid, jnp.int32(STAT_SKIP),
                 jnp.where(done, D.ST_OK,
                 jnp.where(in_tbm, D.ST_BLOCKED, D.ST_BAD)))
        res = res.at[i].set(jnp.stack([status, dst, was_dirty]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(state=state, owner=owner, pfn=pfn, stats=stats),
                res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def sharer_drop(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """Sharer-side LOCAL_INV: voluntarily drop a remote mapping (aux=dirty)."""
    n_words = d.sharers.shape[1]
    _, clear_bit, has_bit, _ = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node, is_dirty = (descs[i, 0], descs[i, 1],
                                        descs[i, 2], descs[i, 3])
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        row = d.sharers[slot]
        ok = valid & (found >= 0) & has_bit(row, node)

        sharers = _cond_write(d.sharers, found, clear_bit(row, node), ok)
        dirty = _cond_write(d.dirty, found,
                            d.dirty[slot] | (is_dirty != 0), ok)

        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, jnp.int32(-1)]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(sharers=sharers, dirty=dirty, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def mark_dirty(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """A write through an established O/S mapping dirties the page."""
    n_words = d.sharers.shape[1]
    _, _, has_bit, _ = _sharer_row_ops(n_words)

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        mapped = (d.owner[slot] == node) | has_bit(d.sharers[slot], node)
        ok = valid & (found >= 0) & (d.state[slot] == O) & mapped

        dirty = _cond_write(d.dirty, found, jnp.bool_(True), ok)
        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, jnp.int32(-1)]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(dirty=dirty, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, static_argnames=("max_probe",), donate_argnums=0)
def clear_dirty(d: DirectoryState, descs: jax.Array, *, max_probe: int = 128):
    """CLEAR_DIRTY: the owner persisted the page's bytes out-of-band.

    A migration hand-off checkpoints the moving frame into the writeback
    queue, but ``complete_migrate`` deliberately carries the dirty bit to the
    new owner; without this opcode the migrated page would pay a second
    writeback on its next eviction.  Only the current owner of an O entry may
    clear it.  Result pfn lane carries the previous dirty bit.
    """

    def step(i, carry):
        d, res = carry
        stream, page, node = descs[i, 0], descs[i, 1], descs[i, 2]
        valid = stream >= 0  # skips INVALID padding + SHOOTDOWN lanes
        found, _ = probe(d.keys, stream, page, max_probe)
        slot = jnp.maximum(found, 0)
        ok = valid & (found >= 0) & (d.state[slot] == O) & \
            (d.owner[slot] == node)

        was = jnp.where(ok & d.dirty[slot], jnp.int32(1), jnp.int32(0))
        dirty = _cond_write(d.dirty, found, jnp.bool_(False), ok)
        status = jnp.where(valid, jnp.where(ok, D.ST_OK, D.ST_BAD),
                           jnp.int32(STAT_SKIP))
        res = res.at[i].set(jnp.stack([status, node, was]))
        stats = d.stats.at[jnp.minimum(status, N_STATS - 1)].add(1)
        return (d._replace(dirty=dirty, stats=stats), res)

    n = descs.shape[0]
    d, res = lax.fori_loop(0, n, step, (d, jnp.zeros((n, 3), jnp.int32)))
    return d, res


@functools.partial(jax.jit, donate_argnums=0)
def fail_node(d: DirectoryState, node: jax.Array):
    """Liveness (paper §5): drop a failed node from the whole directory.

    Entries it owned are removed (lost clean cache state, capacity shrink);
    its sharer bits are cleared everywhere so pending invalidations can
    complete without its ACKs.  Vectorized over the full table.
    """
    n_words = d.sharers.shape[1]
    widx = jnp.arange(n_words, dtype=jnp.int32)
    bit = _bit(node, widx)  # [W]

    owned = (d.owner == node) & (d.state != FREE)
    keys = jnp.where(owned[:, None], jnp.full_like(d.keys, TOMB), d.keys)
    state = jnp.where(owned, jnp.int32(FREE), d.state)
    pfn = jnp.where(owned, jnp.int32(-1), d.pfn)
    dirty = jnp.where(owned, False, d.dirty)
    sharers = d.sharers & ~bit[None, :]
    n_owned = jnp.sum(owned.astype(jnp.int32))
    return d._replace(keys=keys, state=state, pfn=pfn, dirty=dirty,
                      sharers=sharers), n_owned


# ---------------------------------------------------------------------------
# host-side views (tests / debugging)
# ---------------------------------------------------------------------------


def to_host_dict(d: DirectoryState, cfg: DirectoryConfig):
    """Extract {(stream, page): (state, owner, sharers, pfn, dirty)}."""
    import numpy as np
    keys = np.asarray(d.keys)
    state = np.asarray(d.state)
    owner = np.asarray(d.owner)
    sharers = np.asarray(d.sharers)
    pfn = np.asarray(d.pfn)
    dirty = np.asarray(d.dirty)
    out = {}
    for i in range(cfg.capacity):
        if keys[i, 0] >= 0 and state[i] != FREE:
            mask = set()
            for w in range(cfg.sharer_words):
                bits = int(sharers[i, w])
                for b in range(32):
                    if bits & (1 << b):
                        mask.add(w * 32 + b)
            out[(int(keys[i, 0]), int(keys[i, 1]))] = (
                int(state[i]), int(owner[i]), mask, int(pfn[i]), bool(dirty[i]))
    return out


def occupancy(d: DirectoryState) -> jax.Array:
    return jnp.sum((d.keys[:, 0] >= 0) & (d.state != FREE))
