"""ship_compute datapath — beyond-paper DPC remote reads on TPU.

Under CXL the consumer's CPU always pulls page *bytes*.  On a TPU mesh we can
instead ship the (tiny) queries to each page's owner, compute partial
flash-decode attention there, and combine partials with a log-sum-exp
reduction — collective bytes drop from O(context KV) to O(q + o) per step.

Layout (DESIGN.md §5): pool slot dim sharded over every DPC axis
(pod × data × model), so each chip is one DPC node owning a disjoint slice of
pages; pages are fully self-contained (all kv heads).  The page table carries
*global* page ids (node * P_local + slot); each node resolves its own slice
and masks the rest — exactly the directory's owner/PFN resolution.

The LSE combine is an all_reduce (bytes independent of node count), not an
all_gather of partials (bytes linear in node count).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels import dispatch

NEG_INF = -1e30


def _axis_size(axes) -> int:
    # psum of a literal constant-folds to a python int under shard_map —
    # the portable axis-size idiom (lax.axis_size needs jax >= 0.5)
    import numpy as np
    return int(np.prod([jax.lax.psum(1, a) for a in axes]))


def _my_node(dpc_axes: Sequence[str]) -> jax.Array:
    """Linearized DPC node id of this shard (row-major over dpc_axes)."""
    node = jnp.int32(0)
    for ax in dpc_axes:
        node = node * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return node


def localize_table(page_table: jax.Array, my_node: jax.Array,
                   pool_pages: int) -> jax.Array:
    """Global page ids -> local slots on this node (-1 elsewhere)."""
    owner = page_table // pool_pages
    slot = page_table % pool_pages
    mine = (page_table >= 0) & (owner == my_node)
    return jnp.where(mine, slot, -1)


def lse_combine_allreduce(o, m, l, axes, wire_dtype=None):
    """Exact softmax combination of per-node partials via all_reduce.

    o: [B, H, D] float32 partial outputs (already normalized by local l);
    m, l: [B, H].  Returns combined o (replicated over ``axes``).

    ``wire_dtype`` (§Perf iteration C2): the big o-partial all_reduce crosses
    the fabric in the cache's storage dtype (bf16 in production) — halving
    combine bytes; the tiny m/l reductions stay f32 for exactness.
    """
    m_star = jax.lax.pmax(m, axes)
    w = l * jnp.exp(m - m_star)                       # [B, H]
    sum_w = jax.lax.psum(w, axes)
    ow = o * w[..., None]
    if wire_dtype is not None and jnp.dtype(wire_dtype) != jnp.float32:
        ow = ow.astype(wire_dtype)
        ow = jax.lax.optimization_barrier(ow)  # keep the wire in this dtype
    o_sum = jax.lax.psum(ow, axes).astype(jnp.float32)
    return o_sum / jnp.maximum(sum_w, 1e-20)[..., None]


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def make_dpc_attend(mesh: Mesh, *, batch_axes=("pod", "data"),
                    head_axis="model", pool_pages: int,
                    impl: str = "auto"):
    """Returns attend(q, k_new, v_new, k_pool, v_pool, page_table, seq_lens,
    append_slot) with DPC ship_compute semantics.

    Shardings (global views):
      q          [B, Hq, D]     batch over batch_axes, heads over head_axis
      k_new/v_new[B, Hkv, D]    batch over batch_axes, heads replicated
      pools      [Pg, page, Hkv, D]  slots over ALL dpc axes
      page_table [B, N] global ids; seq_lens/append_slot [B] (global ids)
    """
    dpc_axes = tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    b_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)

    def attend(q, k_new, v_new, k_pool, v_pool, page_table, seq_lens,
               append_slot):
        me = _my_node(dpc_axes)

        # --- gather the (tiny) per-request metadata + new-token KV so that
        # whichever node owns a request's filling page performs the install
        kn_all, vn_all = k_new, v_new
        pt_all, sl_all, ap_all = page_table, seq_lens, append_slot
        for ax in reversed(b_axes):
            kn_all = jax.lax.all_gather(kn_all, ax, axis=0, tiled=True)
            vn_all = jax.lax.all_gather(vn_all, ax, axis=0, tiled=True)
            pt_all = jax.lax.all_gather(pt_all, ax, axis=0, tiled=True)
            sl_all = jax.lax.all_gather(sl_all, ax, axis=0, tiled=True)
            ap_all = jax.lax.all_gather(ap_all, ax, axis=0, tiled=True)

        # --- owner-side append of the new token (single-copy: one writer;
        # non-local rows are routed out of bounds and dropped)
        page = k_pool.shape[1]
        local = (ap_all >= 0) & (ap_all // pool_pages == me)
        slot = jnp.where(local, ap_all % pool_pages, pool_pages)
        off = sl_all % page
        k_pool = k_pool.at[slot, off].set(kn_all.astype(k_pool.dtype),
                                          mode="drop")
        v_pool = v_pool.at[slot, off].set(vn_all.astype(v_pool.dtype),
                                          mode="drop")

        # --- ship queries: gather heads over TP, batch over DP
        q_all = q
        if head_axis in mesh.axis_names:
            q_all = jax.lax.all_gather(q_all, head_axis, axis=1, tiled=True)
        for ax in reversed(b_axes):
            q_all = jax.lax.all_gather(q_all, ax, axis=0, tiled=True)

        # --- owner-side partial attention over the local slice
        pt_local = localize_table(pt_all, me, pool_pages)
        out, (m, l) = dispatch.paged_attention(
            q_all, k_pool, v_pool, pt_local, sl_all + 1, impl=impl,
            with_stats=True)

        # --- LSE combine across every owner, then take my slice back
        o = lse_combine_allreduce(out.astype(jnp.float32), m, l, dpc_axes,
                                  wire_dtype=q.dtype)

        b_loc = q.shape[0]
        h_loc = q.shape[1]
        b_idx = jnp.int32(0)
        for ax in b_axes:
            b_idx = b_idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        o = jax.lax.dynamic_slice_in_dim(o, b_idx * b_loc, b_loc, 0)
        if head_axis in mesh.axis_names:
            h_idx = jax.lax.axis_index(head_axis)
            o = jax.lax.dynamic_slice_in_dim(o, h_idx * h_loc, h_loc, 1)
        return o.astype(q.dtype), k_pool, v_pool

    batch_p = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    head_p = head_axis if head_axis in mesh.axis_names else None
    dpc_p = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]

    return shard_map(
        attend, mesh=mesh,
        in_specs=(
            P(batch_p, head_p, None),            # q
            P(batch_p, None, None),              # k_new (replicated heads)
            P(batch_p, None, None),              # v_new
            P(dpc_p, None, None, None),          # k_pool
            P(dpc_p, None, None, None),          # v_pool
            P(batch_p, None),                    # page_table
            P(batch_p),                          # seq_lens
            P(batch_p),                          # append_slot
        ),
        out_specs=(
            P(batch_p, head_p, None),            # out
            P(dpc_p, None, None, None),
            P(dpc_p, None, None, None),
        ),
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# MLA (latent pages)
# ---------------------------------------------------------------------------


def make_dpc_attend_mla(mesh: Mesh, *, batch_axes=("pod", "data"),
                        head_axis="model", pool_pages: int,
                        impl: str = "auto", sm_scale=None):
    """attend(q_latent, q_rope, latent_new, pool, page_table, seq_lens,
    append_slot) over latent pages [Pg, page, R+Dr]."""
    dpc_axes = tuple(ax for ax in ("pod", "data", "model")
                     if ax in mesh.axis_names)
    b_axes = tuple(ax for ax in batch_axes if ax in mesh.axis_names)

    def attend(q_latent, q_rope, latent_new, pool, page_table, seq_lens,
               append_slot):
        me = _my_node(dpc_axes)
        page = pool.shape[1]

        ln_all = latent_new
        pt_all, sl_all, ap_all = page_table, seq_lens, append_slot
        for ax in reversed(b_axes):
            ln_all = jax.lax.all_gather(ln_all, ax, axis=0, tiled=True)
            pt_all = jax.lax.all_gather(pt_all, ax, axis=0, tiled=True)
            sl_all = jax.lax.all_gather(sl_all, ax, axis=0, tiled=True)
            ap_all = jax.lax.all_gather(ap_all, ax, axis=0, tiled=True)

        local = (ap_all >= 0) & (ap_all // pool_pages == me)
        slot = jnp.where(local, ap_all % pool_pages, pool_pages)
        off = sl_all % page
        pool = pool.at[slot, off].set(ln_all.astype(pool.dtype), mode="drop")

        ql, qr = q_latent, q_rope
        if head_axis in mesh.axis_names:
            ql = jax.lax.all_gather(ql, head_axis, axis=1, tiled=True)
            qr = jax.lax.all_gather(qr, head_axis, axis=1, tiled=True)
        for ax in reversed(b_axes):
            ql = jax.lax.all_gather(ql, ax, axis=0, tiled=True)
            qr = jax.lax.all_gather(qr, ax, axis=0, tiled=True)

        pt_local = localize_table(pt_all, me, pool_pages)
        out, (m, l) = dispatch.mla_paged_attention(
            ql, qr, pool, pt_local, sl_all + 1, impl=impl, with_stats=True,
            sm_scale=sm_scale)
        o = lse_combine_allreduce(out.astype(jnp.float32), m, l, dpc_axes,
                                  wire_dtype=q_latent.dtype)

        b_loc, h_loc = q_latent.shape[0], q_latent.shape[1]
        b_idx = jnp.int32(0)
        for ax in b_axes:
            b_idx = b_idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        o = jax.lax.dynamic_slice_in_dim(o, b_idx * b_loc, b_loc, 0)
        if head_axis in mesh.axis_names:
            h_idx = jax.lax.axis_index(head_axis)
            o = jax.lax.dynamic_slice_in_dim(o, h_idx * h_loc, h_loc, 1)
        return o.astype(q_latent.dtype), pool

    batch_p = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)
    head_p = head_axis if head_axis in mesh.axis_names else None
    dpc_p = dpc_axes if len(dpc_axes) > 1 else dpc_axes[0]

    return shard_map(
        attend, mesh=mesh,
        in_specs=(
            P(batch_p, head_p, None),
            P(batch_p, head_p, None),
            P(batch_p, None),
            P(dpc_p, None, None),
            P(batch_p, None),
            P(batch_p),
            P(batch_p),
        ),
        out_specs=(
            P(batch_p, head_p, None),
            P(dpc_p, None, None),
        ),
        check_rep=False,
    )


class DPCBackend:
    """Model-facing backend (same interface as cache.LocalBackend) that routes
    attention through the DPC ship_compute datapath."""

    def __init__(self, mesh: Mesh, page_table, seq_lens, append_slot, *,
                 pool_pages: int, batch_axes=("pod", "data"),
                 head_axis="model", impl="auto", sm_scale=None):
        self.page_table = page_table
        self.seq_lens = seq_lens
        self.append_slot = append_slot
        self._attend = make_dpc_attend(
            mesh, batch_axes=batch_axes, head_axis=head_axis,
            pool_pages=pool_pages, impl=impl)
        self._attend_mla_cache = {}
        self._mesh = mesh
        self._kw = dict(batch_axes=batch_axes, head_axis=head_axis,
                        pool_pages=pool_pages, impl=impl)

    def attend(self, q, k_new, v_new, k_pool, v_pool):
        return self._attend(q, k_new, v_new, k_pool, v_pool,
                            self.page_table, self.seq_lens, self.append_slot)

    def attend_mla(self, q_latent, q_rope, latent_new, latent_pool, *,
                   sm_scale=None):
        key = float(sm_scale) if sm_scale is not None else None
        if key not in self._attend_mla_cache:
            self._attend_mla_cache[key] = make_dpc_attend_mla(
                self._mesh, sm_scale=sm_scale, **self._kw)
        out, pool = self._attend_mla_cache[key](
            q_latent, q_rope, latent_new, latent_pool,
            self.page_table, self.seq_lens, self.append_slot)
        return out, pool
