"""DPC core — the paper's contribution: a distributed page cache with a
page-granular directory (I/E/O/S/TBI), single-copy invariant, deterministic
reclamation, and strong/relaxed coherence modes, in JAX arrays.

Layers:
  descriptors  packed batched page descriptors (the 64 B FUSE descriptor)
  directory    open-addressed hash directory + batched opcodes
  pagepool     per-node frame pool + CLOCK reclamation
  protocol     composite event flows (read/write/reclaim/liveness)
  coherence    dpc / dpc_sc / replicated / local_only write policies
  refimpl      pure-Python executable spec (property-test oracle + host tier)
  remote_read  ship_data datapath (page fetch over ICI, paper-faithful)
  ship_compute beyond-paper datapath (owner-side partial attention + LSE)
  dpc_cache    DistributedKVCache facade used by the serving engine
"""
