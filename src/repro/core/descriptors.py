"""Packed page descriptors — the TPU analog of the paper's 64 B FUSE descriptors.

Every directory opcode (Table 1) carries a *batch* of fixed-size descriptors so
many pages are handled per round trip.  On device a descriptor is a 4-lane
int32 row::

    lane 0  stream_id   content-addressed group ("inode"): prefix hash / file id
    lane 1  page_idx    logical page index within the stream ("file offset")
    lane 2  node        requesting / acknowledging DPC node id
    lane 3  aux         pfn on COMMIT, dirty bit on INV_ACK, flags otherwise

Invalid rows are marked with ``stream_id == INVALID`` so fixed-capacity batches
can be padded (the directory skips them), mirroring the paper's batched
virtqueue messages.

Piggybacked shootdown lanes (paper §4.3 batching): queued TLB shootdowns for
a node ride the next opcode batch routed on that node's behalf instead of
being drained in-process.  A shootdown row reuses the 4-lane layout with a
distinct lane-0 sentinel so every directory opcode treats it as inert::

    lane 0  SHOOTDOWN   (-3) sentinel — directory ops skip the row
    lane 1  page_idx    logical page index of the mapping to drop
    lane 2  node        the *target* node whose TLB entry dies
    lane 3  stream_id   stream of the mapping to drop (aux lane repurposed)

The receiving node services these lanes (drops the cached mappings) before
executing the batch's own descriptors — see core/protocol.py ``_routed`` and
core/tlb.py ``deliver``.

The async data plane adds two more lane kinds on the same sentinel scheme
(core/protocol.py posts them, batches routed on the target node's behalf
carry them, and every directory opcode skips them as inert rows)::

    lane 0  COPY        (-4) migration KV copy obligation
    lane 1  src_pfn     global frame the bytes still live in
    lane 2  node        destination node (the lane rides its batches)
    lane 3  dst_pfn     global frame the bytes land in

    lane 0  FLUSH       (-5) deferred writeback-capture obligation
    lane 1  page_idx    logical page index of the evicted key
    lane 2  node        owner node whose retired frame holds the bytes
    lane 3  stream_id   stream of the evicted key
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.int32(-1)
SHOOTDOWN = jnp.int32(-3)   # lane-0 sentinel: piggybacked TLB shootdown row
COPY = jnp.int32(-4)        # lane-0 sentinel: migration KV copy obligation
FLUSH = jnp.int32(-5)       # lane-0 sentinel: deferred writeback capture
N_LANES = 4

LANE_STREAM = 0
LANE_PAGE = 1
LANE_NODE = 2
LANE_AUX = 3


def encode_shootdowns(triples) -> np.ndarray:
    """Encode (target_node, stream, page) triples as piggyback lane rows.

    Returns a [K, 4] int32 array appendable to any opcode batch; directory
    ops skip the rows (negative lane 0), the target node's TLB services them
    before the batch's own descriptors execute.
    """
    rows = np.full((len(triples), N_LANES), int(INVALID), np.int32)
    for i, (node, stream, page) in enumerate(triples):
        rows[i, LANE_STREAM] = int(SHOOTDOWN)
        rows[i, LANE_PAGE] = page
        rows[i, LANE_NODE] = node
        rows[i, LANE_AUX] = stream
    return rows


def decode_shootdowns(rows: np.ndarray):
    """Inverse of ``encode_shootdowns``: [K, 4] -> (node, stream, page)
    triples, ignoring any non-shootdown rows."""
    out = []
    for row in np.asarray(rows):
        if int(row[LANE_STREAM]) == int(SHOOTDOWN):
            out.append((int(row[LANE_NODE]), int(row[LANE_AUX]),
                        int(row[LANE_PAGE])))
    return out

def encode_copies(triples) -> np.ndarray:
    """Encode (dst_node, src_pfn, dst_pfn) migration-copy obligations as
    lane rows appendable to any opcode batch (directory-inert)."""
    rows = np.full((len(triples), N_LANES), int(INVALID), np.int32)
    for i, (node, src_pfn, dst_pfn) in enumerate(triples):
        rows[i, LANE_STREAM] = int(COPY)
        rows[i, LANE_PAGE] = src_pfn
        rows[i, LANE_NODE] = node
        rows[i, LANE_AUX] = dst_pfn
    return rows


def decode_copies(rows: np.ndarray):
    """Inverse of ``encode_copies``: [K, 4] -> (dst_node, src_pfn, dst_pfn)
    triples, ignoring any non-COPY rows."""
    out = []
    for row in np.asarray(rows):
        if int(row[LANE_STREAM]) == int(COPY):
            out.append((int(row[LANE_NODE]), int(row[LANE_PAGE]),
                        int(row[LANE_AUX])))
    return out


def encode_flushes(triples) -> np.ndarray:
    """Encode (owner_node, stream, page) deferred writeback-capture
    obligations as lane rows (same layout as shootdown rows)."""
    rows = np.full((len(triples), N_LANES), int(INVALID), np.int32)
    for i, (node, stream, page) in enumerate(triples):
        rows[i, LANE_STREAM] = int(FLUSH)
        rows[i, LANE_PAGE] = page
        rows[i, LANE_NODE] = node
        rows[i, LANE_AUX] = stream
    return rows


def decode_flushes(rows: np.ndarray):
    """Inverse of ``encode_flushes``: [K, 4] -> (node, stream, page)
    triples, ignoring any non-FLUSH rows."""
    out = []
    for row in np.asarray(rows):
        if int(row[LANE_STREAM]) == int(FLUSH):
            out.append((int(row[LANE_NODE]), int(row[LANE_AUX]),
                        int(row[LANE_PAGE])))
    return out


# Status codes returned per descriptor by directory ops (mirrors Fig. 2 events)
ST_OK = 0            # op applied
ST_GRANT_E = 1       # ACC_MISS_ALLOC: requester must materialize ("fetch")
ST_MAP_S = 2         # ACC_MISS_RMAP: remote hit — (owner, pfn) returned
ST_HIT_OWNER = 3     # requester already owns the page
ST_HIT_SHARER = 4    # requester already maps the page
ST_BLOCKED = 5       # page in E or TBI: retry after transition completes
ST_FULL = 6          # directory at capacity (no insert slot within max probe)
ST_BAD = 7           # protocol violation (e.g. COMMIT while not in E)

STATUS_NAMES = {
    ST_OK: "OK", ST_GRANT_E: "GRANT_E", ST_MAP_S: "MAP_S",
    ST_HIT_OWNER: "HIT_OWNER", ST_HIT_SHARER: "HIT_SHARER",
    ST_BLOCKED: "BLOCKED", ST_FULL: "FULL", ST_BAD: "BAD",
}


def make_batch(streams, pages, nodes, aux=None) -> jax.Array:
    """Build a [N, 4] int32 descriptor batch."""
    streams = jnp.asarray(streams, jnp.int32)
    pages = jnp.asarray(pages, jnp.int32)
    nodes = jnp.broadcast_to(jnp.asarray(nodes, jnp.int32), streams.shape)
    if aux is None:
        aux = jnp.zeros_like(streams)
    else:
        aux = jnp.broadcast_to(jnp.asarray(aux, jnp.int32), streams.shape)
    return jnp.stack([streams, pages, nodes, aux], axis=-1)


def pad_batch(batch: jax.Array, capacity: int) -> jax.Array:
    """Pad a [N, 4] batch to [capacity, 4] with INVALID rows."""
    n = batch.shape[0]
    if n == capacity:
        return batch
    assert n < capacity, f"batch {n} exceeds capacity {capacity}"
    pad = jnp.full((capacity - n, N_LANES), INVALID, jnp.int32)
    return jnp.concatenate([batch, pad], axis=0)


def hash_key(stream: jax.Array, page: jax.Array) -> jax.Array:
    """fxhash-style 32-bit mix of (stream, page) — the directory probe hash.

    Works on int32 (no x64 requirement); the same constants are used by the
    Pallas ``directory_probe`` kernel and the Python refimpl so all three
    agree on slot placement.
    """
    h = stream.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = h ^ (page.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 13)
    return h


def hash_key_py(stream: int, page: int) -> int:
    """Python mirror of ``hash_key`` (used by refimpl)."""
    mask = 0xFFFFFFFF
    h = (stream * 0x9E3779B9) & mask
    h ^= (page * 0x85EBCA6B) & mask
    h ^= h >> 16
    h = (h * 0xC2B2AE35) & mask
    h ^= h >> 13
    return h


def global_page_id(node: int, slot: int, pool_pages: int) -> int:
    """Linearized cluster-wide physical frame number ("owner PFN")."""
    return node * pool_pages + slot


def split_page_id(pid, pool_pages: int) -> Tuple[jax.Array, jax.Array]:
    return pid // pool_pages, pid % pool_pages


def stream_hash_from_tokens(tokens: np.ndarray, upto: int) -> int:
    """Content-addressed stream id for a token prefix (host-side).

    DPC keys file pages by (inode, offset); the serving analog keys KV pages
    by (prefix content hash, page index) so identical prefixes on different
    replicas resolve to the same directory entries.
    """
    h = 0x811C9DC5
    for t in np.asarray(tokens[:upto]).tolist():
        h = ((h ^ (t & 0xFFFF)) * 0x01000193) & 0x7FFFFFFF
    return h or 1
