"""Per-node physical page pool + CLOCK reclamation (paper §4.3, JAX arrays).

Each DPC node owns a pool of physical page frames (pool slots).  The pool
tracks, per slot, the logical key installed there (reverse map for
invalidation), a CLOCK reference bit (second-chance LRU, standing in for the
kernel's LRU lists), a decaying hotness counter (access frequency feeding the
ownership-migration policy), and a free stack.  "Local reclaim" = CLOCK scan
picks victims -> protocol issues LOCAL_INV batches -> frames freed only after
the directory's INVALIDATION_ACK — never unilaterally (deterministic
reclamation).

Hotness: ``touch`` both sets the CLOCK ref bit and bumps a saturating per-slot
counter; ``decay_hot`` halves every counter (called on a period by the
migration manager).  ``clock_scan`` consumes it GCLOCK-style: a slot whose
ref bit is clear but whose counter is still high is aged (halved) and spared
for the pass, so frequently-hit frames resist eviction beyond the one-bit
second chance.  The cap is kept small (HOT_MAX) so a formerly-hot slot ages
out within a couple of scan revolutions — reclamation can never be starved
by stale heat.  The counter is the *local* access-frequency signal; the
remote-access signal that actually drives promotion lives in the hotness
ledger (core/migration.py) because remote reads never touch the owner's pool.

All ops are functional and jitted; slot state lives on device next to the KV
pool it indexes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import descriptors as D

EMPTY = -1

# slot lifecycle: FREE -> RESERVED (E grant, being installed) -> INSTALLED
# -> DRAINING (TBI, invalidation in flight) -> FREE for clean frames, or
# -> WRITEBACK (flush obligation enqueued, frame pinned) -> FREE for dirty
# ones.  The WRITEBACK hop is the flush-before-free invariant: a dirty
# frame's only copy is being persisted and the slot must not be reusable
# until the WritebackQueue's batch sync commits (repro/storage).
S_FREE, S_RESERVED, S_INSTALLED, S_DRAINING, S_WRITEBACK = 0, 1, 2, 3, 4


HOT_MAX = 8  # hotness saturation: log2(HOT_MAX) scan passes age any slot out


class PoolState(NamedTuple):
    key_of: jax.Array     # [P, 2] int32 (stream, page) or EMPTY
    slot_state: jax.Array  # [P] int32 (S_*)
    ref: jax.Array        # [P] int8 CLOCK reference bit
    hot: jax.Array        # [P] int32 decaying access-frequency counter
    free_stack: jax.Array  # [P] int32
    free_top: jax.Array   # scalar int32: stack[0:top] are free slots
    hand: jax.Array       # scalar int32 CLOCK hand


def init_pool(num_pages: int) -> PoolState:
    return PoolState(
        key_of=jnp.full((num_pages, 2), EMPTY, jnp.int32),
        slot_state=jnp.zeros((num_pages,), jnp.int32),
        ref=jnp.zeros((num_pages,), jnp.int8),
        hot=jnp.zeros((num_pages,), jnp.int32),
        free_stack=jnp.arange(num_pages - 1, -1, -1, dtype=jnp.int32),
        free_top=jnp.int32(num_pages),
        hand=jnp.int32(0),
    )


def abstract_pool(num_pages: int):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        init_pool(num_pages))


@functools.partial(jax.jit, donate_argnums=0)
def alloc(pool: PoolState, want: jax.Array) -> Tuple[PoolState, jax.Array]:
    """Pop up to len(want) slots; want[i] masks row i.  Returns slots (-1 if
    none free / not wanted).  Slots come back RESERVED (the E state's
    "exclusive right to install the next resident copy")."""
    n = want.shape[0]

    def step(i, carry):
        pool, out = carry
        can = want[i] & (pool.free_top > 0)
        top = pool.free_top - 1
        slot = pool.free_stack[jnp.maximum(top, 0)]
        slot = jnp.where(can, slot, jnp.int32(-1))
        free_top = jnp.where(can, top, pool.free_top)
        ss = jnp.where(can, pool.slot_state.at[jnp.maximum(slot, 0)]
                       .set(S_RESERVED), pool.slot_state)
        ref = jnp.where(can, pool.ref.at[jnp.maximum(slot, 0)].set(1), pool.ref)
        hot = jnp.where(can, pool.hot.at[jnp.maximum(slot, 0)].set(1), pool.hot)
        out = out.at[i].set(slot)
        return pool._replace(slot_state=ss, ref=ref, hot=hot,
                             free_top=free_top), out

    out0 = jnp.full((n,), -1, jnp.int32)
    return lax.fori_loop(0, n, step, (pool, out0))


@functools.partial(jax.jit, donate_argnums=0)
def install(pool: PoolState, slots: jax.Array, keys: jax.Array) -> PoolState:
    """RESERVED -> INSTALLED: bind keys [N,2] to slots [N] (COMMIT time).
    Rows with slot < 0 are skipped."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    cur_keys = pool.key_of[safe]
    cur_state = pool.slot_state[safe]
    key_of = pool.key_of.at[safe].set(jnp.where(ok[:, None], keys, cur_keys))
    slot_state = pool.slot_state.at[safe].set(
        jnp.where(ok, jnp.int32(S_INSTALLED), cur_state))
    return pool._replace(key_of=key_of, slot_state=slot_state)


@functools.partial(jax.jit, donate_argnums=0)
def touch(pool: PoolState, slots: jax.Array) -> PoolState:
    """Set CLOCK ref bits and bump hotness on access (negative slots skipped).

    The hotness counter saturates at HOT_MAX; ``decay_hot`` halves it on a
    period, so it approximates an exponentially-weighted access frequency
    (the migration policy's local-traffic signal)."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    ref = pool.ref.at[safe].set(
        jnp.where(ok, jnp.int8(1), pool.ref[safe]))
    hot = pool.hot.at[safe].set(
        jnp.where(ok, jnp.minimum(pool.hot[safe] + 1, HOT_MAX),
                  pool.hot[safe]))
    return pool._replace(ref=ref, hot=hot)


@functools.partial(jax.jit, donate_argnums=0)
def touch_weighted(pool: PoolState, slots: jax.Array,
                   counts: jax.Array) -> PoolState:
    """Batched flush of buffered TLB-hit touches: one device call applies a
    whole engine step's worth of CLOCK/hotness updates.

    ``counts[i]`` accesses are credited to ``slots[i]`` (saturating at
    HOT_MAX).  Slots that are negative (padding) or no longer INSTALLED are
    skipped — the mapping may have been shot down and the frame freed (or
    reallocated into RESERVED) between buffering and flush, and a stale
    touch must not resurrect a dead frame's heat.

    Skipped rows alias onto index 0, so the scatters must be commutative
    (max/add with a zero contribution), never ``set`` — a duplicate-index
    ``set`` writing the old value back could race out a real update."""
    safe = jnp.maximum(slots, 0)
    ok = (slots >= 0) & (pool.slot_state[safe] == S_INSTALLED)
    ref = pool.ref.at[safe].max(jnp.where(ok, 1, 0).astype(jnp.int8))
    hot = pool.hot.at[safe].add(jnp.where(ok, counts, 0))
    return pool._replace(ref=ref, hot=jnp.minimum(hot, HOT_MAX))


@functools.partial(jax.jit, donate_argnums=0)
def decay_hot(pool: PoolState) -> PoolState:
    """Halve every hotness counter (exponential decay tick)."""
    return pool._replace(hot=pool.hot >> 1)


@functools.partial(jax.jit, donate_argnums=0)
def begin_drain(pool: PoolState, slots: jax.Array) -> PoolState:
    """INSTALLED -> DRAINING when LOCAL_INV is issued: the frame is retained
    ("kept on the LRU") and blocked for I/O until the ACK round completes."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    cur = pool.slot_state[safe]
    slot_state = pool.slot_state.at[safe].set(
        jnp.where(ok & (cur == S_INSTALLED), jnp.int32(S_DRAINING), cur))
    return pool._replace(slot_state=slot_state)


@functools.partial(jax.jit, donate_argnums=0)
def reinstate(pool: PoolState, slots: jax.Array) -> PoolState:
    """DRAINING -> INSTALLED: back out of a drain that never completed (the
    directory rejected the transition, or a migration aborted).  Negative
    slots skipped."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    cur = pool.slot_state[safe]
    slot_state = pool.slot_state.at[safe].set(
        jnp.where(ok & (cur == S_DRAINING), jnp.int32(S_INSTALLED), cur))
    return pool._replace(slot_state=slot_state)


@functools.partial(jax.jit, donate_argnums=0)
def orphan(pool: PoolState, slots: jax.Array) -> PoolState:
    """Disassociate frames from their keys without changing slot state.

    The async data plane uses this when a migration hand-off commits: the
    destination frame becomes the key's canonical copy immediately, while
    the source frame stays pinned (DRAINING) as an anonymous staging buffer
    until its COPY lane services — single-copy holds throughout because the
    staging frame no longer *names* the key.  Negative slots skipped."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    key_of = pool.key_of.at[safe].set(
        jnp.where(ok[:, None], jnp.full((2,), EMPTY, jnp.int32),
                  pool.key_of[safe]))
    return pool._replace(key_of=key_of)


@functools.partial(jax.jit, donate_argnums=0)
def retire(pool: PoolState, slots: jax.Array) -> PoolState:
    """DRAINING -> WRITEBACK: the invalidation round completed with the
    dirty bit set and a flush obligation was enqueued.  The frame is pinned
    (not reusable, invisible to CLOCK) until the flush commits and the
    protocol calls ``release``.  Negative slots skipped."""
    ok = slots >= 0
    safe = jnp.maximum(slots, 0)
    cur = pool.slot_state[safe]
    slot_state = pool.slot_state.at[safe].set(
        jnp.where(ok & (cur == S_DRAINING), jnp.int32(S_WRITEBACK), cur))
    return pool._replace(slot_state=slot_state)


@functools.partial(jax.jit, donate_argnums=0)
def release(pool: PoolState, slots: jax.Array) -> PoolState:
    """DRAINING/WRITEBACK/RESERVED -> FREE after INVALIDATION_ACK (clean) or
    after the writeback flush commits (dirty: flush-before-free).
    Pushes slots back on the free stack.  Negative slots skipped."""
    n = slots.shape[0]

    def step(i, pool):
        slot = slots[i]
        ok = slot >= 0
        safe = jnp.maximum(slot, 0)
        key_of = pool.key_of.at[safe].set(
            jnp.where(ok, jnp.full((2,), EMPTY, jnp.int32), pool.key_of[safe]))
        ss = pool.slot_state.at[safe].set(
            jnp.where(ok, jnp.int32(S_FREE), pool.slot_state[safe]))
        ref = pool.ref.at[safe].set(jnp.where(ok, jnp.int8(0), pool.ref[safe]))
        hot = pool.hot.at[safe].set(jnp.where(ok, jnp.int32(0),
                                              pool.hot[safe]))
        top = pool.free_top
        stack = pool.free_stack.at[jnp.where(ok, top, 0)].set(
            jnp.where(ok, slot, pool.free_stack[0]))
        top = jnp.where(ok, top + 1, top)
        return pool._replace(key_of=key_of, slot_state=ss, ref=ref, hot=hot,
                             free_stack=stack, free_top=top)

    return lax.fori_loop(0, n, step, pool)


@functools.partial(jax.jit, static_argnames=("want",), donate_argnums=0)
def clock_scan(pool: PoolState, want: int) -> Tuple[PoolState, jax.Array]:
    """GCLOCK over INSTALLED slots: pick up to ``want`` victims.

    Referenced slots get their bit cleared and are skipped (one more pass of
    life); unreferenced-but-hot slots are aged (counter halved) and spared
    for the pass; unreferenced cold slots become victims.  Scans at most
    enough revolutions to age any slot fully, so a pool of uniformly hot
    frames still yields victims within one call.  Returns
    (pool, victim_slots [want] int32, -1 padded).
    """
    p = pool.key_of.shape[0]
    # 2 revolutions for classic second chance + log2(HOT_MAX) to age heat out
    max_steps = (2 + HOT_MAX.bit_length()) * p

    def cond(c):
        pool, victims, vmask, n_found, steps = c
        return jnp.logical_and(n_found < want, steps < max_steps)

    def body(c):
        pool, victims, vmask, n_found, steps = c
        slot = pool.hand
        hand = jnp.where(slot + 1 >= p, 0, slot + 1)
        installed = pool.slot_state[slot] == S_INSTALLED
        referenced = pool.ref[slot] > 0
        still_hot = pool.hot[slot] > 1
        # second chance: clear the bit
        ref = pool.ref.at[slot].set(
            jnp.where(installed & referenced, jnp.int8(0), pool.ref[slot]))
        # frequency chance: age the counter instead of victimizing
        hot = pool.hot.at[slot].set(
            jnp.where(installed & ~referenced & still_hot,
                      pool.hot[slot] >> 1, pool.hot[slot]))
        # a slot already picked this call must not be picked again when the
        # hand comes back around (want > eligible frames): a duplicate
        # victim would double-drain one frame and corrupt the LOCAL_INV
        is_victim = installed & ~referenced & ~still_hot & ~vmask[slot]
        vmask = vmask.at[slot].set(vmask[slot] | is_victim)
        victims = victims.at[jnp.where(is_victim, n_found, want)].set(
            jnp.where(is_victim, slot, jnp.int32(-1)))
        n_found = n_found + is_victim.astype(jnp.int32)
        return (pool._replace(ref=ref, hot=hot, hand=hand), victims,
                vmask, n_found, steps + 1)

    victims0 = jnp.full((want + 1,), -1, jnp.int32)  # +1 scratch row
    vmask0 = jnp.zeros((p,), bool)
    pool, victims, _, _, _ = lax.while_loop(
        cond, body, (pool, victims0, vmask0, jnp.int32(0), jnp.int32(0)))
    return pool, victims[:want]


def num_free(pool: PoolState) -> jax.Array:
    return pool.free_top


def num_installed(pool: PoolState) -> jax.Array:
    return jnp.sum(pool.slot_state == S_INSTALLED)


def num_writeback(pool: PoolState) -> jax.Array:
    """Frames pinned awaiting their flush commit (not yet reusable)."""
    return jnp.sum(pool.slot_state == S_WRITEBACK)


_STATE_NAMES = {S_FREE: "free", S_RESERVED: "reserved",
                S_INSTALLED: "installed", S_DRAINING: "draining",
                S_WRITEBACK: "writeback"}


def occupancy(pool: PoolState) -> dict:
    """Host-side slot-state census {state_name: count} — one device
    readback per call; feeds the per-node pool gauges in the obs
    snapshot, not the data path."""
    import numpy as np
    states = np.asarray(pool.slot_state)
    return {name: int((states == s).sum())
            for s, name in _STATE_NAMES.items()}
