"""Hotness-driven page-ownership migration — the beyond-paper tentpole.

DPC's single-copy invariant (paper §4) pins a page's sole DRAM copy on the
node that first touched it.  When the traffic moves — a prefix goes viral on
another replica, a tenant rebalances — every access from the new hot node
pays the remote-read penalty forever.  This module makes ownership follow the
workload: a decaying per-(page, node) remote-access ledger feeds a promotion
policy, and promotions execute as batched MIGRATE transactions through the
directory, off the serving critical path.

State machine (per page key; directory codes in core/directory.py):

    O@src --MIGRATE--> TBM --all sharer INV_ACKs--> E@dst --COMMIT--> O@dst
                        |                                              |
                        +---- abort (dst pool full / dst died) --------+
                                   TBM -> E@src -> COMMIT -> O@src

TBM ("to-be-migrated") reuses the invalidation fan-out of reclamation's TBI:
every sharer maps the *moving* frame, so each must tear its mapping down and
ACK before the hand-off lands — the destination is usually among them (that
is precisely the hot-page case).  Because TBM and TBI are distinct states, a
concurrent reclaim and migrate of the same page can never complete each
other's transaction: whichever begin lands first wins, the loser observes
BLOCKED/BAD and retries.  The single-copy invariant therefore holds at every
step: the source frame stays DRAINING (retained, reclaim-proof) until the
destination's COMMIT publishes the new frame, and only then is it freed.

Policy: ``note_remote_access`` bumps the requester's counter for the page;
counters halve every ``decay_every`` rounds (an exponentially-weighted
frequency, mirroring the pool-side hotness counter in core/pagepool.py).  A
round promotes up to ``batch_size`` pages whose hottest remote node crossed
``threshold``, hottest first; a migrated page is immune for
``cooldown_rounds`` rounds so two competing nodes cannot ping-pong a page
back and forth every round.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.protocol import DPCProtocol
from repro.obs import CLUSTER

Key = Tuple[int, int]  # (stream_id, page_idx)


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    threshold: int = 4        # decayed remote-access count that promotes
    batch_size: int = 32      # max MIGRATEs per round (batched, §4.3-style)
    decay_every: int = 4      # rounds between ledger/pool hotness halvings
    cooldown_rounds: int = 2  # rounds a freshly migrated page is immune


class HotnessLedger:
    """Decaying per-(page, node) remote-access counts.

    This is the directory-side complement of the pool's per-slot hotness
    counter: remote reads never touch the owner's pool, so the signal that
    actually justifies moving ownership has to be collected where the
    requests are seen — at lookup time."""

    def __init__(self) -> None:
        self.counts: Dict[Key, Dict[int, int]] = {}

    def note(self, key: Key, node: int, weight: int = 1) -> None:
        self.counts.setdefault(key, {})[node] = \
            self.counts.get(key, {}).get(node, 0) + weight

    def decay(self) -> None:
        """Halve every counter; forget pages that cooled to zero."""
        for key in list(self.counts):
            per_node = {n: c >> 1 for n, c in self.counts[key].items()
                        if c >> 1 > 0}
            if per_node:
                self.counts[key] = per_node
            else:
                del self.counts[key]

    def hottest(self, key: Key) -> Tuple[int, int]:
        """(node, count) of the heaviest remote accessor; (-1, 0) if none."""
        per_node = self.counts.get(key)
        if not per_node:
            return -1, 0
        node = max(per_node, key=lambda n: (per_node[n], -n))
        return node, per_node[node]

    def forget(self, key: Key) -> None:
        self.counts.pop(key, None)


class OwnershipMigrator:
    """Promotion policy + batched MIGRATE execution over a DPCProtocol.

    The serving engine (or any protocol driver) calls ``note_remote_access``
    on every remote hit and ``run_round`` periodically off the critical
    path; everything else — candidate ranking, batching, cooldown, the
    directory transaction, frame accounting — happens here."""

    def __init__(self, proto: DPCProtocol,
                 cfg: Optional[MigrationConfig] = None):
        self.proto = proto
        self.cfg = cfg or MigrationConfig()
        self.ledger = HotnessLedger()
        self.round = 0
        # key -> round number until which it may not migrate again
        self._cooldown: Dict[Key, int] = {}
        self.stats = proto.obs.view(
            CLUSTER, "migration",
            ("rounds", "candidates", "migrated", "cooldown_skips",
             "predicted_notes"))

    # -- signal ---------------------------------------------------------------

    def note_remote_access(self, key: Key, node: int) -> None:
        self.ledger.note(key, node)

    def note_predicted_access(self, key: Key, node: int,
                              weight: int = 1) -> None:
        """Prediction-sourced ledger credit: a prefix-tree match says
        ``node`` is about to read ``key`` — the same promotion signal as an
        observed remote hit, just ahead of time (and weighted, because a
        matched path predicts a whole run of accesses, not one).  This is
        the "predictive promotion" half of the policy: pages on popular
        prefixes migrate toward their predictors before the remote-read
        tax is ever paid."""
        self.ledger.note(key, node, weight=max(weight, 1))
        self.stats["predicted_notes"] += 1

    # -- policy ---------------------------------------------------------------

    def candidates(self) -> List[Tuple[Key, int]]:
        """Up to ``batch_size`` (key, dst) pairs whose hottest remote node
        crossed the threshold, hottest first."""
        out: List[Tuple[int, Key, int]] = []
        for key in self.ledger.counts:
            if self._cooldown.get(key, 0) > self.round:
                self.stats["cooldown_skips"] += 1
                continue
            node, count = self.ledger.hottest(key)
            if node >= 0 and count >= self.cfg.threshold:
                out.append((count, key, node))
        out.sort(key=lambda t: (-t[0], t[1]))
        return [(key, node) for _, key, node in out[:self.cfg.batch_size]]

    # -- execution ------------------------------------------------------------

    def run_round(self, ack_fn=None, copy_fn=None
                  ) -> List[Tuple[Key, int, int]]:
        """One migration round: decay tick, pick candidates, run the batched
        MIGRATE transaction.  Returns [(key, old_pfn, new_pfn)] so callers
        can rewrite page tables.  Safe to call every engine step — rounds
        with no candidates cost one dict scan and no directory traffic."""
        self.round += 1
        self.stats["rounds"] += 1
        if self.cfg.decay_every and self.round % self.cfg.decay_every == 0:
            self.ledger.decay()
            self._decay_pools()
            self._cooldown = {k: r for k, r in self._cooldown.items()
                              if r > self.round}
        pairs = self.candidates()
        if not pairs:
            return []
        self.stats["candidates"] += len(pairs)
        moved = self.proto.migrate_sync(pairs, ack_fn=ack_fn, copy_fn=copy_fn)
        for key, _, _ in moved:
            self._cooldown[key] = self.round + self.cfg.cooldown_rounds
            self.ledger.forget(key)
        self.stats["migrated"] += len(moved)
        return moved

    def _decay_pools(self) -> None:
        from repro.core import pagepool as pp
        for node in range(self.proto.cfg.num_nodes):
            self.proto._pool_update(node,
                                    pp.decay_hot(self.proto.state.pools[node]))

    # -- elastic join ---------------------------------------------------------

    def rebalance_join(self, new_node: int,
                       donors: Optional[List[int]] = None,
                       batch: Optional[int] = None, ack_fn=None, copy_fn=None
                       ) -> List[Tuple[Key, int, int]]:
        """Seed a freshly joined node with the donors' *coldest* pages.

        The inverse of the hotness policy: a newcomer has no access history,
        so instead of waiting for the ledger to warm up, the cluster hands
        it the pages the donors care least about — per-slot pool hotness
        picks them (coldest first, heaviest donor first on ties), and the
        hand-offs are ordinary batched MIGRATE transactions.  ``batch``
        defaults to an even post-join share of the installed pages."""
        import numpy as np

        from repro.core import pagepool as pp

        proto = self.proto
        if donors is None:
            donors = [n for n in range(proto.cfg.num_nodes) if n != new_node]
        donors = [d for d in donors if d != new_node]
        if not donors:
            return []
        pending = set(proto.pending_inv) | set(proto.pending_mig)
        cand: List[Tuple[int, int, int, Key]] = []  # (hot, -load, donor, key)
        loads: Dict[int, int] = {}
        for d in donors:
            pool = proto.state.pools[d]
            ss = np.asarray(pool.slot_state)
            hot = np.asarray(pool.hot)
            keys = np.asarray(pool.key_of)
            rows = np.nonzero(ss == pp.S_INSTALLED)[0]
            loads[d] = len(rows)
            for i in rows:
                key = (int(keys[i, 0]), int(keys[i, 1]))
                if key in pending or self._cooldown.get(key, 0) > self.round:
                    continue
                cand.append((int(hot[i]), 0, d, key))
        if not cand:
            return []
        cand = [(h, -loads[d], d, k) for h, _, d, k in cand]
        cand.sort(key=lambda t: (t[0], t[1], t[3]))
        if batch is None:
            batch = sum(loads.values()) // (len(donors) + 1)
        pairs = [(k, new_node) for _, _, _, k in cand[:max(batch, 0)]]
        moved: List[Tuple[Key, int, int]] = []
        for i in range(0, len(pairs), self.cfg.batch_size):
            moved.extend(proto.migrate_sync(pairs[i:i + self.cfg.batch_size],
                                            ack_fn=ack_fn, copy_fn=copy_fn))
        for key, _, _ in moved:
            self._cooldown[key] = self.round + self.cfg.cooldown_rounds
            self.ledger.forget(key)
        self.stats["migrated"] += len(moved)
        return moved
