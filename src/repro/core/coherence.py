"""Coherence modes (paper §5 + §6 configurations).

  dpc          relaxed coherence: buffered writes stay local; pages already in
               DPC are written through their mapping and reconciled at
               writeback (NFS-like weak semantics).
  dpc_sc       strong coherence: every write range runs the two-step
               LOOKUP_LOCK -> UNLOCK protocol so a page has well-defined
               ownership before data lands (POSIX-like).
  replicated   per-node caching with no cross-node sharing (the uncoordinated
               baseline regime: each node may hold its own copy).
  local_only   no cache coordination at all (Virtiofs baseline: every remote
               miss refetches from "storage" = prefill recompute).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core import descriptors as D
from repro.core.protocol import DPCProtocol

MODES = ("dpc", "dpc_sc", "replicated", "local_only")


def mode_shares_pages(mode: str) -> bool:
    return mode in ("dpc", "dpc_sc")


def mode_strong(mode: str) -> bool:
    return mode == "dpc_sc"


@dataclasses.dataclass
class WriteTicket:
    """Outcome of write-preparation for a batched write range."""
    streams: np.ndarray
    pages: np.ndarray
    node: int
    strong: bool
    # rows that must COMMIT (locked in E) after the data copy
    locked_rows: np.ndarray
    slots: np.ndarray
    # rows being written through a remote mapping (dirty at ack time)
    remote_rows: np.ndarray
    # rows written through an already-owned mapping: the write dirties the
    # page, registered via the TLB write-grant fast path (a steady-state
    # re-write pays zero directory ops — see protocol.mark_dirty)
    owner_rows: np.ndarray = dataclasses.field(
        default_factory=lambda: np.empty(0, np.int64))


class CoherenceManager:
    """Write-path policy over the protocol (paper §4.2 write path).

    The generic buffered-write path iterates the range page by page; for DPC
    mounts preparation/commit are decoupled and batched over contiguous runs
    of missing pages — exactly what ``prepare``/``commit`` model.
    """

    def __init__(self, proto: DPCProtocol, mode: str = "dpc"):
        assert mode in MODES, mode
        self.proto = proto
        self.mode = mode

    def prepare(self, streams, pages, node: int) -> WriteTicket:
        streams = np.asarray(streams, np.int32)
        pages = np.asarray(pages, np.int32)
        strong = mode_strong(self.mode)
        if not mode_shares_pages(self.mode) or not strong:
            # relaxed / baseline: the write proceeds locally, no round trip
            return WriteTicket(streams, pages, node, False,
                               np.empty(0, np.int64), np.empty(0, np.int32),
                               np.empty(0, np.int64))
        res = self.proto.write_prepare(streams, pages, node, strong=True)
        locked = res.granted()
        remote = res.remote_hits()
        owner = res.local_hits()
        return WriteTicket(streams, pages, node, True,
                           locked, res.slot[locked], remote, owner)

    def commit(self, ticket: WriteTicket) -> int:
        """Step 2 (FUSE_DPC_UNLOCK): commit locked pages, dirty the rest."""
        n_ops = 0
        if len(ticket.locked_rows):
            self.proto.commit_pages(ticket.streams[ticket.locked_rows],
                                    ticket.pages[ticket.locked_rows],
                                    ticket.node, ticket.slots)
            n_ops += len(ticket.locked_rows)
        if len(ticket.remote_rows):
            self.proto.mark_dirty(ticket.streams[ticket.remote_rows],
                                  ticket.pages[ticket.remote_rows],
                                  ticket.node)
            n_ops += len(ticket.remote_rows)
        if len(ticket.owner_rows):
            # owned pages were written too: register the dirty bits — a
            # cached write grant makes this free (buffered, zero dir ops)
            self.proto.mark_dirty(ticket.streams[ticket.owner_rows],
                                  ticket.pages[ticket.owner_rows],
                                  ticket.node)
            n_ops += len(ticket.owner_rows)
        if ticket.strong and len(ticket.remote_rows):
            # strong mode promises sharer writes are visible at unlock:
            # S-mode marks routed through the buffered fast path register
            # now, in one batched directory op for the whole range.  Owner
            # re-writes stay buffered (M-grant semantics, flushed at step
            # boundaries) — that keeps the owned two-step directory-free
            self.proto.flush_dirty_marks(ticket.node)
        return n_ops
