"""DPC event layer: the six directory events composed over directory + pools.

The serving engine (and the host-tier data cache) drives the protocol through
these composite flows; each flow is the faithful sequence from the paper:

  read path    (§4.2)  lookup_and_install -> [GRANT_E? alloc frame ->
                        materialize -> commit] / [MAP_S? map remote frame]
  write path   (§4.2)  relaxed: local write (+mark_dirty)
                       strong (DPC_SC): LOOKUP_LOCK -> write -> UNLOCK commit
  reclamation  (§4.3)  CLOCK victims -> LOCAL_INV batch (frames retained,
                        DRAINING) -> DIR_INV fan-out -> INV_ACKs (dirty bits)
                        -> INVALIDATION_ACK -> writeback if dirty -> free
  migration    (beyond-paper)  hot remote page -> MIGRATE batch
                        (O -> TBM, sharers torn down exactly like an
                        invalidation round) -> complete (TBM -> E@dst) ->
                        copy + COMMIT at dst -> source frame freed.
                        See core/migration.py for the policy side.

The *directory placement* mirrors DESIGN.md §2: ``central`` keeps one
directory consulted by every node (the paper's storage-server placement);
``sharded`` hash-partitions entries over nodes by key (TPU-native default).
Both run the identical protocol — placement only decides which shard's arrays
an opcode batch lands on.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import descriptors as D
from repro.core import directory as dirx
from repro.core import pagepool as pp
from repro.core import refimpl
from repro.core.tlb import MODE_M, MODE_O, MODE_S, TLBGroup
from repro.obs import CLUSTER, Obs
from repro.obs import trace as T


@dataclasses.dataclass
class ProtocolConfig:
    num_nodes: int
    pool_pages: int                  # physical pages per node
    directory_capacity: int = 1 << 14
    inv_batch_threshold: int = 32    # paper §4.3
    max_probe: int = 128
    placement: str = "sharded"       # sharded | central
    # per-node mapping cache (software TLB, core/tlb.py): established grants
    # are cached so steady-state re-reads skip the directory entirely.
    # 0 slots disables it.
    tlb_slots: int = 1024
    tlb_max_probe: int = 8
    # write grants: a MODE_M entry at the owner lets mark_dirty /
    # write_prepare complete with zero directory ops; dirty bits buffer per
    # node and flush in one batched op per engine step — and always before
    # a teardown can observe the page (reclaim/migrate/fail flush first)
    tlb_write_grants: bool = True
    # deliver TLB shootdowns as piggybacked SHOOTDOWN lanes appended to the
    # next opcode batch routed for the sharer, serviced before the batch's
    # own ops (paper §4.3 batching).  False = legacy synchronous in-process
    # draining, kept as the reference mode for equivalence property tests.
    tlb_piggyback: bool = True
    # async data plane: migration KV copies and deferred writeback captures
    # ride COPY/FLUSH descriptor lanes on routed batches (serviced at the
    # next batch routed on the target node's behalf, or at a fence —
    # teardown begins, flush barriers, step boundaries), per-shard device
    # transfers in _routed pipeline instead of awaiting one shard at a
    # time, and drain_node evacuates through overlapped MIGRATE rounds.
    # False = legacy synchronous stepping, kept as the reference mode for
    # the async==sync equivalence property tests.
    async_data_plane: bool = True
    # run the pure-Python RefDirectory in lockstep and assert the dirty bit
    # returned on every completed invalidation/migration matches the
    # oracle's needs_writeback — protocol/oracle divergence fails loudly
    # instead of silently dropping (or double-writing) page data
    shadow_oracle: bool = False
    # directory shard count, frozen at construction.  Elastic joins grow
    # num_nodes but must never re-hash existing keys to new shards, so
    # placement stays pinned to the founding layout.  0 resolves from
    # placement/num_nodes in __post_init__.
    num_shards: int = 0
    # observability (repro/obs): off | counters | full — see DPCConfig
    obs_level: str = "counters"
    obs_trace_events: int = 32768

    def __post_init__(self) -> None:
        if self.num_shards <= 0:
            self.num_shards = 1 if self.placement == "central" \
                else self.num_nodes

    def dir_config(self) -> dirx.DirectoryConfig:
        return dirx.DirectoryConfig(self.directory_capacity, self.num_nodes,
                                    self.max_probe)


# protocol counter names, pre-declared so views (and ``kv.stats()``
# snapshots) have stable row order from construction
PROTOCOL_COUNTERS = (
    "reads", "grants", "remote_hits", "local_hits",
    "blocked", "commits", "reclaims", "dir_invs",
    "inv_acks", "writebacks", "dropped_nodes",
    "migrations", "migration_noops", "migration_aborts",
    "migration_acks", "writebacks_committed",
    "migration_writebacks", "flush_before_free_violations",
    "oracle_mismatches", "dirty_clears",
    "tlb_write_hits", "write_prepare_hits",
    "dirty_buffered", "dirty_mark_flushes",
    "joins", "rejoins", "drains", "drained_pages",
    "drain_aborts", "rehomed_pages", "rehome_deferred",
    "lost_dirty_pages", "checkpointed_pages",
    "lane_copies", "lane_flushes", "lane_fences",
    "fenced_nodes", "unfenced_nodes", "fenced_rejects",
    "promotes", "promote_hits", "promote_misses", "promote_blocked",
)


class StaleEpochError(RuntimeError):
    """A routed opcode batch was issued on behalf of a fenced node.

    The node's membership epoch is stale (it sits on the minority side
    of a partition, or was declared failed): its fencing token says any
    directory transition it drives could violate single-copy against
    the majority's re-homed ownership.  The node must degrade to
    local-only serving and rejoin through the committed epoch log."""

    def __init__(self, node: int, token: int):
        super().__init__(
            f"node {node} is fenced at token {token}: routed batches "
            "rejected until it rejoins through the epoch log")
        self.node = node
        self.token = token


class DPCState(NamedTuple):
    """Cluster-wide protocol state (device arrays).

    ``dirs``: tuple of DirectoryState — one per directory shard (len 1 for
    central placement, len num_nodes for sharded).
    ``pools``: tuple of PoolState, one per node.
    """
    dirs: Tuple[dirx.DirectoryState, ...]
    pools: Tuple[pp.PoolState, ...]


def init_state(cfg: ProtocolConfig) -> DPCState:
    dcfg = cfg.dir_config()
    return DPCState(
        dirs=tuple(dirx.init_directory(dcfg) for _ in range(cfg.num_shards)),
        pools=tuple(pp.init_pool(cfg.pool_pages) for _ in range(cfg.num_nodes)),
    )


def dir_shard_of(cfg: ProtocolConfig, stream: int, page: int) -> int:
    """Which directory shard owns the entry for (stream, page).

    Keyed on the frozen ``num_shards`` — a node joining later grows the
    cluster but never moves existing entries between shards."""
    if cfg.num_shards == 1:
        return 0
    return D.hash_key_py(stream, page) % cfg.num_shards


def _group_by_shard(cfg: ProtocolConfig, streams, pages) -> Dict[int, List[int]]:
    groups: Dict[int, List[int]] = {}
    for i, (s, p) in enumerate(zip(streams, pages)):
        groups.setdefault(dir_shard_of(cfg, int(s), int(p)), []).append(i)
    return groups


@dataclasses.dataclass
class ReadResult:
    """Per-page outcome of the read path (host-side view for the engine)."""
    status: np.ndarray        # [N] int32 status codes
    owner: np.ndarray         # [N] owner node (valid for hits)
    pfn: np.ndarray           # [N] global frame number (valid for hits)
    slot: np.ndarray          # [N] local slot allocated for GRANT_E rows (-1)

    def granted(self) -> np.ndarray:
        return np.nonzero(self.status == D.ST_GRANT_E)[0]

    def remote_hits(self) -> np.ndarray:
        return np.nonzero((self.status == D.ST_MAP_S) |
                          (self.status == D.ST_HIT_SHARER))[0]

    def local_hits(self) -> np.ndarray:
        return np.nonzero(self.status == D.ST_HIT_OWNER)[0]

    def blocked(self) -> np.ndarray:
        return np.nonzero((self.status == D.ST_BLOCKED) |
                          (self.status == D.ST_FULL))[0]


class DPCProtocol:
    """Host-driven protocol orchestrator over jitted directory/pool ops.

    This object plays the role of the paper's DPC MM + Directory Manager +
    Invalidation Manager: it routes batched opcodes to directory shards,
    allocates/retains/frees pool frames, and runs the deterministic
    reclamation sequence.  All heavy state stays in device arrays.
    """

    def __init__(self, cfg: ProtocolConfig, state: Optional[DPCState] = None,
                 *, store=None, writeback=None,
                 page_bytes_fn: Optional[Callable] = None, obs=None):
        self.cfg = cfg
        self.state = state or init_state(cfg)
        # --- observability (repro/obs): the cluster hub is either handed
        # down (dpc_cache owns one per cluster and shares it with storage
        # and the engines) or created here so a bare protocol still meters
        # itself.  ``self.counters`` keeps its historical dict shape
        # through a registry view; ``self.trace`` is None below
        # obs_level="full" and every emit site gates on that.
        self.obs: Obs = obs if obs is not None else Obs(
            cfg.obs_level, num_nodes=cfg.num_nodes,
            trace_capacity=cfg.obs_trace_events)
        self.trace = self.obs.tracer
        if self.trace is not None:
            self.trace.meta["pool_pages"] = cfg.pool_pages
            self.trace.meta["num_nodes"] = cfg.num_nodes
        self._h_batch = self.obs.histogram(CLUSTER, "protocol", "batch_rows")
        self._h_fence = self.obs.histogram(CLUSTER, "protocol",
                                           "lane_fence_depth")
        # pages in TBI with outstanding sharer ACKs: (stream, page) -> set(nodes)
        self.pending_inv: Dict[Tuple[int, int], Dict] = {}
        # pages in TBM (ownership hand-off in flight):
        # (stream, page) -> {src, dst, src_slot, old_pfn, waiting: set(nodes)}
        self.pending_mig: Dict[Tuple[int, int], Dict] = {}
        # --- storage tier (repro/storage): durable backing + async flushes.
        # page_bytes_fn(key, pfn) is the data-plane hook that captures the
        # frame's bytes at enqueue time (the engine reads its KV pools; tests
        # and benchmarks supply synthetic payloads).
        self.store = store
        self.writeback = writeback
        self.page_bytes_fn = page_bytes_fn
        # frames pinned in S_WRITEBACK until their flush commits:
        # (node, slot) -> key.  release refuses these (flush-before-free).
        self._wb_outstanding: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # tokens orphaned by a node rejoin: the node's pool was re-initialized
        # fresh, so when these flushes commit the frames must NOT be released
        # into the reborn pool (that would double-free a slot) — harvest
        # discards them instead.  The obligations' bytes still flush normally.
        self._wb_stale: set = set()
        # per-node mapping cache + shootdown plumbing (core/tlb.py); the
        # protocol keeps it coherent (installs on commit, precise shootdowns
        # on teardown fan-outs, epoch flash on node failure) and the cache
        # facade (dpc_cache) serves hits from it
        self.tlbs: Optional[TLBGroup] = None
        if cfg.tlb_slots > 0:
            self.tlbs = TLBGroup(cfg.num_nodes, cfg.tlb_slots,
                                 cfg.tlb_max_probe, obs=self.obs)
        # buffered write-grant dirty marks, one set per node: a MODE_M hit
        # adds its key here instead of paying a directory op; the set is
        # flushed in ONE batched mark_dirty per node per engine step, and
        # always before any teardown could observe the page
        self._dirty_buf: List[set] = [set() for _ in range(cfg.num_nodes)]
        # buffered CLOCK touches for TLB-served write_prepare owner hits
        # (the directory path touched HIT_OWNER rows in read_pages — hot
        # re-written pages must not look cold to the eviction scan); they
        # flush with the dirty marks, so reclaim_begin sees the heat
        self._wtouch_buf: List[Dict[int, int]] = [
            {} for _ in range(cfg.num_nodes)]
        # reusable host-side descriptor buffers, one per power-of-two batch
        # size: _routed fills these and ships ONE array to the device instead
        # of building + padding fresh arrays per call
        self._desc_scratch: Dict[int, np.ndarray] = {}
        # --- async data plane (cfg.async_data_plane) -----------------------
        # in-flight obligations riding descriptor lanes: migration KV copies
        # and deferred writeback captures queue per target node and are
        # serviced when the next batch is routed on that node's behalf (like
        # shootdown lanes) or force-settled by fence_data_lanes().  Host-side
        # metadata keyed by the lane payload recovers the full obligation.
        self._lane_copies: Dict[int, List[Tuple[int, int, int]]] = {}
        self._copy_meta: Dict[Tuple[int, int], Dict] = {}
        self._lane_flushes: Dict[int, List[Tuple[int, int, int]]] = {}
        self._flush_meta: Dict[Tuple[int, int, int], int] = {}
        # --- quorum fencing (runtime/epoch_log) ----------------------------
        # nodes whose membership epoch is stale: _routed rejects batches on
        # their behalf (StaleEpochError) until they rejoin.  fence_token is
        # the highest committed-epoch token this protocol has observed;
        # _fence_bypass nests while survivor-side cleanup (fail/drain)
        # legitimately routes batches *for* a fenced or dead node.
        self._fenced: Dict[int, int] = {}
        self._fence_bypass = 0
        self.fence_token = 0
        # detection -> fence -> recovery latency, measured where the wipe
        # actually happens (surfaced in the failover example's phase table)
        self._member_lat = self.obs.view(
            CLUSTER, "membership",
            ("detect_to_fence_us", "fence_to_recover_us"))
        # --- fault injection (runtime/faults): None = clean execution ------
        self.faults = None
        # executable-spec shadow (satellite: divergence must fail loudly)
        self.oracle: Optional[refimpl.RefDirectory] = None
        if cfg.shadow_oracle:
            self.oracle = refimpl.RefDirectory(
                cfg.directory_capacity * cfg.num_shards, cfg.num_nodes)
        # counters for the microbenchmarks — cluster-scope registry rows
        # behind a dict-compatible view (plain dict at obs_level="off")
        self.counters = self.obs.view(CLUSTER, "protocol",
                                      PROTOCOL_COUNTERS)
        # eviction classes (pagepool subsystem): what reclaim_finish frees
        # cleanly vs. retires through the writeback pipeline
        self.pool_counters = self.obs.view(
            CLUSTER, "pagepool", ("evict_clean", "evict_dirty"))

    def attach_storage(self, store=None, writeback=None,
                       page_bytes_fn: Optional[Callable] = None) -> None:
        """Late-bind the durable tier (the engine attaches its KV-pool byte
        fetcher after construction)."""
        if store is not None:
            self.store = store
        if writeback is not None:
            self.writeback = writeback
        if page_bytes_fn is not None:
            self.page_bytes_fn = page_bytes_fn

    def attach_faults(self, plan) -> None:
        """Thread a :class:`repro.runtime.faults.FaultPlan` through the
        routed batches, descriptor lanes, and named crash points.  None
        detaches (clean execution)."""
        self.faults = plan

    # -- quorum fencing (runtime/epoch_log) ------------------------------------

    def epoch_bump(self, epoch: int, token: int) -> None:
        """Record a committed membership epoch: every protocol-visible
        bump carries its fencing token (monotone — the audit checks)."""
        self.fence_token = max(self.fence_token, int(token))
        if self.trace is not None:
            self.trace.emit(T.EV_EPOCH, CLUSTER, int(epoch), int(token))

    def fence_nodes(self, nodes: Sequence[int],
                    token: Optional[int] = None) -> int:
        """Fence ``nodes`` at ``token`` (default: one past the highest
        observed): their routed batches raise :class:`StaleEpochError`
        until :meth:`unfence_nodes`.  Returns the token."""
        token = int(token) if token is not None else self.fence_token + 1
        self.fence_token = max(self.fence_token, token)
        for n in nodes:
            self._fenced[int(n)] = token
            if self.trace is not None:
                self.trace.emit(T.EV_FENCE, int(n), token)
        self.counters["fenced_nodes"] += len(list(nodes))
        return token

    def unfence_nodes(self, nodes: Sequence[int]) -> None:
        """Lift the fence (the node rejoined through the epoch log)."""
        for n in nodes:
            self._fenced.pop(int(n), None)
            if self.trace is not None:
                self.trace.emit(T.EV_UNFENCE, int(n), self.fence_token)
        self.counters["unfenced_nodes"] += len(list(nodes))

    def fenced_view(self) -> Dict[int, int]:
        return dict(self._fenced)

    def is_fenced(self, node: int) -> bool:
        return node in self._fenced

    def _check_crash(self, point: str, node: int) -> None:
        if self.faults is not None:
            self.faults.check_crash(point, node)

    # -- helpers -------------------------------------------------------------

    def _dir_op(self, op, shard: int, descs: jax.Array, **kw):
        dirs = list(self.state.dirs)
        out = op(dirs[shard], descs, max_probe=self.cfg.max_probe, **kw)
        dirs[shard] = out[0]
        self.state = self.state._replace(dirs=tuple(dirs))
        return out[1:]

    def _routed(self, op, streams, pages, nodes, aux=None):
        """Route a descriptor batch to directory shards; reassemble results.

        Piggyback lanes: queued TLB shootdowns for every node this batch is
        routed on behalf of (the node lane) ride along as SHOOTDOWN rows and
        are serviced — cached entries dropped — *before* the batch's own
        descriptors execute, the paper's §4.3 batched-invalidation delivery.
        A sharer's INV_ACK is itself a routed batch, so delivery still lands
        no later than the ACK; transaction completes fence any node that saw
        no traffic since its post (``TLBGroup.fence``).
        """
        streams = np.asarray(streams, np.int32)
        pages = np.asarray(pages, np.int32)
        nodes = np.broadcast_to(np.asarray(nodes, np.int32), streams.shape)
        aux = (np.zeros_like(streams) if aux is None
               else np.broadcast_to(np.asarray(aux, np.int32), streams.shape))
        n = len(streams)
        routed_nodes = np.unique(nodes).tolist() if n else []
        if self._fenced and not self._fence_bypass:
            # partition fencing: a batch routed on behalf of a stale-epoch
            # node is rejected outright — the minority side must degrade
            # to local-only, never drive directory transitions.  Survivor-
            # side cleanup (fail/drain re-homing) runs under the bypass.
            for nd in routed_nodes:
                if nd in self._fenced:
                    self.counters["fenced_rejects"] += 1
                    raise StaleEpochError(nd, self._fenced[nd])
        if self.faults is not None and n:
            # injected transient send failures: bounded retry-with-backoff,
            # accounted per node under (node, "faults", ...)
            self.faults.routed_send(routed_nodes)
            # lane reordering: a delayed node's pending descriptor lanes
            # sit this batch out (delivered delay_batches later, or force-
            # settled by the next fence — the invariant under test)
            lane_nodes = [nd for nd in routed_nodes
                          if not self.faults.lane_delayed(nd)]
            dup_nodes = {nd for nd in lane_nodes
                         if self.faults.lane_duplicated(nd)}
        else:
            lane_nodes = routed_nodes
            dup_nodes = set()
        lane_rows: List[np.ndarray] = []
        n_sd = n_cp = n_fl = 0
        if self.tlbs is not None and self.cfg.tlb_piggyback and n:
            triples = self.tlbs.drain_for(lane_nodes)
            if triples:
                sd = D.encode_shootdowns(triples)
                lane_rows.append(sd)
                n_sd = len(triples)
                # receiver-side service: the lanes are decoded and the cached
                # mappings die before any of the batch's own ops run
                self.tlbs.deliver(D.decode_shootdowns(sd))
                if dup_nodes:
                    # duplicated delivery: shootdown service is idempotent
                    # (dropping an already-dropped mapping is a no-op)
                    self.tlbs.deliver([t for t in D.decode_shootdowns(sd)
                                       if t[0] in dup_nodes])
        if self.cfg.async_data_plane and n:
            # data-plane lanes: pending COPY/FLUSH obligations for the nodes
            # this batch is routed on behalf of ride along the same way and
            # are serviced receiver-side before the batch's own ops
            cp = [t for nd in lane_nodes
                  for t in self._lane_copies.pop(nd, [])]
            fl = [t for nd in lane_nodes
                  for t in self._lane_flushes.pop(nd, [])]
            if cp:
                rows = D.encode_copies(cp)
                lane_rows.append(rows)
                n_cp = len(cp)
                self._service_copy_lanes(D.decode_copies(rows))
                if dup_nodes:
                    # second service is a no-op: _copy_meta pops once
                    self._service_copy_lanes(
                        [t for t in D.decode_copies(rows)
                         if t[0] in dup_nodes])
            if fl:
                rows = D.encode_flushes(fl)
                lane_rows.append(rows)
                n_fl = len(fl)
                self._service_flush_lanes(D.decode_flushes(rows))
                if dup_nodes:
                    self._service_flush_lanes(
                        [t for t in D.decode_flushes(rows)
                         if t[0] in dup_nodes])
        extra_rows = (np.concatenate(lane_rows) if lane_rows else None)
        if n:
            if self._h_batch is not None:
                self._h_batch.observe(n)
            if self.trace is not None:
                # dispatch record with lane composition: how many real rows
                # and how many piggybacked SHOOTDOWN/COPY/FLUSH descriptors
                # this batch carried
                self.trace.emit(T.EV_BATCH, CLUSTER, n, n_sd, n_cp, n_fl)
        res = np.zeros((n, 3), np.int32)
        extra: Dict[int, np.ndarray] = {}
        groups = list(_group_by_shard(self.cfg, streams, pages).items())
        # async mode issues every shard's device transfer + op before
        # materializing any result (the host<->device await moves from
        # per-shard to per-call); sync reference mode awaits shard by shard
        pipelined = self.cfg.async_data_plane and len(groups) > 1
        issued = []
        sizes_used = set()
        for shard, idxs in groups:
            # pad to the next power of two: opcode programs recompile per
            # batch shape, so this bounds jit variants to log2(n) per opcode.
            # The padded host buffer is cached per size and filled in place —
            # one device transfer per shard instead of a stack + concat chain.
            n_real = len(idxs)
            n_ex = 0 if extra_rows is None else len(extra_rows)
            n_pad = 1 << (n_real + n_ex - 1).bit_length()
            buf = self._desc_scratch.get(n_pad)
            if buf is None:
                buf = np.full((n_pad, D.N_LANES), int(D.INVALID), np.int32)
                self._desc_scratch[n_pad] = buf
            if pipelined and n_pad in sizes_used:
                # the scratch for this size is potentially aliased by a
                # still-unmaterialized transfer from an earlier shard in
                # this same call — fill a fresh buffer instead
                buf = np.full((n_pad, D.N_LANES), int(D.INVALID), np.int32)
            sizes_used.add(n_pad)
            buf[n_real:] = int(D.INVALID)
            buf[:n_real, D.LANE_STREAM] = streams[idxs]
            buf[:n_real, D.LANE_PAGE] = pages[idxs]
            buf[:n_real, D.LANE_NODE] = nodes[idxs]
            buf[:n_real, D.LANE_AUX] = aux[idxs]
            if n_ex:
                # the lanes ride the first shard's batch (directory-inert:
                # every opcode skips negative lane-0 rows)
                buf[n_real:n_real + n_ex] = extra_rows
                extra_rows = None
            out = self._dir_op(op, shard, jnp.asarray(buf))
            if pipelined:
                issued.append((shard, idxs, n_real, out))
            else:
                res[idxs] = np.asarray(out[0])[:n_real]
                if len(out) > 1:  # begin_invalidate/migrate: sharer masks
                    extra[shard] = (idxs, np.asarray(out[1])[:n_real])
        for shard, idxs, n_real, out in issued:
            res[idxs] = np.asarray(out[0])[:n_real]
            if len(out) > 1:
                extra[shard] = (idxs, np.asarray(out[1])[:n_real])
        return res, extra

    def _pool_update(self, node: int, new_pool: pp.PoolState):
        pools = list(self.state.pools)
        pools[node] = new_pool
        self.state = self.state._replace(pools=tuple(pools))

    # -- storage-tier plumbing -------------------------------------------------

    def _release_frames(self, node: int, slots: Sequence[int]) -> int:
        """Free frames, refusing any with an uncommitted flush obligation —
        the flush-before-free invariant is enforced here, not trusted."""
        ok = []
        for s in slots:
            if (node, int(s)) in self._wb_outstanding:
                self.counters["flush_before_free_violations"] += 1
                continue
            ok.append(int(s))
        if ok:
            self._pool_update(node, pp.release(
                self.state.pools[node], jnp.asarray(ok, jnp.int32)))
            if self.trace is not None:
                base = node * self.cfg.pool_pages
                for s in ok:
                    self.trace.emit(T.EV_FRAME_FREE, node, s, 0, base + s)
        return len(ok)

    def _enqueue_writeback(self, key: Tuple[int, int], node: int,
                           slot: int) -> None:
        """Capture the frame's bytes and hand the flush obligation to the
        queue; the frame is pinned (S_WRITEBACK) until the batch sync."""
        pfn = node * self.cfg.pool_pages + slot
        data = None
        if self.page_bytes_fn is not None:
            data = self.page_bytes_fn(key, pfn)
        if data is None:
            # control-plane-only run (no data plane attached): the
            # obligation still flows so ordering/accounting stay honest
            data = np.zeros((0,), np.uint8)
        token = (node, slot)
        self._wb_outstanding[token] = key
        if self.trace is not None:
            self.trace.emit(T.EV_WB_REG, node, slot, key[0], key[1])
        self.writeback.enqueue(key, np.asarray(data), token=token)

    def harvest_writebacks(self) -> int:
        """Release every frame whose flush committed since the last call
        (the engine runs this at step boundaries).  Returns frames freed."""
        if self.writeback is None:
            return 0
        done = self.writeback.drain_completions()
        by_node: Dict[int, List[int]] = {}
        for token, key in done:
            if self.trace is not None:
                self.trace.emit(T.EV_WB_COMMIT, token[0], token[1],
                                key[0], key[1])
            if token in self._wb_stale:
                # a rejoin re-initialized this node's pool: the flush is
                # durable but the frame no longer exists — do not release
                # the slot into the reborn pool
                self._wb_stale.discard(token)
                if self._wb_outstanding.get(token) == key:
                    self._wb_outstanding.pop(token)
                continue
            self._wb_outstanding.pop(token, None)
            by_node.setdefault(token[0], []).append(token[1])
        for node, slots in by_node.items():
            self._release_frames(node, slots)
        self.counters["writebacks_committed"] += len(done)
        return len(done)

    def pump_writeback(self, max_batches: Optional[int] = 1) -> int:
        """Step-boundary pump: in sync mode drain up to ``max_batches``
        inline, then harvest completions.  Returns frames freed."""
        if self.writeback is None:
            return 0
        # lane-carried flush captures must enter the queue before the pump
        # can observe it (bounded staleness: one engine step at most)
        self.fence_data_lanes()
        if not self.writeback.cfg.async_mode:
            self.writeback.pump(max_batches)
        return self.harvest_writebacks()

    def flush(self, upto_epoch: Optional[int] = None,
              stream: Optional[int] = None) -> int:
        """Flush barrier: block until obligations (all, one epoch prefix, or
        one stream's) are durable, then release their frames."""
        if self.writeback is None:
            return 0
        # a barrier promises durability for every obligation incurred so
        # far — including ones still riding lanes, so settle those first
        self.fence_data_lanes()
        if stream is not None:
            self.writeback.fsync_stream(stream)
        else:
            self.writeback.flush_barrier(upto_epoch)
        return self.harvest_writebacks()

    # -- async data plane: lane-carried obligations ----------------------------

    def _post_copy_lane(self, key: Tuple[int, int], src: int, src_slot: int,
                        dst: int, src_pfn: int, dst_pfn: int, dirty: bool,
                        copy_fn) -> None:
        """Defer a migration's KV copy (and its dirty-page checkpoint) onto
        a COPY lane riding the next batch routed for the destination.  The
        source frame stays DRAINING — retained and invisible to clock_scan —
        until the lane services, so the only materialized copy is pinned."""
        self._copy_meta[(src_pfn, dst_pfn)] = {
            "key": key, "src": src, "src_slot": src_slot, "dst": dst,
            "dirty": dirty, "copy_fn": copy_fn}
        self._lane_copies.setdefault(dst, []).append((dst, src_pfn, dst_pfn))
        self.counters["lane_copies"] += 1

    def _service_copy_lanes(self, triples) -> int:
        """Receiver-side COPY service: run the data-plane copy, then the
        hand-off epilogue the sync path runs inline — dirty sources
        checkpoint through the writeback queue (retire + CLEAR_DIRTY at the
        new owner), clean sources free."""
        done = 0
        for (_dst_node, src_pfn, dst_pfn) in triples:
            info = self._copy_meta.pop((src_pfn, dst_pfn), None)
            if info is None:
                continue   # already settled by a fence
            key = info["key"]
            if info["copy_fn"] is not None:
                info["copy_fn"](key, src_pfn, dst_pfn)
            src, src_slot = info["src"], info["src_slot"]
            if info["dirty"] and self.writeback is not None:
                self._enqueue_writeback(key, src, src_slot)
                self._pool_update(src, pp.retire(
                    self.state.pools[src],
                    jnp.asarray([src_slot], jnp.int32)))
                self.counters["migration_writebacks"] += 1
                self.clear_dirty([key[0]], [key[1]], info["dst"])
            else:
                self._release_frames(src, [src_slot])
            done += 1
        return done

    def _post_flush_lane(self, key: Tuple[int, int], node: int,
                         slot: int) -> None:
        """Defer a dirty eviction's byte capture onto a FLUSH lane.  The
        frame is already retired (S_WRITEBACK — pinned, never re-allocated),
        so capturing at lane service still reads the only materialized copy.
        The flush token registers eagerly: every pinned frame has exactly
        one outstanding obligation even while the capture is in flight, and
        _release_frames refuses the frame (flush-before-free) from the
        moment it retires."""
        self._wb_outstanding[(node, slot)] = key
        if self.trace is not None:
            # the obligation exists from the moment the token registers —
            # the audit's flush-before-free window opens here, not at the
            # deferred byte capture
            self.trace.emit(T.EV_WB_REG, node, slot, key[0], key[1])
        self._flush_meta[(node, key[0], key[1])] = slot
        self._lane_flushes.setdefault(node, []).append(
            (node, key[0], key[1]))
        self.counters["lane_flushes"] += 1
        # crash point: the obligation token is registered and the capture
        # rides a lane — a crash here must still flush the bytes (the
        # failover's lane fence services the capture before the wipe)
        self._check_crash("post_flush_register", node)

    def _service_flush_lanes(self, triples) -> int:
        """Receiver-side FLUSH service: capture the retired frame's bytes
        into a writeback obligation (the deferred _enqueue_writeback)."""
        done = 0
        for (node, stream, page) in triples:
            slot = self._flush_meta.pop((node, stream, page), None)
            if slot is None:
                continue   # already settled by a fence
            self._enqueue_writeback((stream, page), node, slot)
            done += 1
        return done

    def fence_data_lanes(self) -> int:
        """Force-settle every pending COPY/FLUSH lane — the data-plane
        analog of ``TLBGroup.fence``.  Teardown begins, flush barriers,
        failure/drain/rejoin entry points, and the engine's step boundary
        call this so nothing that observes frames, dirty bits, or the
        writeback queue can race an in-flight obligation.  Returns lanes
        settled."""
        if not self._lane_copies and not self._lane_flushes:
            return 0
        cp = [t for q in self._lane_copies.values() for t in q]
        fl = [t for q in self._lane_flushes.values() for t in q]
        self._lane_copies.clear()
        self._lane_flushes.clear()
        if self._h_fence is not None:
            self._h_fence.observe(len(cp) + len(fl))
        if self.trace is not None:
            self.trace.emit(T.EV_LANE_FENCE, CLUSTER, len(cp), len(fl))
        n = self._service_copy_lanes(cp) + self._service_flush_lanes(fl)
        self.counters["lane_fences"] += 1
        return n

    # -- shadow oracle (refimpl run in lockstep; divergence fails loudly) ------

    def _oracle_lookup(self, streams, pages, node: int, statuses) -> None:
        if self.oracle is None:
            return
        for s, p, st in zip(streams, pages, statuses):
            s, p, st = int(s), int(p), int(st)
            ref_st = self.oracle.lookup_and_install(s, p, int(node))[0]
            if st == D.ST_FULL and ref_st == D.ST_GRANT_E:
                # array shard / pool hit capacity before the oracle did:
                # back the oracle's install out to stay in lockstep
                self.oracle.abort_install(s, p, int(node))
            elif ref_st != st:
                self.counters["oracle_mismatches"] += 1

    def _oracle_op(self, fn: str, *args, expect: Optional[int] = None) -> None:
        if self.oracle is None:
            return
        out = getattr(self.oracle, fn)(*args)
        st = out[0] if isinstance(out, tuple) else out
        if expect is not None and st != expect:
            self.counters["oracle_mismatches"] += 1

    def _oracle_completion(self, fn: str, key: Tuple[int, int], args,
                           dirty: bool) -> None:
        """The satellite's loud assert: a completed invalidation/migration's
        dirty bit (pfn lane) must equal the oracle's needs_writeback."""
        if self.oracle is None:
            return
        st_ref, dirty_ref = getattr(self.oracle, fn)(key[0], key[1], *args)
        assert st_ref == D.ST_OK and bool(dirty_ref) == bool(dirty), (
            f"protocol/oracle divergence on {fn}{key}: oracle returned "
            f"(status={st_ref}, needs_writeback={dirty_ref}) but the "
            f"directory's pfn lane said dirty={dirty} — a writeback would "
            f"be dropped or double-issued")

    # -- read path (FUSE_DPC_READ) --------------------------------------------

    def read_pages(self, streams, pages, node: int) -> ReadResult:
        """Batched read-miss handling for ``node``.

        GRANT_E rows come back with a locally allocated frame (the paper's
        preallocated DMA target); the caller materializes contents (prefill /
        storage fetch) and must then call ``commit_pages``.  If the local pool
        is exhausted the grant is aborted (engine should reclaim + retry).
        """
        res, _ = self._routed(dirx.lookup_and_install, streams, pages, node)
        n = len(res)
        slots = np.full((n,), -1, np.int32)

        # pool mutations (alloc + CLOCK touch) build on one local PoolState
        # and land in a single _pool_update — the seed paid two to three
        # device-state swaps per read batch here
        pool = self.state.pools[node]
        dirty_pool = False
        grant_rows = np.nonzero(res[:, 0] == D.ST_GRANT_E)[0]
        if len(grant_rows):
            want = jnp.asarray(np.ones(len(grant_rows), bool))
            pool, got = pp.alloc(pool, want)
            dirty_pool = True
            got = np.asarray(got)
            slots[grant_rows] = got
            # pool exhausted -> abort those E grants (caller must reclaim)
            failed = grant_rows[got < 0]
            if len(failed):
                streams_a = np.asarray(streams, np.int32)[failed]
                pages_a = np.asarray(pages, np.int32)[failed]
                self._routed(dirx.abort_install, streams_a, pages_a, node)
                res[failed, 0] = D.ST_FULL

        # CLOCK touch on local hits
        local = np.nonzero(res[:, 0] == D.ST_HIT_OWNER)[0]
        if len(local):
            lslots = res[local, 2] % self.cfg.pool_pages
            pool = pp.touch(pool, jnp.asarray(lslots, jnp.int32))
            dirty_pool = True
        if dirty_pool:
            self._pool_update(node, pool)

        # fill the requester's mapping cache: established grants (own pages
        # and S-mappings) are servable TLB-side until a shootdown lands
        if self.tlbs is not None:
            streams_a = np.asarray(streams, np.int32)
            pages_a = np.asarray(pages, np.int32)
            for i in np.nonzero((res[:, 0] == D.ST_HIT_OWNER) |
                                (res[:, 0] == D.ST_MAP_S) |
                                (res[:, 0] == D.ST_HIT_SHARER))[0]:
                mode = (MODE_O if int(res[i, 0]) == D.ST_HIT_OWNER
                        else MODE_S)
                self.tlbs.install(node, int(streams_a[i]), int(pages_a[i]),
                                  int(res[i, 1]), int(res[i, 2]), mode)

        self._oracle_lookup(streams, pages, node, res[:, 0])

        c = self.counters
        c["reads"] += n
        c["grants"] += int((res[:, 0] == D.ST_GRANT_E).sum())
        c["remote_hits"] += int(((res[:, 0] == D.ST_MAP_S) |
                                 (res[:, 0] == D.ST_HIT_SHARER)).sum())
        c["local_hits"] += int((res[:, 0] == D.ST_HIT_OWNER).sum())
        c["blocked"] += int(((res[:, 0] == D.ST_BLOCKED) |
                             (res[:, 0] == D.ST_FULL)).sum())
        return ReadResult(res[:, 0], res[:, 1], res[:, 2], slots)

    # -- predictive promotion (prefix-tree prefetch) ---------------------------

    def promote_pages(self, streams, pages, node: int) -> np.ndarray:
        """Batched sharer-bit promotion for predicted pages (``map_shared``).

        The prefetch half of the read path: resident pages gain ``node``'s
        sharer bit plus a TLB entry (the later real lookup is then a zero-op
        cached hit), and their owner-side frames take a CLOCK touch so a
        predicted-hot page cannot be reclaimed out from under its prediction.
        Absent keys are misses — **nothing** is allocated for them, so a
        wrong prediction costs one inert descriptor row.  Returns the status
        vector (MAP_S / HIT_* / BLOCKED / BAD per row).
        """
        res, _ = self._routed(dirx.map_shared, streams, pages, node)
        n = len(res)
        if n == 0:
            return res[:, 0] if res.ndim == 2 else res
        hit_mask = ((res[:, 0] == D.ST_MAP_S) |
                    (res[:, 0] == D.ST_HIT_SHARER) |
                    (res[:, 0] == D.ST_HIT_OWNER))
        streams_a = np.asarray(streams, np.int32)
        pages_a = np.asarray(pages, np.int32)
        if self.tlbs is not None:
            for i in np.nonzero(hit_mask)[0]:
                mode = (MODE_O if int(res[i, 0]) == D.ST_HIT_OWNER
                        else MODE_S)
                self.tlbs.install(node, int(streams_a[i]), int(pages_a[i]),
                                  int(res[i, 1]), int(res[i, 2]), mode)
        # owner-side CLOCK credit: the promoted frame is about to be read
        touches: Dict[int, Dict[int, int]] = {}
        for i in np.nonzero(hit_mask)[0]:
            owner, pfn = int(res[i, 1]), int(res[i, 2])
            if pfn >= 0:
                slot = pfn % self.cfg.pool_pages
                touches.setdefault(owner, {})[slot] = \
                    touches.get(owner, {}).get(slot, 0) + 1
        for owner, buf in touches.items():
            self.touch_slots(owner, list(buf.keys()), list(buf.values()))
        if self.oracle is not None:
            # lockstep only where the op can mutate: on hit/blocked rows the
            # oracle's lookup_and_install transitions identically (sharer
            # add / no-op / blocked); a miss row must NOT drive the oracle —
            # its lookup would claim an E entry map_shared never creates
            for i in np.nonzero(hit_mask | (res[:, 0] == D.ST_BLOCKED))[0]:
                ref_st = self.oracle.lookup_and_install(
                    int(streams_a[i]), int(pages_a[i]), int(node))[0]
                if ref_st != int(res[i, 0]):
                    self.counters["oracle_mismatches"] += 1
        c = self.counters
        c["promotes"] += n
        c["promote_hits"] += int(hit_mask.sum())
        c["promote_misses"] += int((res[:, 0] == D.ST_BAD).sum())
        c["promote_blocked"] += int((res[:, 0] == D.ST_BLOCKED).sum())
        return res[:, 0]

    # -- commit (FUSE_DPC_UNLOCK) ----------------------------------------------

    def commit_pages(self, streams, pages, node: int, slots,
                     dirty=None) -> np.ndarray:
        """E -> O: publish global PFNs, bind keys to pool slots.

        ``dirty`` (bool or per-row sequence) marks rows whose contents exist
        *only* in the committed frame — a page materialized by prefill or a
        write has no durable copy, so its eventual eviction owes a writeback.
        Pages refilled *from* the backing store commit clean.
        """
        slots = np.asarray(slots, np.int32)
        pfns = np.where(slots >= 0,
                        node * self.cfg.pool_pages + slots, -1).astype(np.int32)
        res, _ = self._routed(dirx.commit, streams, pages, node, pfns)
        if self.oracle is not None:
            for s, p, pfn, st in zip(streams, pages, pfns, res[:, 0]):
                self._oracle_op("commit", int(s), int(p), int(node), int(pfn),
                                expect=int(st))
        keys = np.stack([np.asarray(streams, np.int32),
                         np.asarray(pages, np.int32)], -1)
        self._pool_update(node, pp.install(
            self.state.pools[node], jnp.asarray(slots), jnp.asarray(keys)))
        self.counters["commits"] += int((res[:, 0] == D.ST_OK).sum())
        if self.trace is not None:
            # residency interval opens: key -> frame.  The audit replays
            # these BINDs against single-copy and shootdown-before-remap.
            for i in np.nonzero((res[:, 0] == D.ST_OK) & (pfns >= 0))[0]:
                self.trace.emit(T.EV_BIND, node, int(keys[i, 0]),
                                int(keys[i, 1]), int(pfns[i]))
        if self.tlbs is not None:
            # a committed page is an established owner mapping: cache it
            # inline so the very next re-read is already directory-free
            for i in np.nonzero((res[:, 0] == D.ST_OK) & (pfns >= 0))[0]:
                self.tlbs.install(node, int(keys[i, 0]), int(keys[i, 1]),
                                  node, int(pfns[i]), MODE_O)
        if dirty is not None:
            dirty = np.broadcast_to(np.asarray(dirty, bool),
                                    np.asarray(streams).shape)
            rows = np.nonzero(dirty & (res[:, 0] == D.ST_OK))[0]
            if len(rows):
                self.mark_dirty(np.asarray(streams, np.int32)[rows],
                                np.asarray(pages, np.int32)[rows], node)
        # crash point: the commit is fully applied (directory, pool, TLB,
        # dirty marks) — a crash here must lose nothing already committed
        self._check_crash("post_commit", node)
        return res[:, 0]

    # -- write path ------------------------------------------------------------

    def write_prepare(self, streams, pages, node: int, strong: bool
                      ) -> ReadResult:
        """DPC_SC two-step write, step 1 (FUSE_DPC_LOOKUP_LOCK).

        Strong mode consults the directory for every page in the write range:
        absent pages are locked in E; remotely-owned pages come back as S
        mappings to write through (CXL keeps them coherent).  Established
        mappings are served TLB-first: a cached owner/shared grant answers
        the lock step with **zero directory ops and zero device round
        trips** — only the remaining rows run the read pipeline.  Relaxed
        mode is a no-op returning local-write statuses — pages not
        previously in DPC stay local-only and untracked (paper §5 Relaxed
        consistency).
        """
        if not strong:
            n = len(np.asarray(streams))
            z = np.zeros((n,), np.int32)
            return ReadResult(np.full((n,), D.ST_OK, np.int32),
                              z - 1, z - 1, z - 1)
        streams_a = np.asarray(streams, np.int32)
        pages_a = np.asarray(pages, np.int32)
        n = len(streams_a)
        if self.tlbs is None or not self.cfg.tlb_write_grants or n == 0:
            return self.read_pages(streams, pages, node)
        owners, pfns, modes, hit = self.tlbs.lookup_batch(node, streams_a,
                                                          pages_a)
        if not hit.any():
            return self.read_pages(streams, pages, node)
        status = np.zeros((n,), np.int32)
        owner_out = np.full((n,), -1, np.int32)
        pfn_out = np.full((n,), -1, np.int32)
        slots = np.full((n,), -1, np.int32)
        wtouch = self._wtouch_buf[node]
        for i in np.nonzero(hit)[0]:
            key = (int(streams_a[i]), int(pages_a[i]))
            shared = int(modes[i]) == MODE_S
            self.check_tlb_grant(key, node, int(owners[i]), int(pfns[i]),
                                 shared)
            status[i] = D.ST_HIT_SHARER if shared else D.ST_HIT_OWNER
            owner_out[i] = owners[i]
            pfn_out[i] = pfns[i]
            if not shared:
                # the directory path CLOCK-touched HIT_OWNER rows; buffer
                # the equivalent heat, flushed with the dirty marks
                slot = int(pfns[i]) % self.cfg.pool_pages
                wtouch[slot] = wtouch.get(slot, 0) + 1
        self.counters["write_prepare_hits"] += int(hit.sum())
        miss = np.nonzero(~hit)[0]
        if len(miss):
            sub = self.read_pages(streams_a[miss], pages_a[miss], node)
            status[miss] = sub.status
            owner_out[miss] = sub.owner
            pfn_out[miss] = sub.pfn
            slots[miss] = sub.slot
        return ReadResult(status, owner_out, pfn_out, slots)

    def mark_dirty(self, streams, pages, node: int) -> np.ndarray:
        """Register writes' dirty bits — TLB write grants first.

        Rows whose mapping is cached in owner mode complete with zero
        directory ops: a MODE_M entry means the bit is already registered
        (or buffered); a MODE_O hit buffers the key into the node's dirty
        set and upgrades the entry to MODE_M.  Sharer-mode hits buffer the
        same way (the write went through the coherent S mapping into the
        owner's frame — only the *bit* needs to reach the directory, and it
        can ride the batched flush or, if a teardown races in first, the
        node's INV_ACK dirty lane).  Buffered bits flush in ONE batched
        directory op per engine step (``flush_dirty_marks``) — and always
        before a teardown can observe the page, so the writeback obligation
        can never be lost.  Only true misses pay the per-call directory
        pipeline.
        """
        streams = np.asarray(streams, np.int32)
        pages = np.asarray(pages, np.int32)
        n = len(streams)
        status = np.full((n,), D.ST_OK, np.int32)
        miss = np.arange(n)
        if self.tlbs is not None and self.cfg.tlb_write_grants and n:
            owners, pfns, modes, hit = self.tlbs.lookup_batch(node, streams,
                                                              pages)
            own_hit = hit & (modes >= MODE_O)
            s_hit = hit & (modes == MODE_S)
            buf = self._dirty_buf[node]
            for i in np.nonzero(own_hit)[0]:
                key = (int(streams[i]), int(pages[i]))
                if int(modes[i]) != MODE_M:
                    buf.add(key)
                    self.tlbs.install(node, key[0], key[1], int(owners[i]),
                                      int(pfns[i]), MODE_M)
                    self.counters["dirty_buffered"] += 1
                self.check_tlb_write_grant(key, node, int(pfns[i]))
            for i in np.nonzero(s_hit)[0]:
                key = (int(streams[i]), int(pages[i]))
                self.check_tlb_grant(key, node, int(owners[i]),
                                     int(pfns[i]), True)
                if key not in buf:
                    buf.add(key)
                    self.counters["dirty_buffered"] += 1
            self.counters["tlb_write_hits"] += int((own_hit | s_hit).sum())
            miss = np.nonzero(~(own_hit | s_hit))[0]
        if len(miss):
            res, _ = self._routed(dirx.mark_dirty, streams[miss],
                                  pages[miss], node)
            if self.oracle is not None:
                for s, p, st in zip(streams[miss], pages[miss], res[:, 0]):
                    self._oracle_op("mark_dirty", int(s), int(p), int(node),
                                    expect=int(st))
            status[miss] = res[:, 0]
        return status

    def flush_dirty_marks(self, node: Optional[int] = None) -> int:
        """Flush buffered write-grant dirty bits in ONE batched directory op
        per node (the engine runs this at step boundaries; teardown begins
        run it first so no teardown can observe an unregistered bit).
        Returns keys flushed."""
        if self.tlbs is None:
            return 0
        which = range(self.cfg.num_nodes) if node is None else [node]
        total = 0
        for nd in which:
            tbuf = self._wtouch_buf[nd]
            if tbuf:
                # write-hit CLOCK heat lands with the same cadence, so the
                # reclaim scan never sees hot re-written pages as cold
                self.touch_slots(nd, list(tbuf.keys()), list(tbuf.values()))
                tbuf.clear()
            buf = self._dirty_buf[nd]
            if not buf:
                continue
            # keys mid-teardown stay buffered: TBI/TBM refuse mark_dirty, so
            # a sharer-buffered bit for a page whose owner started a reclaim
            # or migration rides the node's INV_ACK dirty lane instead
            # (_take_buffered_dirty) — flushing it here would land BAD and
            # drop the writeback obligation
            held = {k for k in buf
                    if k in self.pending_inv or k in self.pending_mig}
            keys = sorted(buf - held)
            buf.clear()
            buf.update(held)
            if not keys:
                continue
            res, _ = self._routed(dirx.mark_dirty,
                                  [k[0] for k in keys],
                                  [k[1] for k in keys], nd)
            if self.oracle is not None:
                for (s, p), st in zip(keys, res[:, 0]):
                    self._oracle_op("mark_dirty", s, p, nd, expect=int(st))
                    # a mark may legitimately outlive its entry: the owner
                    # died (fail_node wiped the key) between buffering and
                    # this flush — the data died with the owner and dropping
                    # the mark is correct.  An entry the oracle still holds
                    # means the flush-before-teardown fence was violated.
                    assert int(st) == D.ST_OK or \
                        (s, p) not in self.oracle.entries, (
                        f"buffered dirty mark for {(s, p)} on node {nd} "
                        f"landed {D.STATUS_NAMES.get(int(st), st)} — it was "
                        f"flushed after a teardown observed the page (the "
                        f"flush-before-teardown fence was violated)")
            total += len(keys)
            self.counters["dirty_mark_flushes"] += 1
        return total

    def _take_buffered_dirty(self, key: Tuple[int, int], node: int) -> bool:
        """Pop ``key`` from ``node``'s buffered dirty set.

        Sharer-side marks held back from the batched flush while the key is
        mid-teardown (TBI/TBM refuse mark_dirty) are carried by the node's
        INV_ACK / voluntary-drop dirty lane instead — the teardown paths
        call this to fold the buffered bit in."""
        buf = self._dirty_buf[node]
        if key in buf:
            buf.discard(key)
            return True
        return False

    def clear_dirty(self, streams, pages, node: int) -> np.ndarray:
        """CLEAR_DIRTY: drop the writeback obligation of pages whose bytes
        were just persisted out-of-band (the migration hand-off checkpoint).
        Owner-only; see directory.clear_dirty."""
        res, _ = self._routed(dirx.clear_dirty, streams, pages, node)
        if self.oracle is not None:
            for s, p, st in zip(streams, pages, res[:, 0]):
                self._oracle_op("clear_dirty", int(s), int(p), int(node),
                                expect=int(st))
        self.counters["dirty_clears"] += int((res[:, 0] == D.ST_OK).sum())
        return res[:, 0]

    # -- mapping cache (software TLB, core/tlb.py) -----------------------------

    def check_tlb_grant(self, key: Tuple[int, int], node: int, owner: int,
                        pfn: int, shared: bool) -> None:
        """Shadow-oracle single-copy assert: a TLB hit must never return a
        mapping the directory no longer grants.  Fails loudly (like the
        dirty-bit completion assert) instead of serving stale bytes."""
        if self.oracle is None:
            return
        ok, why = self.oracle.grants_mapping(key[0], key[1], node, owner,
                                             pfn, shared)
        assert ok, (
            f"stale TLB hit on node {node} for {key}: cached "
            f"(owner={owner}, pfn={pfn}, shared={shared}) but {why} — a "
            f"shootdown was lost and the single-copy invariant is broken")

    def check_tlb_write_grant(self, key: Tuple[int, int], node: int,
                              pfn: int) -> None:
        """Shadow-oracle write-grant assert: a MODE_M hit must still be the
        directory-granted owner AND its dirty bit must be registered or
        buffered — a violation means a writeback obligation would be lost."""
        if self.oracle is None:
            return
        ok, why, dirty = self.oracle.grants_write(key[0], key[1], node, pfn)
        assert ok, (
            f"stale TLB write grant on node {node} for {key}: cached "
            f"pfn={pfn} but {why} — a write landed on a revoked mapping")
        assert dirty or key in self._dirty_buf[node], (
            f"TLB write grant for {key} on node {node} has no registered "
            f"or buffered dirty bit — the writeback obligation was dropped")

    def _assert_no_late_shootdown(self, key: Tuple[int, int]) -> None:
        """Shadow-oracle completion assert: once a teardown transaction for
        ``key`` completes (all ACKs in, fence run), no node's mapping cache
        may still serve it — a holder means a piggybacked shootdown lane was
        lost past the fence."""
        if self.oracle is None or self.tlbs is None:
            return
        held = self.tlbs.holders(key)
        assert not held, (
            f"late shootdown: nodes {held} still cache {key} at teardown "
            f"completion — a piggybacked lane was lost past the fence")

    def touch_slots(self, node: int, slots, counts) -> None:
        """Flush a step's buffered TLB-hit CLOCK touches in ONE batched
        device call (pow2-padded to bound jit variants)."""
        slots = np.asarray(slots, np.int32)
        counts = np.asarray(counts, np.int32)
        n = len(slots)
        if n == 0:
            return
        n_pad = 1 << (n - 1).bit_length()
        if n_pad != n:
            slots = np.concatenate(
                [slots, np.full((n_pad - n,), -1, np.int32)])
            counts = np.concatenate(
                [counts, np.zeros((n_pad - n,), np.int32)])
        self._pool_update(node, pp.touch_weighted(
            self.state.pools[node], jnp.asarray(slots), jnp.asarray(counts)))

    # -- reclamation (§4.3) ------------------------------------------------------

    def reclaim_begin(self, node: int, want: int
                      ) -> Tuple[np.ndarray, Dict[Tuple[int, int], List[int]]]:
        """Owner-side LOCAL_INV: CLOCK scan -> TBI -> DIR_INV fan-out list.

        Returns (victim_slots, {key: [sharer nodes to notify]}).  Frames move
        to DRAINING (retained, I/O-blocked) — they are *not* freed until
        ``reclaim_finish`` observes all ACKs ("deterministic reclamation").
        """
        # write grants flush first: begin_invalidate moves entries to TBI,
        # which refuses mark_dirty — a buffered bit flushed any later would
        # be dropped and its writeback lost.  Keys owned by this node are
        # only ever buffered on this node (write grants are owner-only).
        # Lane-carried obligations settle first too: a committed migration
        # destination with a pending COPY must receive its bytes before the
        # scan could victimize (and capture) that frame.
        self.fence_data_lanes()
        self.flush_dirty_marks(node)
        pool, victims = pp.clock_scan(self.state.pools[node], want)
        victims_np = np.asarray(victims)
        victims_np = victims_np[victims_np >= 0]
        if len(victims_np) == 0:
            self._pool_update(node, pool)
            return victims_np, {}
        keys = np.asarray(pool.key_of)[victims_np]
        pool = pp.begin_drain(pool, jnp.asarray(victims_np))
        self._pool_update(node, pool)

        res, extra = self._routed(dirx.begin_invalidate,
                                  keys[:, 0], keys[:, 1], node)
        if self.oracle is not None:
            for (s, p), st in zip(keys, res[:, 0]):
                self._oracle_op("begin_invalidate", int(s), int(p), int(node),
                                expect=int(st))
        notify: Dict[Tuple[int, int], List[int]] = {}
        ok_rows = set(np.nonzero(res[:, 0] == D.ST_OK)[0].tolist())
        # rows the directory refused (e.g. the page is mid-MIGRATE, in TBM):
        # back the drain out so the frame stays usable and CLOCK-visible
        refused = victims_np[res[:, 0] != D.ST_OK]
        if len(refused):
            self._pool_update(node, pp.reinstate(
                self.state.pools[node], jnp.asarray(refused, jnp.int32)))
        for shard, (idxs, masks) in extra.items():
            for j, row in enumerate(idxs):
                if row not in ok_rows:
                    continue
                key = (int(keys[row, 0]), int(keys[row, 1]))
                sharer_nodes = _mask_to_nodes(masks[j])
                notify[key] = sharer_nodes
                self.pending_inv[key] = {
                    "owner": node, "slot": int(victims_np[row]),
                    "waiting": set(sharer_nodes),
                    "sharers": list(sharer_nodes),
                }
                if self.trace is not None:
                    self.trace.emit(T.EV_TBI_BEGIN, node, key[0], key[1],
                                    node, len(sharer_nodes))
                if self.tlbs is not None:
                    # TLB shootdown fan-out piggybacks on the DIR_INVs the
                    # directory just named: the initiating owner drops its
                    # entry now; each sharer's shootdown rides the lanes of
                    # the next batch routed its way (no later than its ACK)
                    self.tlbs.drop(node, key)
                    for s in sharer_nodes:
                        self.tlbs.post(s, key)
        self.counters["reclaims"] += len(notify)
        self.counters["dir_invs"] += sum(len(v) for v in notify.values())
        return victims_np, notify

    def reclaim_ack(self, stream: int, page: int, node: int,
                    dirty: bool = False) -> int:
        """FUSE_DPC_INV_ACK from sharer ``node`` (notification manager path).

        The ACK is itself a routed batch, so in piggyback mode the node's
        pending shootdown lanes ride it and are serviced before the ack
        executes — the ACK is still the sharer's promise that its mapping,
        including the cached one, is torn down (shootdown-before-complete).
        """
        if self.tlbs is not None and not self.cfg.tlb_piggyback:
            self.tlbs.service(node)   # legacy synchronous draining
        key = (stream, page)
        # a buffered sharer-side mark held back from the batched flush (the
        # key was already in TBI) rides this ACK's dirty lane
        dirty = bool(dirty) or self._take_buffered_dirty(key, node)
        res, _ = self._routed(dirx.ack_invalidate, [stream], [page], node,
                              [1 if dirty else 0])
        self._oracle_op("ack_invalidate", stream, page, node, dirty,
                        expect=int(res[0, 0]))
        if key in self.pending_inv:
            self.pending_inv[key]["waiting"].discard(node)
        self.counters["inv_acks"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_TBI_ACK, node, stream, page, node,
                            1 if dirty else 0)
        return int(res[0, 0])

    def reclaim_finish(self, node: int) -> Tuple[int, int]:
        """Complete all ready invalidations for ``node``: INVALIDATION_ACK ->
        writeback-if-dirty -> frames freed.  Returns (completed, writebacks).

        With a ``WritebackQueue`` attached, a dirty frame is NOT freed here:
        its bytes are captured into a flush obligation and the frame moves to
        S_WRITEBACK, reusable only after ``harvest_writebacks`` observes the
        batch sync (flush-before-free).  Clean frames keep the fast path.
        Without a queue the dirty bit is only counted — the seed behavior.
        """
        ready = [(k, v) for k, v in self.pending_inv.items()
                 if v["owner"] == node and not v["waiting"]]
        if not ready:
            return 0, 0
        # crash point: all ACKs are in but nothing completed — pending_inv
        # is intact, so failover cleanly retires the rounds this node owns
        self._check_crash("pre_reclaim_finish", node)
        if self.tlbs is not None:
            if self.cfg.tlb_piggyback:
                # bounded-staleness epoch fence: any named sharer still
                # behind its post epoch (ACK force-cleared, no batch traffic
                # since) gets a forced delivery before the entry can leave
                # the directory — completes always observe all teardowns
                self.tlbs.fence([s for _, v in ready
                                 for s in v.get("sharers", ())])
            else:
                # legacy safety net: drain every queue synchronously
                self.tlbs.service_all()
            for key, _ in ready:
                self._assert_no_late_shootdown(key)
        streams = [k[0] for k, _ in ready]
        pages = [k[1] for k, _ in ready]
        res, _ = self._routed(dirx.complete_invalidate, streams, pages, node)
        freed_slots, retired_slots, writebacks = [], [], 0
        for (key, info), row in zip(ready, res):
            if row[0] != D.ST_OK:
                continue
            is_dirty = bool(row[2])   # pfn lane = writeback flag
            self._oracle_completion("complete_invalidate", key, (node,),
                                    is_dirty)
            del self.pending_inv[key]
            if self.trace is not None:
                pfn = node * self.cfg.pool_pages + info["slot"]
                self.trace.emit(T.EV_UNBIND, node, key[0], key[1], pfn)
                self.trace.emit(T.EV_TBI_END, node, key[0], key[1],
                                int(row[0]), int(is_dirty))
            writebacks += int(is_dirty)
            if is_dirty and self.writeback is not None:
                if self.cfg.async_data_plane:
                    # defer the byte capture onto a FLUSH lane: the frame
                    # retires now (pinned in S_WRITEBACK), the enqueue rides
                    # the next batch routed for this node or the next fence
                    self._post_flush_lane(key, node, info["slot"])
                else:
                    self._enqueue_writeback(key, node, info["slot"])
                retired_slots.append(info["slot"])
            else:
                freed_slots.append(info["slot"])
        if retired_slots:
            self._pool_update(node, pp.retire(
                self.state.pools[node],
                jnp.asarray(retired_slots, jnp.int32)))
        if freed_slots:
            self._release_frames(node, freed_slots)
        self.counters["writebacks"] += writebacks
        self.pool_counters["evict_clean"] += len(freed_slots)
        self.pool_counters["evict_dirty"] += len(retired_slots)
        return len(freed_slots) + len(retired_slots), writebacks

    def reclaim_sync(self, node: int, want: int,
                     ack_fn=None) -> Tuple[int, int]:
        """One full synchronous reclamation round (used by µbenchmarks and
        under memory pressure): LOCAL_INV -> deliver DIR_INVs (``ack_fn`` lets
        the engine tear down real page-table mappings) -> finish."""
        _, notify = self.reclaim_begin(node, want)
        for key, sharer_nodes in notify.items():
            for s in sharer_nodes:
                if ack_fn is not None:
                    ack_fn(key, s)
                self.reclaim_ack(key[0], key[1], s)
        return self.reclaim_finish(node)

    # -- ownership migration (hotness-driven hand-off; core/migration.py) -------

    def migrate_begin(self, pairs: Sequence[Tuple[Tuple[int, int], int]]
                      ) -> Tuple[np.ndarray,
                                 Dict[Tuple[int, int], List[int]]]:
        """Batched MIGRATE step 1: O -> TBM for each ((stream, page), dst).

        Returns (statuses [N], {key: [sharer nodes to DIR_INV]}).  The source
        frame moves to DRAINING (retained — it is still the only valid copy
        and serves reads-in-flight) and the directory fans DIR_INV to every
        sharer; the hand-off completes in ``migrate_finish`` only after all
        ACKs, exactly like deterministic reclamation.  Keys already in an
        invalidation or migration round are skipped (BLOCKED)."""
        # sources are only known after the directory answers, so every
        # node's buffered write-grant dirty bits flush before any O -> TBM
        # transition can make a late mark_dirty land BAD.  In-flight data
        # lanes settle first for the same reason a reclaim fences: a page
        # whose COPY is still riding must not become a migration source
        # before its bytes land.
        self.fence_data_lanes()
        self.flush_dirty_marks()
        n = len(pairs)
        statuses = np.full((n,), D.ST_BLOCKED, np.int32)
        rows = [i for i, (key, _) in enumerate(pairs)
                if key not in self.pending_inv and key not in self.pending_mig]
        # a key may appear twice in one batch: the directory serializes them
        # (first wins, second BLOCKED), mirroring same-batch read semantics
        if not rows:
            return statuses, {}
        streams = [pairs[i][0][0] for i in rows]
        pages = [pairs[i][0][1] for i in rows]
        dsts = np.asarray([pairs[i][1] for i in rows], np.int32)
        res, extra = self._routed(dirx.begin_migrate, streams, pages, dsts)
        statuses[rows] = res[:, 0]
        if self.oracle is not None:
            for s, p, dst, st in zip(streams, pages, dsts, res[:, 0]):
                self._oracle_op("begin_migrate", int(s), int(p), int(dst),
                                expect=int(st))

        notify: Dict[Tuple[int, int], List[int]] = {}
        ok = res[:, 0] == D.ST_OK
        self.counters["migration_noops"] += int(
            (res[:, 0] == D.ST_HIT_OWNER).sum())
        masks_by_row: Dict[int, np.ndarray] = {}
        for shard, (idxs, masks) in extra.items():
            for j, row in enumerate(idxs):
                masks_by_row[row] = masks[j]
        for j, row_ok in enumerate(ok):
            if not row_ok:
                continue
            key = (int(streams[j]), int(pages[j]))
            src, old_pfn = int(res[j, 1]), int(res[j, 2])
            src_slot = old_pfn % self.cfg.pool_pages
            sharer_nodes = _mask_to_nodes(masks_by_row[j])
            self._pool_update(src, pp.begin_drain(
                self.state.pools[src], jnp.asarray([src_slot], jnp.int32)))
            notify[key] = sharer_nodes
            self.pending_mig[key] = {
                "src": src, "dst": int(dsts[j]), "src_slot": src_slot,
                "old_pfn": old_pfn, "waiting": set(sharer_nodes),
                "sharers": list(sharer_nodes),
            }
            if self.trace is not None:
                self.trace.emit(T.EV_TBM_BEGIN, src, key[0], key[1],
                                src, int(dsts[j]))
            if self.tlbs is not None:
                # same shootdown discipline as reclamation: the source's
                # owner-mode entry dies now; each sharer's shootdown (the
                # destination is usually among them) rides the piggyback
                # lanes of the next batch routed its way
                self.tlbs.drop(src, key)
                for s in sharer_nodes:
                    self.tlbs.post(s, key)
            self.counters["dir_invs"] += len(sharer_nodes)
        return statuses, notify

    def migrate_ack(self, stream: int, page: int, node: int,
                    dirty: bool = False) -> int:
        """Sharer ACK for a migration DIR_INV (same opcode as reclamation;
        the ACK batch carries the node's pending shootdown lanes)."""
        if self.tlbs is not None and not self.cfg.tlb_piggyback:
            self.tlbs.service(node)   # legacy synchronous draining
        key = (stream, page)
        # held-back sharer-side marks ride the migration ACK the same way
        # they ride a reclamation ACK
        dirty = bool(dirty) or self._take_buffered_dirty(key, node)
        res, _ = self._routed(dirx.ack_invalidate, [stream], [page], node,
                              [1 if dirty else 0])
        self._oracle_op("ack_invalidate", stream, page, node, dirty,
                        expect=int(res[0, 0]))
        if key in self.pending_mig:
            self.pending_mig[key]["waiting"].discard(node)
        self.counters["migration_acks"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_TBM_ACK, node, stream, page, node,
                            1 if dirty else 0)
        return int(res[0, 0])

    def _migrate_abort(self, key: Tuple[int, int], info: Dict) -> None:
        """Back a migration out: TBM -> E@src -> COMMIT restores O@src with
        the original frame (commit re-installs the key over the retained
        DRAINING slot, which doubles as the reinstate)."""
        res, _ = self._routed(dirx.complete_migrate, [key[0]], [key[1]],
                              info["src"], [info["src"]])
        if res[0, 0] == D.ST_OK:
            self._oracle_completion("complete_migrate", key,
                                    (info["src"], info["src"]),
                                    bool(res[0, 2]))
            if self.trace is not None:
                # the abort's commit re-binds the retained source frame:
                # close the old residency interval first so the replay sees
                # unbind -> (re)bind, not a double-bind
                self.trace.emit(T.EV_UNBIND, info["src"], key[0], key[1],
                                info["old_pfn"])
            self.commit_pages([key[0]], [key[1]], info["src"],
                              [info["src_slot"]])
        if self.trace is not None:
            self.trace.emit(T.EV_TBM_END, info["src"], key[0], key[1],
                            -1, info["old_pfn"])
        self.counters["migration_aborts"] += 1

    def migrate_finish(self, copy_fn=None
                       ) -> List[Tuple[Tuple[int, int], int, int]]:
        """Complete every migration whose sharer ACKs are all in.

        Per ready key: allocate a frame at the destination, TBM -> E@dst,
        copy the page (``copy_fn(key, src_pfn, dst_pfn)`` is the data-plane
        hook), COMMIT at the destination (publishes the new PFN), then free
        the source frame.  Destination pool exhaustion aborts that hand-off
        (ownership stays at the source — migration is best-effort and must
        never lose the only copy).  Returns [(key, src_pfn, dst_pfn)] for
        page-table rewriting by the caller."""
        ready = [(k, v) for k, v in self.pending_mig.items()
                 if not v["waiting"]]
        if ready and self.tlbs is not None:
            if self.cfg.tlb_piggyback:
                # shootdown-before-complete: fence the named sharers so no
                # undelivered lane survives the hand-off
                self.tlbs.fence([s for _, v in ready
                                 for s in v.get("sharers", ())])
            else:
                self.tlbs.service_all()   # legacy safety net
        moved: List[Tuple[Tuple[int, int], int, int]] = []
        for key, info in ready:
            # crash point: the hand-off for this key has not begun — its
            # pending_mig entry is intact, the source frame still DRAINING,
            # so a source crash here re-homes through the ordinary path
            self._check_crash("pre_migrate_finish", info["src"])
            self._assert_no_late_shootdown(key)
            del self.pending_mig[key]
            src, dst = info["src"], info["dst"]
            if dst == src:  # retargeted after a destination failure
                self._migrate_abort(key, info)
                continue
            pool, got = pp.alloc(self.state.pools[dst],
                                 jnp.ones((1,), bool))
            self._pool_update(dst, pool)
            dst_slot = int(np.asarray(got)[0])
            if dst_slot < 0:
                self._migrate_abort(key, info)
                continue
            res, _ = self._routed(dirx.complete_migrate, [key[0]], [key[1]],
                                  dst, [src])
            if res[0, 0] != D.ST_OK:
                # src died mid-round (entry gone) or state changed under us:
                # give the reserved frame back and drop the transaction
                self._release_frames(dst, [dst_slot])
                self.counters["migration_aborts"] += 1
                if self.trace is not None:
                    self.trace.emit(T.EV_TBM_END, dst, key[0], key[1],
                                    int(res[0, 0]), -1)
                continue
            was_dirty = bool(res[0, 2])
            self._oracle_completion("complete_migrate", key, (dst, src),
                                    was_dirty)
            dst_pfn = dst * self.cfg.pool_pages + dst_slot
            if self.trace is not None:
                # ownership left the source at complete_migrate: the old
                # residency interval closes here, before the destination's
                # commit re-binds the key (the orphaned source frame is an
                # anonymous staging buffer from now on)
                self.trace.emit(T.EV_UNBIND, src, key[0], key[1],
                                info["old_pfn"])
            if self.cfg.async_data_plane:
                # overlap the hand-off's data plane: commit the new owner
                # now, defer the KV copy (and the dirty checkpoint /
                # source free) onto a COPY lane riding the next batch
                # routed for the destination.  The source frame stays
                # DRAINING (pinned, scan-invisible) until the lane lands.
                self.commit_pages([key[0]], [key[1]], dst, [dst_slot])
                self._post_copy_lane(key, src, info["src_slot"], dst,
                                     info["old_pfn"], dst_pfn, was_dirty,
                                     copy_fn)
                # the destination is the key's canonical copy from here on;
                # the source stays pinned as an anonymous staging buffer so
                # single-copy holds while the lane is in flight
                self._pool_update(src, pp.orphan(
                    self.state.pools[src],
                    jnp.asarray([info["src_slot"]], jnp.int32)))
            else:
                if copy_fn is not None:
                    copy_fn(key, info["old_pfn"], dst_pfn)
                # dirty=True: the hand-off carries the writeback obligation
                # (the directory keeps the dirty bit at the new owner)
                self.commit_pages([key[0]], [key[1]], dst, [dst_slot])
                if was_dirty and self.writeback is not None:
                    # checkpoint the moving page: enqueue the *source*
                    # frame's bytes (still the materialized copy) and pin
                    # it until the flush commits — migration must never
                    # free the only unpersisted copy of a dirty page
                    self._enqueue_writeback(key, src, info["src_slot"])
                    self._pool_update(src, pp.retire(
                        self.state.pools[src],
                        jnp.asarray([info["src_slot"]], jnp.int32)))
                    self.counters["migration_writebacks"] += 1
                    # the hand-off just checkpointed the page's bytes, so
                    # the entry at the new owner starts clean — CLEAR_DIRTY
                    # stops a second writeback on eviction
                    self.clear_dirty([key[0]], [key[1]], dst)
                else:
                    self._release_frames(src, [info["src_slot"]])
            self.counters["migrations"] += 1
            if self.trace is not None:
                self.trace.emit(T.EV_TBM_END, dst, key[0], key[1],
                                int(D.ST_OK), dst_pfn)
            moved.append((key, info["old_pfn"], dst_pfn))
        return moved

    def migrate_sync(self, pairs: Sequence[Tuple[Tuple[int, int], int]],
                     ack_fn=None, copy_fn=None
                     ) -> List[Tuple[Tuple[int, int], int, int]]:
        """One full synchronous MIGRATE round: begin -> deliver DIR_INVs
        (``ack_fn`` lets the engine tear down real mappings) -> finish."""
        _, notify = self.migrate_begin(pairs)
        for key, sharer_nodes in notify.items():
            for s in sharer_nodes:
                if ack_fn is not None:
                    ack_fn(key, s)
                self.migrate_ack(key[0], key[1], s)
        return self.migrate_finish(copy_fn=copy_fn)

    # -- sharer-side voluntary drop ---------------------------------------------

    def drop_mapping(self, streams, pages, node: int, dirty=None) -> np.ndarray:
        if self.tlbs is not None:
            # the voluntary drop is its own shootdown: the cached mapping
            # dies with the real one, before the directory clears the bit
            for s, p in zip(streams, pages):
                self.tlbs.drop(node, (int(s), int(p)))
        n = len(np.asarray(streams))
        aux = (np.zeros((n,), np.int32) if dirty is None
               else np.broadcast_to(np.asarray(dirty, np.int32),
                                    (n,)).copy())
        # buffered sharer-side marks for the dropped keys ride the drop's
        # dirty lane (the S mapping is gone — the flush could no longer
        # register them)
        for i, (s, p) in enumerate(zip(streams, pages)):
            if self._take_buffered_dirty((int(s), int(p)), node):
                aux[i] = 1
        res, _ = self._routed(dirx.sharer_drop, streams, pages, node, aux)
        if self.oracle is not None:
            for s, p, dd, st in zip(streams, pages, aux, res[:, 0]):
                self._oracle_op("sharer_drop", int(s), int(p), int(node),
                                bool(dd), expect=int(st))
        return res[:, 0]

    # -- liveness (paper §5) ------------------------------------------------------

    def fail_node(self, node: int, rehome_to: Optional[int] = None,
                  install_fn: Optional[Callable] = None) -> int:
        """Failover (heartbeat loss): remove the node everywhere and unblock
        any invalidation waiting on its ACK.

        With ``rehome_to`` given (and a durable tier attached), pages the
        dead node owned are not simply dropped: every orphan whose bytes
        survive in the backing store — or in the still-pending writeback
        queue (read-your-writes: a crash mid-flush must recover the
        last-committed bytes) — is refilled into E-state on the surviving
        node (``install_fn(key, pfn, data)`` is the data-plane hook) and
        committed clean.  An orphan with no durable copy is gone; if its
        dirty bit was registered that is a lost committed write and counts
        into ``lost_dirty_pages`` — zero whenever a checkpoint or writeback
        preceded the crash.  Returns owned entries dropped."""
        t0 = time.perf_counter()
        # survivor-side cleanup legitimately routes batches *for* the dead
        # (possibly fenced) node — synthesized ACKs, forced completions —
        # so the fence check stands down for the duration; crash points
        # disarm too (recovery for one crash must not trip another)
        self._fence_bypass += 1
        if self.faults is not None:
            self.faults.disarm()
        try:
            return self._fail_node_inner(node, rehome_to, install_fn, t0)
        finally:
            if self.faults is not None:
                self.faults.rearm()
            self._fence_bypass -= 1

    def _fail_node_inner(self, node: int, rehome_to: Optional[int],
                         install_fn: Optional[Callable],
                         t0: float) -> int:
        # settle in-flight lane obligations before anything dies: a pending
        # COPY whose source is the failing node still has its only copy
        # pinned in DRAINING — servicing it now lands the bytes (and any
        # dirty checkpoint) exactly as the sync path already had; dropping
        # it would lose committed dirty bytes
        self.fence_data_lanes()
        # register surviving buffered dirty bits while their entries still
        # exist (the failing node's own marks die with its data — flushing
        # them first keeps the flush-status assert honest)
        self.flush_dirty_marks()
        # marks the dead node buffered for keys already mid-teardown were
        # held back from that flush; synthesize the node's ACK now, while
        # its sharer bit is still set, so the dirty bit survives the wipe
        for key, info in list(self.pending_inv.items()):
            if node in info["waiting"] and key in self._dirty_buf[node]:
                self.reclaim_ack(key[0], key[1], node)
        for key, info in list(self.pending_mig.items()):
            if node in info["waiting"] and key in self._dirty_buf[node]:
                self.migrate_ack(key[0], key[1], node)
        self._dirty_buf[node].clear()
        self._wtouch_buf[node].clear()
        # orphan census before the wipe: pages the dead node owned, with
        # their registered dirty bits.  E entries have no committed copy to
        # recover — they die as uncommitted installs.
        orphans: List[Tuple[Tuple[int, int], bool]] = []
        if rehome_to is not None and rehome_to != node:
            for key, (st, owner, _sh, _pfn, dirty) in \
                    self.directory_view().items():
                if owner == node and st != dirx.E:
                    orphans.append((key, bool(dirty)))
        if self.trace is not None:
            # the audit retires the dead node's frame range and writeback
            # obligations on this edge, exactly like the protocol does
            self.trace.emit(T.EV_FAIL, node,
                            -1 if rehome_to is None else rehome_to)
        if self.tlbs is not None:
            # fail_node wipes directory entries wholesale without naming
            # keys, so precise shootdowns cannot cover it — the global
            # epoch flash invalidates every cached mapping cluster-wide
            self.tlbs.flash_all()
        dirs = list(self.state.dirs)
        lost = 0
        for i, dshard in enumerate(dirs):
            dshard, n_owned = dirx.fail_node(dshard, jnp.int32(node))
            dirs[i] = dshard
            lost += int(n_owned)
        self.state = self.state._replace(dirs=tuple(dirs))
        if self.oracle is not None:
            self.oracle.fail_node(node)
        # the fence point: the TLB flash + directory wipe just made the
        # dead node's mappings unservable cluster-wide.  Detection -> here
        # is the window a stale mapping could still have served.
        self._member_lat["detect_to_fence_us"] += max(
            1, int((time.perf_counter() - t0) * 1e6))
        t_fence = time.perf_counter()
        for key, info in list(self.pending_inv.items()):
            info["waiting"].discard(node)
            if info["owner"] == node:
                del self.pending_inv[key]
        for key, info in list(self.pending_mig.items()):
            info["waiting"].discard(node)
            if info["src"] == node:
                # the only copy died with its owner: the directory entry is
                # gone (dirx.fail_node) — nothing to hand over
                del self.pending_mig[key]
            elif info["dst"] == node:
                # destination died: retarget the hand-off at the source —
                # migrate_finish treats dst == src as the abort path once
                # the remaining sharer ACKs drain
                info["dst"] = info["src"]
        self.counters["dropped_nodes"] += 1
        if orphans:
            self._rehome_orphans(orphans, rehome_to, install_fn)
        self._member_lat["fence_to_recover_us"] += max(
            1, int((time.perf_counter() - t_fence) * 1e6))
        return lost

    def _rehome_orphans(self, orphans: List[Tuple[Tuple[int, int], bool]],
                        rehome_to: int,
                        install_fn: Optional[Callable]) -> None:
        """Failover recovery: refill each orphan from the durable tier into
        E-state on the survivor and commit it clean (the durable copy stays
        the backstop).  Orphans that find no room are deferred, not lost —
        the durable bytes still serve the next fault's refill."""
        c = self.counters
        for key, dirty in sorted(orphans):
            data = None
            if self.writeback is not None:
                data = self.writeback.peek(key)   # read-your-writes
            if data is None and self.store is not None:
                data = self.store.read(key[0], key[1])
            if data is None:
                if dirty:
                    c["lost_dirty_pages"] += 1
                continue
            rr = self.read_pages([key[0]], [key[1]], rehome_to)
            if int(rr.status[0]) == D.ST_GRANT_E and int(rr.slot[0]) >= 0:
                slot = int(rr.slot[0])
                if install_fn is not None:
                    install_fn(key, rehome_to * self.cfg.pool_pages + slot,
                               data)
                self.commit_pages([key[0]], [key[1]], rehome_to, [slot])
                c["rehomed_pages"] += 1
            else:
                # survivor pool full: deferred, not lost
                c["rehome_deferred"] += 1

    # -- elastic membership (join / drain / rejoin) ------------------------------

    def add_node(self) -> int:
        """Join: grow the cluster by one node, returning its id.

        The newcomer gets a fresh pool, mapping cache, and dirty/heat
        buffers; directory sharer masks widen if the node count crosses a
        32-bit word boundary.  Shard placement is frozen at init
        (``num_shards``), so no existing entry moves — the join is
        metadata-only until ``OwnershipMigrator.rebalance_join`` seeds the
        node with cold pages through ordinary MIGRATE rounds."""
        node = self.cfg.num_nodes
        old_words = (self.cfg.num_nodes + 31) // 32
        self.cfg.num_nodes += 1
        new_words = (self.cfg.num_nodes + 31) // 32
        if new_words != old_words:
            # widen every shard's sharer bitmask; opcodes key on the array
            # shape, so the next batch recompiles against the new width
            dirs = tuple(
                d._replace(sharers=jnp.pad(
                    d.sharers, ((0, 0), (0, new_words - old_words))))
                for d in self.state.dirs)
            self.state = self.state._replace(dirs=dirs)
        self.state = self.state._replace(
            pools=self.state.pools + (pp.init_pool(self.cfg.pool_pages),))
        if self.tlbs is not None:
            self.tlbs.add_node()
        self._dirty_buf.append(set())
        self._wtouch_buf.append({})
        if self.oracle is not None:
            self.oracle.num_nodes = self.cfg.num_nodes
        self.counters["joins"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_JOIN, node, self.cfg.num_nodes)
        return node

    def rejoin_node(self, node: int) -> None:
        """A previously drained/failed node comes back empty-handed: fresh
        pool, wiped mapping cache, cleared buffers.  Flush obligations from
        its previous life keep flushing (durability is not rewound) but
        their frame tokens go stale — harvest must not release them into
        the reborn pool."""
        assert 0 <= node < self.cfg.num_nodes
        # pending FLUSH lanes must capture against the OLD pool before it is
        # re-initialized — their tokens then go stale like any other
        # outstanding flush of the previous incarnation
        self.fence_data_lanes()
        for token in list(self._wb_outstanding):
            if token[0] == node:
                self._wb_stale.add(token)
        pools = list(self.state.pools)
        pools[node] = pp.init_pool(self.cfg.pool_pages)
        self.state = self.state._replace(pools=tuple(pools))
        if self.trace is not None:
            self.trace.emit(T.EV_POOL_RESET, node)
        if self.tlbs is not None:
            self.tlbs.wipe(node)
        self._dirty_buf[node].clear()
        self._wtouch_buf[node].clear()
        self.counters["rejoins"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_REJOIN, node,
                            self.obs.registry.incarnations.get(node, 0) + 1
                            if self.obs.registry is not None else 0)
        # incarnation fold (the counter-reset semantics): the reborn node's
        # per-node live rows restart at zero, their history folds into the
        # monotonic cluster totals
        self.obs.reset_node(node)

    def drain_node(self, node: int, dest_fn: Optional[Callable] = None,
                   copy_fn: Optional[Callable] = None) -> Dict:
        """Planned departure: evacuate everything ``node`` holds *before* it
        leaves, then retire its mapping cache with a precise per-node wipe —
        no global epoch flash, so every other node's warm TLB survives.

        Sequence (each step an ordinary protocol transaction):
          1. flush the buffered dirty marks / write heat (fence),
          2. settle in-flight teardowns involving the node: deliver its
             outstanding sharer ACKs (held-back buffered dirty bits ride
             the ACK dirty lane), force-complete rounds it owns and
             migrations it sources, retarget migrations headed *to* it,
          3. voluntarily drop its remaining sharer mappings,
          4. abort its uncommitted E-state installs, release the frames,
          5. batch-MIGRATE every page it owns to destinations picked by
             ``dest_fn(key) -> node`` (default round-robin over the
             others); dirty pages checkpoint through the writeback queue
             exactly like any other hand-off,
          6. flush barrier: its writeback obligations become durable,
          7. precise TLB retirement for this node only.

        Returns a stats dict; ``moved`` lists (key, old_pfn, new_pfn) for
        page-table rewriting by the caller."""
        # the drain routes batches on the leaver's behalf throughout; if
        # the leaver is (or becomes) fenced the evacuation must still run
        self._fence_bypass += 1
        try:
            return self._drain_node_inner(node, dest_fn, copy_fn)
        finally:
            self._fence_bypass -= 1

    def _drain_node_inner(self, node: int, dest_fn: Optional[Callable],
                          copy_fn: Optional[Callable]) -> Dict:
        cfg = self.cfg
        stats: Dict = {"migrated": 0, "aborted": 0, "e_aborted": 0,
                       "shares_dropped": 0, "moved": []}
        if self.trace is not None:
            self.trace.emit(T.EV_DRAIN_BEGIN, node)
        # in-flight lane obligations involving the leaver settle up front —
        # the drain must observe the same frames and dirty bits the sync
        # reference mode would
        self.fence_data_lanes()
        self.flush_dirty_marks()
        for key, info in list(self.pending_inv.items()):
            if node in info["waiting"]:
                self.reclaim_ack(key[0], key[1], node)
        for key, info in list(self.pending_mig.items()):
            if node in info["waiting"]:
                self.migrate_ack(key[0], key[1], node)
        # force-settle rounds the node drives: the drain is a synchronous
        # protocol driver (like reclaim_sync), so it delivers the remaining
        # sharers' DIR_INVs itself and completes the transactions
        if any(v["owner"] == node for v in self.pending_inv.values()):
            for key, info in list(self.pending_inv.items()):
                if info["owner"] == node:
                    for s in list(info["waiting"]):
                        self.reclaim_ack(key[0], key[1], s)
            self.reclaim_finish(node)
        if self.pending_mig:
            settle = False
            for key, info in list(self.pending_mig.items()):
                if info["dst"] == node:
                    info["dst"] = info["src"]   # abort: ownership stays put
                if info["src"] == node:
                    settle = True
                    for s in list(info["waiting"]):
                        self.migrate_ack(key[0], key[1], s)
            if settle:
                stats["moved"].extend(self.migrate_finish(copy_fn=copy_fn))
        view = self.directory_view()
        # 3. sharer-side retirement: later teardowns must not wait on a
        # departed node's ACK
        shared = sorted(k for k, v in view.items() if node in v[2])
        if shared:
            self.drop_mapping([k[0] for k in shared],
                              [k[1] for k in shared], node)
            stats["shares_dropped"] = len(shared)
        # 4. uncommitted installs: nothing materialized to preserve
        e_keys = sorted(k for k, v in view.items()
                        if v[1] == node and v[0] == dirx.E)
        if e_keys:
            res, _ = self._routed(dirx.abort_install,
                                  [k[0] for k in e_keys],
                                  [k[1] for k in e_keys], node)
            if self.oracle is not None:
                for (s, p), st in zip(e_keys, res[:, 0]):
                    self._oracle_op("abort_install", s, p, node,
                                    expect=int(st))
            stats["e_aborted"] = len(e_keys)
        reserved = np.nonzero(np.asarray(
            self.state.pools[node].slot_state) == pp.S_RESERVED)[0]
        if len(reserved):
            self._release_frames(node, reserved.tolist())
        # 5. evacuate ownership through ordinary MIGRATE transactions
        owned = sorted(k for k, v in view.items()
                       if v[1] == node and v[0] == dirx.O)
        others = [n for n in range(cfg.num_nodes) if n != node]

        def _chunk_pairs(i):
            chunk = owned[i:i + 64]
            pairs = []
            for j, key in enumerate(chunk):
                dst = dest_fn(key) if dest_fn is not None else -1
                if dst is None or dst < 0 or dst == node \
                        or dst >= cfg.num_nodes:
                    dst = others[(i + j) % len(others)]
                pairs.append((key, int(dst)))
            return pairs

        if cfg.async_data_plane:
            # overlapped rounds: chunk k+1's DIR_INV fan-out goes out before
            # chunk k's ACKs are delivered and completed, so two evacuation
            # rounds are always in flight (the COPY lanes their completions
            # post ride the next round's batches)
            prev_notify: Dict[Tuple[int, int], List[int]] = {}
            for i in range(0, len(owned), 64):
                _, notify = self.migrate_begin(_chunk_pairs(i))
                for key, sharer_nodes in prev_notify.items():
                    for s in sharer_nodes:
                        self.migrate_ack(key[0], key[1], s)
                prev_notify = notify
                stats["moved"].extend(self.migrate_finish(copy_fn=copy_fn))
                self._check_crash("mid_drain_chunk", node)
            for key, sharer_nodes in prev_notify.items():
                for s in sharer_nodes:
                    self.migrate_ack(key[0], key[1], s)
            stats["moved"].extend(self.migrate_finish(copy_fn=copy_fn))
        else:
            for i in range(0, len(owned), 64):
                stats["moved"].extend(
                    self.migrate_sync(_chunk_pairs(i), copy_fn=copy_fn))
                self._check_crash("mid_drain_chunk", node)
        stats["migrated"] = len(stats["moved"])
        owned_set = set(owned)
        stats["aborted"] = len(owned) - sum(
            1 for k, _o, _n in stats["moved"] if k in owned_set)
        if self.writeback is not None:
            # 6. the departing node's obligations become durable; retired
            # source frames are harvested
            self.flush()
        if self.tlbs is not None:
            self.tlbs.wipe(node)
        self._dirty_buf[node].clear()
        self._wtouch_buf[node].clear()
        c = self.counters
        c["drains"] += 1
        c["drained_pages"] += stats["migrated"]
        c["drain_aborts"] += stats["aborted"]
        if self.trace is not None:
            self.trace.emit(T.EV_DRAIN_END, node, stats["migrated"],
                            stats["aborted"], stats["shares_dropped"])
        return stats

    def checkpoint_dirty(self, node: Optional[int] = None) -> int:
        """Persist every registered dirty page's bytes out-of-band (token-
        less obligations — no frame pins) and clear the dirty bits: the
        planned-crash fsync that makes a subsequent failover lossless.
        ``node`` restricts the sweep to one owner.  Returns pages
        checkpointed."""
        if self.writeback is None or self.page_bytes_fn is None:
            return 0
        # a checkpoint sweeps the dirty set — lane-carried copies and
        # captures must land first so the sweep sees settled state
        self.fence_data_lanes()
        self.flush_dirty_marks()
        by_owner: Dict[int, List[Tuple[int, int]]] = {}
        for key, (st, owner, _sh, pfn, dirty) in \
                self.directory_view().items():
            if not dirty or st != dirx.O:
                continue
            if node is not None and owner != node:
                continue
            data = self.page_bytes_fn(key, pfn)
            if data is None:
                continue
            self.writeback.enqueue(key, np.asarray(data))
            by_owner.setdefault(owner, []).append(key)
        total = 0
        for owner, keys in by_owner.items():
            self.clear_dirty([k[0] for k in keys],
                             [k[1] for k in keys], owner)
            if self.tlbs is not None:
                # MODE_M entries promised a registered-or-buffered bit the
                # clear just dropped — downgrade to MODE_O so the next
                # write re-registers instead of tripping the write-grant
                # oracle assert
                for k in keys:
                    hit = self.tlbs.lookup(owner, k[0], k[1])
                    if hit is not None and hit[2] == MODE_M:
                        self.tlbs.install(owner, k[0], k[1], hit[0],
                                          hit[1], MODE_O)
            total += len(keys)
        self.counters["checkpointed_pages"] += total
        return total

    # -- views ---------------------------------------------------------------

    def directory_view(self) -> Dict:
        out = {}
        dcfg = self.cfg.dir_config()
        for dshard in self.state.dirs:
            out.update(dirx.to_host_dict(dshard, dcfg))
        return out

    def hit_rate(self) -> float:
        c = self.counters
        hits = c["remote_hits"] + c["local_hits"]
        return hits / max(c["reads"], 1)


def _mask_to_nodes(mask_row: np.ndarray) -> List[int]:
    nodes = []
    for w, bits in enumerate(np.asarray(mask_row).tolist()):
        b = int(bits)
        while b:
            low = b & -b
            nodes.append(w * 32 + low.bit_length() - 1)
            b ^= low
    return nodes
