"""Per-node mapping cache — a software TLB in front of the DPC directory.

The paper's speedups hinge on established mappings being remote-memory-speed:
after the first MAP_S, "the directory adds ~nothing" to a re-read.  The seed
paid directory cost on 100% of accesses — every lookup ran the full
``read_pages`` -> ``_routed`` -> per-shard jitted opcode pipeline with host
syncs and device round trips.  This module caches established grants so a
steady-state re-read costs a few numpy ops and nothing else: **zero directory
opcodes, zero device round trips** — and, since the write-grant extension,
the same holds for a steady-state re-*write* (``mark_dirty`` on an owned
page whose dirty bit the directory already has, or will get in the next
batched flush).

Structure (mirrors the directory's open addressing, host-side numpy):

    keys   [S, 2] int32   (stream, page); EMPTY/TOMB sentinels like directory
    owner  [S]    int32   owner node of the cached mapping
    pfn    [S]    int32   global frame number the mapping resolves to
    mode   [S]    int8    MODE_S (shared) / MODE_O (owner) / MODE_M (owner
                          with a registered-or-buffered write grant)
    epoch  [S]    int64   global shootdown epoch at install time

Entry modes:

  MODE_S   remote S-mapping (HIT_SHARER / MAP_S): servable for reads.
  MODE_O   owner mapping (HIT_OWNER / commit): reads are local, a write
           must still register its dirty bit with the directory once.
  MODE_M   owner mapping whose dirty bit is already registered at the
           directory *or* sits in the owner's buffered-dirty set awaiting
           the next batched flush — a re-write is a pure cache hit.

A cached entry is *advisory*: it may be dropped at any time (capacity
replacement, shootdown) and the reader falls back to the directory.  What it
must never do is survive a teardown — coherence is enforced by the protocol
(core/protocol.py) through two mechanisms, mirroring hardware TLB shootdowns:

  piggybacked lanes    ``begin_invalidate`` / ``begin_migrate`` fan-outs
                       already name the sharer set; the protocol posts the
                       key to each named node's **shootdown queue**.  Queued
                       keys are not drained in-process: they are encoded as
                       SHOOTDOWN descriptor rows appended to the next opcode
                       batch routed on behalf of that node (paper §4.3-style
                       batching) and serviced *before* the batch's own ops
                       execute.  A sharer's INV_ACK is itself a routed batch,
                       so delivery still lands no later than the ACK.
  epoch fence          every ``post`` bumps the target's post-epoch; a
                       delivery advances its served-epoch.  Before a teardown
                       transaction completes, the protocol fences the named
                       sharers: any of them still behind (ACK force-cleared,
                       no traffic since) gets a forced delivery — bounded
                       staleness, completes always observe all teardowns.
  epoch flash          ``fail_node`` removes directory entries wholesale
                       without naming keys; the safety net is a **global
                       shootdown epoch** — bumping it invalidates every
                       cached entry on every node in O(1).

CLOCK touches for owner-mode hits are NOT issued per hit (that would be a
device round trip); callers buffer hit slots and flush them in one batched
``pagepool.touch_weighted`` per engine step (see DistributedKVCache).  The
write path mirrors the pattern: dirty marks for MODE_O hits are buffered
per node (core/protocol.py) and flushed in one batched ``mark_dirty`` per
engine step — and always before any teardown can observe the page.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.descriptors import hash_key_py
from repro.obs import CLUSTER, LEVEL_FULL, Obs
from repro.obs import trace as T

Key = Tuple[int, int]

# per-node counter names (registry rows under (node, "tlb", ...))
_TLB_STATS = ("hits", "misses", "installs", "replacements", "shootdowns")
# group-level plumbing counters (cluster scope)
_GROUP_STATS = ("posted", "serviced", "delivered", "fenced",
                "flashes", "wipes")

EMPTY = -1   # never-used slot: probe chains stop here
TOMB = -2    # shot-down slot: probe chains continue past

# entry modes (int8 lane); 0 is "unset" so a zeroed table holds no grants
MODE_S = 1   # shared mapping (remote reads)
MODE_O = 2   # owner mapping (local reads; a write still owes one mark_dirty)
MODE_M = 3   # owner mapping with write grant (dirty registered or buffered)

_C1 = np.uint32(0x9E3779B9)
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)


def _hash_np(streams: np.ndarray, pages: np.ndarray) -> np.ndarray:
    """Vectorized mirror of descriptors.hash_key (uint32 wraparound)."""
    h = streams.astype(np.uint32) * _C1
    h = h ^ (pages.astype(np.uint32) * _C2)
    h = h ^ (h >> np.uint32(16))
    h = h * _C3
    h = h ^ (h >> np.uint32(13))
    return h


class MappingTLB:
    """One node's fixed-size open-addressed mapping cache."""

    def __init__(self, slots: int, max_probe: int = 8, stats=None,
                 probe_hist=None):
        assert slots & (slots - 1) == 0, "tlb slots must be a power of two"
        self.slots = slots
        self.max_probe = min(max_probe, slots)
        self.keys = np.full((slots, 2), EMPTY, np.int32)
        self.owner = np.full((slots,), -1, np.int32)
        self.pfn = np.full((slots,), -1, np.int32)
        self.mode = np.zeros((slots,), np.int8)
        self.epoch = np.zeros((slots,), np.int64)
        # shootdown inbox: keys posted by in-flight directory transactions,
        # delivered (entries dropped) by the piggyback lanes of the next
        # opcode batch routed for this node — no later than its INV_ACK
        self.pending_inv: Deque[Key] = deque()
        # registry-backed when the group hands a MetricsView down (so the
        # counters survive a wipe-and-replace); plain dict standalone
        self.stats = stats if stats is not None \
            else {n: 0 for n in _TLB_STATS}
        self.probe_hist = probe_hist

    # -- scalar ops (install / drop run on the already-slow miss path) -------

    def _probe(self, stream: int, page: int, epoch: int
               ) -> Tuple[int, int]:
        """Returns (found_slot, insert_slot); -1 = none within max_probe."""
        mask = self.slots - 1
        h = hash_key_py(stream, page) & mask
        insert = -1
        for step in range(self.max_probe):
            i = (h + step) & mask
            s = int(self.keys[i, 0])
            if s == stream and int(self.keys[i, 1]) == page:
                return i, insert
            stale = s >= 0 and int(self.epoch[i]) != epoch
            if insert < 0 and (s == EMPTY or s == TOMB or stale):
                insert = i
            if s == EMPTY:
                break
        return -1, insert

    def install(self, stream: int, page: int, owner: int, pfn: int,
                mode: int, epoch: int) -> None:
        found, insert = self._probe(stream, page, epoch)
        slot = found
        if slot < 0:
            if insert < 0:
                # chain full within max_probe: replace the home slot — a TLB
                # is a cache, losing an entry only costs a directory re-read
                slot = hash_key_py(stream, page) & (self.slots - 1)
                self.stats["replacements"] += 1
            else:
                slot = insert
            self.keys[slot] = (stream, page)
            self.stats["installs"] += 1
        self.owner[slot] = owner
        self.pfn[slot] = pfn
        self.mode[slot] = mode
        self.epoch[slot] = epoch

    def drop(self, stream: int, page: int, epoch: int) -> bool:
        # the scalar probe matches the key regardless of epoch, so a
        # stale-epoch residue is tombed here too (harmless and keeps the
        # chain short); only the vectorized hit path is epoch-gated
        found, _ = self._probe(stream, page, epoch)
        if found < 0:
            return False
        self.keys[found] = (TOMB, TOMB)
        self.mode[found] = 0
        self.stats["shootdowns"] += 1
        return True

    # -- batched lookup (the steady-state hot path) --------------------------

    def lookup_batch(self, streams: np.ndarray, pages: np.ndarray,
                     epoch: int) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """Vectorized probe.  Returns (owner, pfn, mode, hit) arrays; rows
        with ``hit == False`` must fall back to the directory."""
        n = len(streams)
        mask = self.slots - 1
        idx = (_hash_np(streams, pages) & np.uint32(mask)).astype(np.int64)
        found = np.full((n,), -1, np.int64)
        live = np.ones((n,), bool)
        # probe-depth histogram (registry level): rows record the step at
        # which their chain resolved; unresolved rows charge max_probe
        depth = None if self.probe_hist is None \
            else np.full((n,), self.max_probe, np.int64)
        for step in range(self.max_probe):
            ks = self.keys[idx]
            match = live & (ks[:, 0] == streams) & (ks[:, 1] == pages) \
                & (self.epoch[idx] == epoch)
            found = np.where(match, idx, found)
            # EMPTY terminates the chain; TOMB and stale rows are probed past
            nxt = live & ~match & (ks[:, 0] != EMPTY)
            if depth is not None:
                depth[live & ~nxt] = step + 1
            live = nxt
            if not live.any():
                break
            idx = (idx + 1) & mask
        hit = found >= 0
        safe = np.maximum(found, 0)
        self.stats["hits"] += int(hit.sum())
        self.stats["misses"] += int(n - hit.sum())
        if depth is not None and n:
            self.probe_hist.observe_array(depth)
        return self.owner[safe], self.pfn[safe], self.mode[safe], hit


class TLBGroup:
    """The cluster's per-node TLBs + the coherence plumbing the protocol
    drives: per-node shootdown queues with piggybacked delivery (post /
    drain / deliver / fence epochs) and the global flash epoch."""

    def __init__(self, num_nodes: int, slots: int, max_probe: int = 8,
                 obs: Optional[Obs] = None):
        self.slots = slots
        self.max_probe = max_probe
        self.obs = obs if obs is not None else Obs("off")
        self.trace = self.obs.tracer
        self.nodes: List[MappingTLB] = [self._make_tlb(n)
                                        for n in range(num_nodes)]
        self.global_epoch = 1
        # bounded-staleness fence epochs: post_epoch counts shootdowns posted
        # to a node, served_epoch the prefix it has delivered.  A node is
        # "caught up" iff served == posted; transaction completes fence on it.
        self.post_epoch = [0] * num_nodes
        self.served_epoch = [0] * num_nodes
        self.stats = self.obs.view(CLUSTER, "tlb_group", _GROUP_STATS)

    def _make_tlb(self, node: int) -> MappingTLB:
        """Per-node TLB wired to the hub: the counter view targets the same
        registry rows across wipe-and-replace, so per-node stats persist
        until the rejoin incarnation fold rather than dying with the
        instance.  The probe-depth distribution costs depth-mask work per
        probe step, so it rides the ``full`` (tracing) tier, not the
        always-on counters tier."""
        return MappingTLB(
            self.slots, self.max_probe,
            stats=self.obs.view(node, "tlb", _TLB_STATS),
            probe_hist=self.obs.histogram(node, "tlb", "probe_depth",
                                          min_level=LEVEL_FULL))

    # -- elastic membership ---------------------------------------------------

    def add_node(self) -> int:
        """Join: attach a fresh (empty, caught-up) TLB for a new node."""
        self.nodes.append(self._make_tlb(len(self.nodes)))
        self.post_epoch.append(0)
        self.served_epoch.append(0)
        return len(self.nodes) - 1

    def wipe(self, node: int) -> None:
        """Precise per-node retirement: drop every mapping the node caches
        and mark its shootdown queue caught-up — without touching the
        global epoch, so every *other* node's warm entries survive (the
        whole point of drain over fail)."""
        self.nodes[node] = self._make_tlb(node)
        self.served_epoch[node] = self.post_epoch[node]
        self.stats["wipes"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_SD_WIPE, node)

    # -- read path -----------------------------------------------------------

    def lookup_batch(self, node: int, streams, pages):
        s = np.asarray(streams, np.int32)
        p = np.asarray(pages, np.int32)
        return self.nodes[node].lookup_batch(s, p, self.global_epoch)

    def lookup(self, node: int, stream: int, page: int
               ) -> Optional[Tuple[int, int, int]]:
        """Scalar probe: (owner, pfn, mode) or None."""
        owner, pfn, mode, hit = self.lookup_batch(node, [stream], [page])
        if not hit[0]:
            return None
        return int(owner[0]), int(pfn[0]), int(mode[0])

    # -- fills ----------------------------------------------------------------

    def install(self, node: int, stream: int, page: int, owner: int,
                pfn: int, mode: int) -> None:
        self.nodes[node].install(stream, page, owner, pfn, mode,
                                 self.global_epoch)

    # -- coherence -------------------------------------------------------------

    def drop(self, node: int, key: Key) -> bool:
        """Immediate local teardown (initiator side / voluntary drop)."""
        return self.nodes[node].drop(key[0], key[1], self.global_epoch)

    def post(self, node: int, key: Key) -> None:
        """Queue a shootdown for ``node``: it rides the piggyback lanes of
        the next opcode batch routed on that node's behalf (DIR_INV
        piggyback), bumping the node's post epoch for the fence."""
        self.nodes[node].pending_inv.append(key)
        self.post_epoch[node] += 1
        self.stats["posted"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_SD_POST, node, key[0], key[1])

    def drain_for(self, nodes: Sequence[int]) -> List[Tuple[int, int, int]]:
        """Pop every queued shootdown for ``nodes`` and advance their served
        epochs.  Returns (target_node, stream, page) triples for the caller
        to encode as piggyback lanes and hand back to ``deliver``."""
        out: List[Tuple[int, int, int]] = []
        for n in dict.fromkeys(int(n) for n in nodes):
            q = self.nodes[n].pending_inv
            while q:
                s, p = q.popleft()
                out.append((n, s, p))
            self.served_epoch[n] = self.post_epoch[n]
        return out

    def deliver(self, triples: Sequence[Tuple[int, int, int]]) -> int:
        """Service decoded piggyback lanes: drop each (node, stream, page).
        Runs before the carrying batch's own ops execute (protocol._routed),
        the modeled receiver-side shootdown service."""
        n = 0
        trace = self.trace
        for node, s, p in triples:
            self.nodes[node].drop(s, p, self.global_epoch)
            if trace is not None:
                trace.emit(T.EV_SD_DELIVER, node, s, p)
            n += 1
        self.stats["delivered"] += n
        return n

    def fence(self, nodes: Sequence[int]) -> int:
        """Bounded-staleness fence: force delivery for any named node still
        behind its post epoch (its ACK was force-cleared, or it saw no batch
        traffic since the post).  Transaction completes run this so a
        finished teardown can never leave a cached entry anywhere."""
        behind = [n for n in dict.fromkeys(int(n) for n in nodes)
                  if self.served_epoch[n] < self.post_epoch[n]]
        if not behind:
            return 0
        delivered = self.deliver(self.drain_for(behind))
        self.stats["fenced"] += delivered
        return delivered

    def service(self, node: int) -> int:
        """Synchronous in-process drain (legacy / piggyback-off mode): runs
        no later than the node's INV_ACK so a completed teardown can never
        leave a stale entry."""
        n = self.deliver(self.drain_for([node]))
        self.stats["serviced"] += n
        return n

    def service_all(self) -> int:
        """Synchronous-mode safety net before transaction completion."""
        return sum(self.service(n) for n in range(len(self.nodes)))

    def flash_all(self) -> None:
        """Global shootdown epoch bump: every cached entry on every node is
        invalid in O(1).  The fallback for teardowns that cannot name keys
        (``fail_node`` wipes a whole node's directory ownership)."""
        self.global_epoch += 1
        self.stats["flashes"] += 1
        if self.trace is not None:
            self.trace.emit(T.EV_SD_FLASH, CLUSTER)
        for i, t in enumerate(self.nodes):
            t.pending_inv.clear()
            self.served_epoch[i] = self.post_epoch[i]

    # -- views -----------------------------------------------------------------

    def holders(self, key: Key) -> List[int]:
        """Nodes whose TLB still serves ``key`` (oracle late-shootdown
        assert: must be empty once the key's teardown completed)."""
        return [n for n in range(len(self.nodes))
                if key in self.entries(n)]

    def entries(self, node: int) -> dict:
        """Host view {key: (owner, pfn, mode)} of live entries (tests)."""
        t = self.nodes[node]
        out = {}
        for i in range(t.slots):
            if int(t.keys[i, 0]) >= 0 and int(t.epoch[i]) == self.global_epoch:
                out[(int(t.keys[i, 0]), int(t.keys[i, 1]))] = (
                    int(t.owner[i]), int(t.pfn[i]), int(t.mode[i]))
        return out
