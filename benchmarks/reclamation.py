"""Reclamation overheads — paper §6.2.5 analog.

Measures (a) local-only frame release (no cross-node state: the baseline
"11 us" path), (b) synchronous single-page invalidation with a remote sharer
(directory round trip + DIR_INV + ACK + completion: the "99.7 us" path),
(c) the batched asynchronous flow (LOCAL_INV batch -> overlapped ACKs ->
single completion pass), whose per-page cost approaches the local one —
the paper's claim that batching removes invalidation from the critical path
— and (d) the same batched flow for *dirty* pages through the storage tier
(retire -> batched flush -> release), the full writeback pipeline cost.

``smoke=True`` shrinks pools/batches/iters to a seconds-scale run that CI
exercises end-to-end (instead of import-checking).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fresh, time_host
from repro.configs.base import DPCConfig
from repro.core import pagepool as pp
from repro.core.dpc_cache import DistributedKVCache

PAGE = 16
NODES = 4


def _warm_cache(n_pages: int, pool_pages: int, sharer: bool = True,
                storage: bool = False, dirty: bool = False
                ) -> DistributedKVCache:
    dpc = DPCConfig(page_size=PAGE, pool_pages_per_shard=pool_pages,
                    storage_backend="memory" if storage else "none",
                    writeback_async=False, writeback_batch=32)
    kv = DistributedKVCache(dpc, NODES)
    if storage:
        payload = np.zeros((PAGE, 4), np.float32)
        kv.set_page_bytes_fn(lambda key, pfn: payload)
    streams = list(range(1, n_pages + 1))
    pages = [0] * n_pages
    lks = kv.lookup(streams, pages, 0)
    kv.commit(streams, pages, 0, lks, dirty=dirty if storage else None)
    if sharer:
        kv.lookup(streams, pages, 2)   # node 2 maps everything remotely
    return kv


def run(smoke: bool = False):
    pool_pages = 512 if smoke else 4096
    batch = 16 if smoke else 32
    iters = 2 if smoke else 5

    # (a) local-only release: pool ops without any directory involvement
    # (ops donate their buffers, so each sample runs the full
    # alloc -> install -> release cycle on a fresh pool)
    def local_cycle():
        pool = pp.init_pool(pool_pages)
        pool, slots = pp.alloc(pool, jnp.ones((1,), bool))
        pool = pp.install(pool, slots, jnp.ones((1, 2), jnp.int32))
        pool = pp.release(pool, slots)
        pool.free_top.block_until_ready()

    t_local = time_host(local_cycle, iters=iters)
    emit("reclaim.local_only.1pg", t_local, "no directory (full cycle)")

    # (b) synchronous single-page invalidation with a live sharer
    t_sync = time_fresh(lambda: _warm_cache(1, pool_pages),
                        lambda kv: kv.proto.reclaim_sync(0, want=1),
                        iters=iters)
    emit("reclaim.sync_remote.1pg", t_sync,
         f"vs_local={t_sync / max(t_local, 1e-9):.1f}x")

    # (c) batched asynchronous invalidation (threshold 32, paper §4.3)
    def batched(kv):
        _, notify = kv.proto.reclaim_begin(0, want=batch)
        for key, sharers in notify.items():
            for s in sharers:
                kv.proto.reclaim_ack(key[0], key[1], s)
        kv.proto.reclaim_finish(0)

    t_batch = time_fresh(lambda: _warm_cache(batch * 2, pool_pages),
                         batched, iters=iters) / batch
    emit("reclaim.batched_async.per_pg", t_batch,
         f"batch={batch} amortization={t_sync / max(t_batch, 1e-9):.1f}x")

    # (d) dirty pages: the same batch pays retire -> batched flush ->
    # release through the writeback queue (the storage-tier price of the
    # single-copy invariant — an evicted dirty page must be durable
    # before its frame is reusable)
    def batched_dirty(kv):
        batched(kv)
        kv.flush()

    t_wb = time_fresh(
        lambda: _warm_cache(batch * 2, pool_pages, storage=True, dirty=True),
        batched_dirty, iters=iters) / batch
    emit("reclaim.batched_writeback.per_pg", t_wb,
         f"batch={batch} vs_clean={t_wb / max(t_batch, 1e-9):.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
