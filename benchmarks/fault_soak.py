"""Chaos soak — membership churn under randomized seeded fault schedules.

ISSUE 9 acceptance harness: >= 20 randomized fault schedules (``--smoke``
runs 5), each a seeded :func:`repro.runtime.faults.random_plan` (message
drops with bounded retry, lane delay/duplication, node crashes at named
crash points, clock skew, transient sync failures) driven through
join / drain / failover / partition-heal churn on a 5-node cluster with
the shadow oracle checking every transition.  Per schedule, asserted
inline:

* zero lost committed dirty bytes (crash recovery checkpoints the
  surviving pooled frames — CXL memory outlives the node — before the
  failover wipes its state);
* zero single-copy violations (shadow oracle per-op + explicit
  ``check_invariants`` at settle + full trace-replay audit);
* the fenced minority serves reads local-only and commits **no**
  ownership transitions while fenced;
* sustained survivor throughput at every churn epoch.

Two dedicated witness tie-break schedules ride along (smoke and full):
a 6-voter cluster with one CXL witness lease word partitions a 2- and a
3-node minority — the 3/3 split only commits because the witness attests
for the majority — and the whole fenced group must serve local-only with
**zero** committed ownership transitions until heal + re-probe rejoin.

Emits one row per schedule plus a summary; ``BENCH_fault_soak.json``
(CI uploads it, the perf gate compares against the committed baseline).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import DPCConfig
from repro.core import descriptors as D
from repro.core.dpc_cache import DistributedKVCache
from repro.obs.audit import audit_events
from repro.runtime.faults import FAULT_COUNTERS, NodeCrash, random_plan
from repro.runtime.liveness import Membership

PAGE = 16
NODES = 5

# epoch actions the schedule rng draws uniformly — at 5-10 epochs per
# schedule every kind of churn shows up across the suite
_ACTIONS = ("traffic", "drain", "fail", "partition")


def _new_cluster(per_node: int, nodes: int = NODES, witnesses: int = 0):
    dpc = DPCConfig(page_size=PAGE, pool_pages_per_shard=per_node * 3,
                    directory_capacity=1 << 10,
                    storage_backend="memory", writeback_async=False,
                    shadow_oracle=True, obs_level="full",
                    migrate_threshold=3, migrate_batch=per_node * nodes)
    kv = DistributedKVCache(dpc, nodes)
    frames = {}
    kv.set_page_bytes_fn(lambda key, pfn: frames.get(key))
    membership = Membership(num_nodes=nodes, witnesses=witnesses)
    kv.attach_membership(
        membership,
        install_fn=lambda key, pfn, data: frames.__setitem__(
            key, np.asarray(data)))
    return kv, frames, membership


def _traffic(kv, frames, readers, all_streams, rng, reads) -> int:
    """One sustained-traffic leg; returns ops served."""
    ops = 0
    for reader in readers:
        picks = rng.choice(len(all_streams), reads, replace=True)
        streams = [all_streams[i] for i in picks]
        pages = [0] * len(streams)
        lks = kv.lookup(streams, pages, reader)
        for s, lk in zip(streams, lks):
            if lk.needs_fill and lk.page_id >= 0:
                frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
        kv.commit(streams, pages, reader, lks)
        ops += len(streams)
    return ops


def _recover_crash(kv, membership, crash: NodeCrash) -> None:
    """Harness reaction to a fault-plan crash: the pooled frames survive
    the node (CXL), so registered dirty pages checkpoint before the
    ordinary failover wipes its state — zero lost committed bytes."""
    kv.checkpoint_dirty()
    membership.evict(crash.node, kind="fail")


def _fault_totals(plan) -> dict:
    tot = {k: 0 for k in FAULT_COUNTERS}
    for n in list(range(NODES)) + [-1]:
        for k, v in plan.counters(n).items():
            tot[k] += v
    return tot


def run_schedule(seed: int, per_node: int, epochs: int,
                 intensity: float = 1.0, trace: str = "") -> dict:
    """One seeded fault schedule; returns its summary stats."""
    kv, frames, membership = _new_cluster(per_node)
    rng = np.random.default_rng(seed)
    membership.clock = time.monotonic   # skew wired below, bounded < timeout

    # steady state: every node first-touches its shard, then checkpoints
    shard = {}
    for n in range(NODES):
        streams = [n * per_node + i + 1 for i in range(per_node)]
        shard[n] = streams
        lks = kv.lookup(streams, [0] * per_node, n)
        for s in streams:
            frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
        kv.commit(streams, [0] * per_node, n, lks)
    all_streams = [s for n in range(NODES) for s in shard[n]]
    kv.checkpoint_dirty()

    # arm the schedule only after the steady state exists: the soak
    # measures churn under faults, not a cluster that never got built
    plan = random_plan(seed, NODES, obs=kv.obs, intensity=intensity,
                       crash_candidates=list(range(1, NODES)))
    kv.attach_faults(plan)
    for skewed in plan.cfg.clock_skew_s:
        # bounded skew (< the liveness timeout) stresses the detector
        # without manufacturing false suspicions
        membership.clock = plan.skewed_clock(skewed, time.monotonic)

    crashes = 0
    t0 = time.perf_counter()
    total_ops = 0
    for epoch in range(epochs):
        action = _ACTIONS[int(rng.integers(len(_ACTIONS)))]
        victim = int(rng.integers(1, NODES))
        try:
            if action == "drain" and victim in membership.alive \
                    and len(membership.alive) > 2:
                membership.drain(victim)
            elif action == "fail" and victim in membership.alive \
                    and len(membership.alive) > 2:
                kv.checkpoint_dirty()
                membership.evict(victim, kind="fail")
            elif action == "partition" and victim in membership.alive \
                    and len(membership.alive) > 2:
                kv.checkpoint_dirty()
                membership.partition([victim])
                membership.assert_no_quorum(victim)
                # the fenced minority keeps serving — local-only, zero
                # ownership transitions while fenced
                commits_before = kv.proto.counters["commits"]
                fenced_lks = kv.lookup(
                    [9000 + victim, 9100 + victim], [0, 0], victim)
                assert all(lk.status in (D.ST_GRANT_E, D.ST_FULL)
                           for lk in fenced_lks), \
                    f"fenced node {victim} served through the directory"
                kv.commit([9000 + victim, 9100 + victim], [0, 0],
                          victim, fenced_lks)
                assert kv.proto.counters["commits"] == commits_before, \
                    f"fenced node {victim} committed an ownership transition"
        except NodeCrash as c:
            crashes += 1
            _recover_crash(kv, membership, c)

        ep0 = time.perf_counter()
        try:
            ops = _traffic(kv, frames, sorted(membership.alive),
                           all_streams, rng,
                           max(4, per_node // 2))
        except NodeCrash as c:
            crashes += 1
            _recover_crash(kv, membership, c)
            ops = _traffic(kv, frames, sorted(membership.alive),
                           all_streams, rng, max(4, per_node // 2))
        dt = max(time.perf_counter() - ep0, 1e-9)
        assert ops > 0 and ops / dt > 0, \
            f"schedule {seed} epoch {epoch}: no sustained throughput"
        total_ops += ops

        # pump fresh dirty pages through the writeback queue every epoch
        # so the schedule's sync-failure budget (and the reclaim crash
        # points) actually get exercised
        try:
            helper = int(min(membership.alive))
            wb = [5000 + epoch * 2, 5001 + epoch * 2]
            lks = kv.lookup(wb, [0, 0], helper)
            for s in wb:
                frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
            kv.commit(wb, [0, 0], helper, lks)
            kv.reclaim(helper, per_node + 2)
            kv.flush()
        except NodeCrash as c:
            crashes += 1
            _recover_crash(kv, membership, c)

        # heal any partition and drive the guard's re-probe rejoin
        if membership.fenced:
            membership.heal()
            for _ in range(4):
                kv.probe_fenced(membership)
            assert not membership.fenced, "heal re-probe never rejoined"
        # departed nodes come back empty before the next epoch
        for n in range(NODES):
            if n not in membership.alive:
                membership.join(n)
    wall = time.perf_counter() - t0

    # settle and check everything the schedule could have broken; the
    # reclaim leg pushes dirty evictions through the writeback queue so
    # the schedule's sync-failure budget actually gets spent (crash
    # points stay disarmed — settle is cleanup, not measured churn)
    plan.disarm()
    kv.proto.fence_data_lanes()
    for n in sorted(membership.alive):
        kv.reclaim(n, 4)
    kv.flush()
    if kv.proto.oracle is not None:
        kv.proto.oracle.check_invariants()
    c = kv.proto.counters
    assert c["lost_dirty_pages"] == 0, \
        f"schedule {seed}: lost {c['lost_dirty_pages']} committed dirty pages"
    owners: dict = {}
    for key, (st, owner, _sh, _pfn, _d) in kv.proto.directory_view().items():
        assert key not in owners, f"double-owned {key}"
        owners[key] = owner
    tr = kv.obs.tracer
    violations = audit_events(
        tr.events(), pool_pages=kv.dpc.pool_pages_per_shard,
        dropped=tr.dropped)
    assert not violations, \
        f"schedule {seed}: {len(violations)} trace violations: " \
        f"{[str(v) for v in violations[:5]]}"
    faults = _fault_totals(plan)
    # node obs rows reset when a churned node rejoins (new incarnation),
    # so setup-time skew wiring is re-accounted from the plan itself
    faults["skew_applied"] = max(faults["skew_applied"],
                                 len(plan.cfg.clock_skew_s))
    out = {"seed": seed, "ops": total_ops, "wall_s": wall,
           "crashes": crashes, "faults": faults,
           "epoch": membership.epoch, "violations": 0}
    if trace:
        # full-history Chrome trace for the CI artifact; the workflow
        # replays it through `python -m repro.obs.audit` afterwards
        kv.obs.tracer.export_chrome(trace)
    kv.close()
    return out


def run_minority_schedule(seed: int, per_node: int,
                          minority_size: int = 3) -> dict:
    """Witness tie-break schedule: a 6-voter cluster (one CXL witness
    lease word) partitions a multi-node minority — including the even
    3/3 split only the witness can break.  The whole fenced group must
    keep serving local-only and commit **zero** ownership transitions
    while fenced; the majority side sustains traffic throughout."""
    nodes = 6
    kv, frames, membership = _new_cluster(per_node, nodes=nodes,
                                          witnesses=1)
    rng = np.random.default_rng(seed)

    shard = {}
    for n in range(nodes):
        streams = [n * per_node + i + 1 for i in range(per_node)]
        shard[n] = streams
        lks = kv.lookup(streams, [0] * per_node, n)
        for s in streams:
            frames[(s, 0)] = np.full(PAGE, float(s), np.float32)
        kv.commit(streams, [0] * per_node, n, lks)
    all_streams = [s for n in range(nodes) for s in shard[n]]
    kv.checkpoint_dirty()

    minority = sorted(int(v) for v in rng.choice(
        np.arange(1, nodes), size=minority_size, replace=False))
    t0 = time.perf_counter()
    cut = membership.partition(minority)
    assert cut == minority, f"partition fenced {cut}, wanted {minority}"

    # every fenced node: no quorum, local-only service, zero commits
    commits_before = kv.proto.counters["commits"]
    for victim in minority:
        membership.assert_no_quorum(victim)
        fenced_lks = kv.lookup([9000 + victim, 9100 + victim], [0, 0],
                               victim)
        assert all(lk.status in (D.ST_GRANT_E, D.ST_FULL)
                   for lk in fenced_lks), \
            f"fenced node {victim} served through the directory"
        kv.commit([9000 + victim, 9100 + victim], [0, 0], victim,
                  fenced_lks)
    assert kv.proto.counters["commits"] == commits_before, \
        f"fenced group {minority} committed an ownership transition"

    # the witness-backed majority keeps quorum and keeps serving
    ops = 0
    for _ in range(3):
        ops += _traffic(kv, frames, sorted(membership.alive), all_streams,
                        rng, max(4, per_node // 2))
    assert ops > 0

    membership.heal()
    for _ in range(4):
        kv.probe_fenced(membership)
    assert not membership.fenced, "heal re-probe never rejoined"
    wall = time.perf_counter() - t0

    kv.proto.fence_data_lanes()
    kv.flush()
    if kv.proto.oracle is not None:
        kv.proto.oracle.check_invariants()
    c = kv.proto.counters
    assert c["lost_dirty_pages"] == 0
    tr = kv.obs.tracer
    violations = audit_events(
        tr.events(), pool_pages=kv.dpc.pool_pages_per_shard,
        dropped=tr.dropped)
    assert not violations, \
        f"minority schedule {seed}: {len(violations)} trace violations"
    out = {"seed": seed, "ops": ops, "wall_s": wall,
           "minority": minority, "fenced": len(minority),
           "epoch": membership.epoch}
    kv.close()
    return out


def run(smoke: bool = False, schedules: int = 0, trace: str = "") -> int:
    n = schedules or (5 if smoke else 24)
    per_node = 6 if smoke else 12
    epochs = 5 if smoke else 8
    absorbed = {k: 0 for k in FAULT_COUNTERS}
    total_crashes = 0
    for seed in range(n):
        s = run_schedule(seed, per_node, epochs,
                         trace=trace if seed == n - 1 else "")
        total_crashes += s["crashes"]
        for k, v in s["faults"].items():
            absorbed[k] += v
        emit(f"fault_soak.schedule_{seed}",
             s["wall_s"] / max(s["ops"], 1) * 1e6,
             f"ops={s['ops']} crashes={s['crashes']} "
             f"drops={s['faults']['drops_injected']} "
             f"delays={s['faults']['lanes_delayed']} "
             f"dups={s['faults']['lanes_duplicated']} "
             f"syncfails={s['faults']['sync_fails_injected']} "
             f"epochs={s['epoch']} lost_dirty=0 violations=0")
    # rejoin resets the crashed node's obs row (new incarnation), so the
    # harness's own crash count is the authoritative one
    absorbed["crashes_fired"] = max(absorbed["crashes_fired"], total_crashes)

    # dedicated witness tie-break schedules: multi-node minority
    # partitions (one an even 3/3 split) on a 6-voter + 1-witness
    # cluster — the fenced group must commit zero ownership transitions
    for i, msize in enumerate((2, 3)):
        s = run_minority_schedule(1000 + i, per_node, minority_size=msize)
        emit(f"fault_soak.partition_minority_{msize}",
             s["wall_s"] / max(s["ops"], 1) * 1e6,
             f"ops={s['ops']} minority={s['minority']} "
             f"fenced={s['fenced']} epochs={s['epoch']} "
             f"commits_while_fenced=0 violations=0")

    active = sum(1 for k in ("drops_injected", "lanes_delayed",
                             "lanes_duplicated", "crashes_fired",
                             "sync_fails_injected") if absorbed[k])
    assert active >= 4, f"schedules too tame: only {active} fault kinds fired"
    emit("fault_soak.summary", 0.0,
         f"schedules={n} crashes={total_crashes} "
         f"drops={absorbed['drops_injected']} "
         f"retries={absorbed['retries']} "
         f"timeouts={absorbed['send_timeouts']} "
         f"delays={absorbed['lanes_delayed']} "
         f"dups={absorbed['lanes_duplicated']} "
         f"syncfails={absorbed['sync_fails_injected']} "
         f"skews={absorbed['skew_applied']} "
         f"lost_dirty=0 violations=0")
    return n


if __name__ == "__main__":
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--schedules", type=int, default=0,
                    help="override the schedule count (0 = suite default)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="export the last schedule's full event history "
                         "as a Chrome trace JSON (CI replays it through "
                         "repro.obs.audit)")
    args = ap.parse_args()
    run(smoke=args.smoke, schedules=args.schedules, trace=args.trace)
    common.dump_json("fault_soak")
